//! Minimal, dependency-free shim of the `anyhow` error API for offline
//! builds (the registry image cannot fetch crates). Implements the
//! surface this repository uses — `Error`, `Result`, `anyhow!`, `bail!`,
//! `ensure!`, and the `Context` extension trait for `Result` and
//! `Option` — with the same formatting conventions (`{e}` prints the
//! outermost message, `{e:#}` prints the whole context chain).
//!
//! Differences from real anyhow: the cause chain is stored as rendered
//! strings (no `downcast`, no backtraces). Swap this path dependency for
//! the registry crate when network access is available — no call sites
//! need to change.

use std::fmt::{self, Display};

/// An error wrapping a chain of context messages. `chain[0]` is the
/// outermost (most recently attached) context.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost to root cause.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("error"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                if self.chain.len() > 2 {
                    write!(f, "\n    {i}: {cause}")?;
                } else {
                    write!(f, "\n    {cause}")?;
                }
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket conversion below coherent (same trick as the
// real anyhow crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Conversion into `Error` from both std errors and `Error` itself —
/// the receiver bound used by [`Context`] (anyhow's `ext::StdError`).
pub trait ToError {
    fn to_error(self) -> Error;
}

impl<E> ToError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn to_error(self) -> Error {
        Error::from(self)
    }
}

impl ToError for Error {
    fn to_error(self) -> Error {
        self
    }
}

/// Context extension for `Result` and `Option`, mirroring anyhow.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ToError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.to_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.to_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().with_context(|| "reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_build_errors() {
        let name = "pool";
        let e = anyhow!("no such {name}");
        assert_eq!(e.to_string(), "no such pool");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "math broke: 42");
        fn g() -> Result<()> {
            bail!("bye")
        }
        assert!(g().is_err());
    }
}
