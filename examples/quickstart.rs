//! Quickstart: load the AOT-compiled Performer, fill masked residues in a
//! protein sequence through the serving coordinator.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;
use performer::configx::ServeConfig;
use performer::coordinator::Coordinator;
use performer::protein::vocab::{self, BOS, EOS, MASK};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::EngineActor;

fn main() -> Result<()> {
    // 1. the engine actor owns the PJRT CPU client + compile cache
    let actor = EngineActor::spawn("artifacts")?;

    // 2. a coordinator pool serving the tiny Performer-ReLU MLM
    let cfg = ServeConfig { artifact: "tiny_relu_bid".into(), ..Default::default() };
    let mut coord = Coordinator::new(actor.handle());
    coord.start_pool(&cfg, None)?;

    // 3. mask a few residues of a synthetic protein and ask the model
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(7);
    let (family, seq) = corpus.sample_iid(&mut rng);
    let mut tokens = vec![BOS];
    tokens.extend(seq.iter().take(40));
    tokens.push(EOS);
    let original = tokens.clone();
    for i in [5usize, 12, 23, 31] {
        tokens[i] = MASK;
    }

    println!("family   : {family}");
    println!("original : {}", vocab::decode(&original));
    println!("masked   : {}", vocab::decode(&tokens));

    let resp = coord.fill_mask(&cfg.artifact, tokens)?;
    println!("filled   : {}", vocab::decode(&resp.filled));
    for (pos, tok, p) in &resp.predictions {
        let truth = vocab::token_letter(original[*pos]);
        let guess = vocab::token_letter(*tok);
        println!(
            "  pos {pos:>2}: predicted {guess} (p={p:.3}), original {truth} {}",
            if guess == truth { "✓" } else { " " }
        );
    }
    println!("latency  : {:?}", resp.latency);

    let metrics = coord.metrics(&cfg.artifact).unwrap();
    println!("metrics  : {}", metrics.summary());
    coord.shutdown();
    drop(actor);
    let _ = Arc::strong_count(&metrics);
    Ok(())
}
