//! Serving load test: start the coordinator, fire concurrent fill-mask
//! requests from client threads, and report latency/throughput — the
//! serving-side counterpart of the paper's efficiency claims.
//!
//!   make artifacts && cargo run --release --example serve_proteins
//!
//! Environment: SERVE_REQUESTS (default 128), SERVE_CLIENTS (default 4),
//! SERVE_ARTIFACT (default tiny_relu_bid).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use performer::configx::ServeConfig;
use performer::coordinator::Coordinator;
use performer::protein::vocab::{AA_BASE, MASK};
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::runtime::EngineActor;

fn main() -> Result<()> {
    let n_requests: usize = std::env::var("SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128);
    let n_clients: usize = std::env::var("SERVE_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let artifact =
        std::env::var("SERVE_ARTIFACT").unwrap_or_else(|_| "tiny_relu_bid".to_string());

    let actor = EngineActor::spawn("artifacts")?;
    let cfg = ServeConfig { artifact: artifact.clone(), max_batch: 8, max_wait_ms: 4, workers: 1, seed: 0 };
    let mut coord = Coordinator::new(actor.handle());
    coord.start_pool(&cfg, None)?;
    let coord = Arc::new(coord);

    let l = actor.handle().meta(&format!("{artifact}_fwd"))?.config.max_len;
    println!("serving {artifact} (L={l}); {n_clients} clients x {} requests", n_requests / n_clients);

    // a wedged worker must surface as a timeout error, not a client
    // that blocks forever — every request in this load test carries a
    // deadline (first one generous: it pays the PJRT compile)
    let deadline = Duration::from_secs(30);

    // warm the executable before timing
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    {
        let mut rng = Pcg64::new(99);
        let toks = corpus.window(&corpus.sample_iid(&mut rng).1, l);
        coord.fill_mask_timeout(&artifact, toks, Duration::from_secs(120))?;
    }

    let t0 = Instant::now();
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let coord = coord.clone();
        let corpus = corpus.clone();
        let artifact = artifact.clone();
        let per_client = n_requests / n_clients;
        clients.push(std::thread::spawn(move || -> Result<(usize, f64)> {
            let mut rng = Pcg64::new(1000 + c as u64);
            let mut filled = 0usize;
            let mut latency_sum = 0.0f64;
            for _ in 0..per_client {
                let (_, seq) = corpus.sample_iid(&mut rng);
                let mut toks = corpus.window(&seq, l);
                for t in toks.iter_mut() {
                    if *t >= AA_BASE && rng.uniform() < 0.15 {
                        *t = MASK;
                    }
                }
                let resp = coord.fill_mask_timeout(&artifact, toks, deadline)?;
                filled += resp.predictions.len();
                latency_sum += resp.latency.as_secs_f64();
            }
            Ok((filled, latency_sum / per_client as f64))
        }));
    }
    let mut total_filled = 0;
    for c in clients {
        let (filled, mean_lat) = c.join().expect("client panicked")?;
        total_filled += filled;
        println!("client mean latency: {:.1}ms", mean_lat * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = coord.metrics(&artifact).unwrap();
    println!("\n== load test ==");
    println!("requests        : {n_requests} in {wall:.2}s -> {:.1} req/s", n_requests as f64 / wall);
    println!("masks filled    : {total_filled}");
    println!("tokens/s        : {:.0}", (n_requests * l) as f64 / wall);
    println!("pool metrics    : {}", m.summary());
    println!(
        "batching amortization: mean batch {:.2} (1.0 = no batching win)",
        m.mean_batch_size()
    );

    Arc::try_unwrap(coord).ok().map(|mut c| c.shutdown());
    Ok(())
}
