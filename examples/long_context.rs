//! Long-context demonstration (the paper's motivating capability):
//! process concatenated protein sequences far beyond the exact-attention
//! memory budget with the native FAVOR implementation, and show the
//! analytic memory accounting that replaces the paper's V100 OOM plot.
//!
//!   cargo run --release --example long_context
//!
//! No artifacts required — this exercises the native (L3) FAVOR path, so
//! it can sweep L well past what exact attention can materialize.

use anyhow::Result;
use performer::benchlib::{fmt_secs, loglog_slope, Bench, Report};
use performer::favor::{exact_attention, favor_attention, Direction, FeatureKind, FeatureMap};
use performer::linalg::OrfMechanism;
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::tensor::Mat;

fn main() -> Result<()> {
    let d = 64;
    let m_feats = 128;
    let mut rng = Pcg64::new(0);
    let fm = FeatureMap::sample(FeatureKind::Relu, m_feats, d, OrfMechanism::Regular, &mut rng);

    // a real concatenated-protein stream drives the sweep
    let corpus = Corpus::generate(CorpusConfig::default());

    let mut rep = Report::new(
        "Long-context attention: FAVOR vs exact (native, causal)",
        &["L", "favor_time", "exact_time", "favor_bytes", "exact_bytes", "exact_feasible_16GB"],
    );
    let bench = Bench { warmup: 1, samples: 3, max_total_secs: 20.0 };
    let mut ls = Vec::new();
    let mut favor_times = Vec::new();
    for l in [512usize, 1024, 2048, 4096, 8192] {
        let window = corpus.concat_stream(l, 1, &mut rng).pop().unwrap();
        // token-derived pseudo-embeddings keep the sweep data-driven
        let q = Mat::from_fn(l, d, |i, j| {
            ((window[i] as usize * 31 + j * 7) % 13) as f32 * 0.05 - 0.3
        });
        let k = q.clone();
        let v = Mat::from_fn(l, d, |i, j| ((window[i] as usize + j) % 7) as f32 * 0.1);

        let favor = bench.run(&format!("favor_L{l}"), || {
            favor_attention(&fm, &q, &k, &v, Direction::Unidirectional)
        });
        // exact attention only up to the point it stays tractable here
        let exact_time = if l <= 2048 {
            let s = bench.run(&format!("exact_L{l}"), || {
                exact_attention(&q, &k, &v, Direction::Unidirectional)
            });
            fmt_secs(s.median())
        } else {
            "skipped".to_string()
        };

        // memory accounting per head (f32): exact stores the LxL matrix;
        // FAVOR stores LxM features + the M x (d+1) running state
        let favor_bytes = 4 * (l * m_feats + m_feats * (d + 1));
        let exact_bytes = 4 * l * l;
        // the paper's observed boundary: V100 16GB, regular model, batch 1.
        // 8 heads x 6 layers of LxL f32 (+activations ~2x) vs 16GB:
        let feasible = (exact_bytes as f64) * 8.0 * 6.0 * 2.0 < 16e9;

        ls.push(l as f64);
        favor_times.push(favor.median());
        rep.row(vec![
            l.to_string(),
            fmt_secs(favor.median()),
            exact_time,
            favor_bytes.to_string(),
            exact_bytes.to_string(),
            feasible.to_string(),
        ]);
    }
    println!("{}", rep.render());

    let slope = loglog_slope(&ls, &favor_times);
    println!("FAVOR time scaling exponent over L: {slope:.2} (paper claims ~1.0 linear; exact is 2.0)");
    assert!(slope < 1.5, "FAVOR must scale sub-quadratically");
    rep.save_csv(std::path::Path::new("results/long_context.csv"))?;
    Ok(())
}
