//! Long-context streaming demonstration (the paper's motivating
//! capability, upgraded to the stateful session API): consume
//! concatenated protein streams chunk by chunk through the native
//! Performer stack, far beyond any fixed compiled length, with resident
//! memory that does not grow with the stream.
//!
//!   cargo run --release --example long_context
//!
//! No artifacts required — this drives `stream::ChunkScorer` over a
//! synthetic native model, plus the raw `FavorStream` attention core.
//! The analytic memory accounting replaces the paper's V100 OOM plot:
//! exact attention must materialize O(L²) per head, the stream carries
//! O(M·d) regardless of L.

use anyhow::Result;
use performer::benchlib::{fmt_secs, loglog_slope, Report};
use performer::favor::{FeatureKind, FeatureMap};
use performer::linalg::OrfMechanism;
use performer::protein::{Corpus, CorpusConfig};
use performer::rng::Pcg64;
use performer::stream::{chunked_latency_point, FavorStream};
use performer::tensor::Mat;
use performer::train::{NativeModel, SyntheticConfig};
use std::sync::Arc;

fn main() -> Result<()> {
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(0);

    // --- 1. raw attention core: one head streamed vs single-shot ------
    let (d, m_feats, l) = (64usize, 128usize, 4096usize);
    let fm = FeatureMap::sample(FeatureKind::Relu, m_feats, d, OrfMechanism::Regular, &mut rng);
    let window = corpus.concat_stream(l, 1, &mut rng).pop().unwrap();
    let q = Mat::from_fn(l, d, |i, j| {
        ((window[i] as usize * 31 + j * 7) % 13) as f32 * 0.05 - 0.3
    });
    let k = q.clone();
    let v = Mat::from_fn(l, d, |i, j| ((window[i] as usize + j) % 7) as f32 * 0.1);

    let single = performer::favor::favor_attention(
        &fm,
        &q,
        &k,
        &v,
        performer::favor::Direction::Unidirectional,
    );
    let mut stream = FavorStream::new(fm.clone(), d);
    let mut streamed_rows = Vec::new();
    for lo in (0..l).step_by(512) {
        let hi = (lo + 512).min(l);
        let out = stream.advance(
            &q.rows_slice(lo, hi),
            &k.rows_slice(lo, hi),
            &v.rows_slice(lo, hi),
        );
        streamed_rows.extend(out.data);
    }
    let streamed = Mat::from_vec(l, d, streamed_rows);
    let diff = streamed.max_abs_diff(&single);
    println!(
        "streamed (8 x 512-token chunks) vs single-shot attention: max |Δ| = {diff:.2e} \
         (state: {} bytes)",
        stream.state().state_bytes()
    );
    assert!(diff < 1e-5, "streamed attention must equal single-shot");

    // --- 2. full model: per-chunk latency flat as streams grow --------
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let chunk = 512usize;
    let mut rep = Report::new(
        "Long-context streaming: full Performer stack, chunked (native, causal)",
        &["total_L", "chunks", "per_chunk_first", "per_chunk_last", "stream_bytes", "exact_bytes_at_L"],
    );
    let mut ls = Vec::new();
    let mut lasts = Vec::new();
    for total in [4096usize, 8192, 16384, 32768] {
        let p = chunked_latency_point(&model, &corpus, chunk, total, &mut rng)?;
        ls.push(total as f64);
        lasts.push(p.last_secs);
        // exact attention at this L would need the L×L matrix per head
        let exact_bytes = 4usize * total * total;
        rep.row(vec![
            total.to_string(),
            p.n_chunks.to_string(),
            fmt_secs(p.first_secs),
            fmt_secs(p.last_secs),
            p.state_bytes.to_string(),
            exact_bytes.to_string(),
        ]);
    }
    println!("{}", rep.render());

    let slope = loglog_slope(&ls, &lasts);
    println!(
        "per-chunk latency scaling exponent over total L: {slope:.2} \
         (streaming claim: ~0.0 flat; exact attention is ≥1 per token)"
    );
    assert!(slope < 0.5, "per-chunk cost must not grow with total streamed length");
    rep.save_csv(std::path::Path::new("results/long_context.csv"))?;
    Ok(())
}
