//! End-to-end validation: train the base Performer-ReLU protein MLM on
//! the synthetic TrEMBL-surrogate corpus for a few hundred steps, log the
//! loss curve, evaluate on Test + OOD, and compare against the empirical
//! baseline — exercising every layer of the stack:
//!
//!   L1 Pallas kernels  →  L2 JAX model  →  AOT HLO  →  L3 rust driver
//!   (data pipeline, masking, train loop, checkpointing, eval).
//!
//!   make artifacts && cargo run --release --example train_mlm
//!
//! Environment: TRAIN_STEPS (default 300) scales the run; the loss curve
//! is written to results/train_mlm_curve.csv and recorded in
//! EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use anyhow::Result;
use performer::protein::{
    empirical_baseline, mlm_batch, token_frequencies, Corpus, CorpusConfig, MaskPolicy,
};
use performer::rng::Pcg64;
use performer::runtime::Engine;
use performer::train::{run_training, LoopOptions, Split, TrainState};

fn main() -> Result<()> {
    let steps: usize = std::env::var("TRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let tag = "base_perf_relu_bid";

    let engine = Arc::new(Engine::new("artifacts")?);
    println!("platform: {}", engine.platform());

    let mut state = TrainState::new(engine, tag)?;
    println!(
        "model: {} ({} params, L={}, batch={})",
        tag,
        state.train_exe.meta.config.param_count,
        state.train_exe.meta.config.max_len,
        state.train_exe.meta.config.batch
    );

    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut gen = state.data_gen(corpus.clone(), 42);

    let t0 = std::time::Instant::now();
    let opts = LoopOptions {
        steps,
        eval_every: (steps / 6).max(1),
        eval_batches: 4,
        log_every: (steps / 15).max(1),
        resample_every: 0,
        quiet: false,
    };
    let curve = run_training(&mut state, &mut gen, &opts, 42)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nloss sparkline: {}", curve.sparkline());
    println!(
        "throughput: {:.1} steps/min, {:.0} tokens/s",
        steps as f64 / wall * 60.0,
        (steps * state.train_exe.meta.config.batch * state.train_exe.meta.config.max_len) as f64
            / wall
    );

    // final evaluation: Test + OOD vs the empirical baseline (Table 2 style)
    let (test_loss, test_acc) = state.evaluate(&mut gen, Split::Test, 8)?;
    let (ood_loss, ood_acc) = state.evaluate(&mut gen, Split::Ood, 8)?;

    let mut rng = Pcg64::new(123);
    let windows: Vec<Vec<u8>> =
        (0..256).map(|_| corpus.window(&corpus.sample_iid(&mut rng).1, 128)).collect();
    let freqs = token_frequencies(&windows);
    let batch = mlm_batch(&windows, 128, MaskPolicy::default(), &mut rng);
    let (base_acc, base_ppl) = empirical_baseline(&batch, &freqs);

    println!("\n== results ==");
    println!("empirical baseline: acc {:.2}%  ppl {:.2}", base_acc * 100.0, base_ppl);
    println!(
        "Performer Test:     acc {:.2}%  ppl {:.2}",
        test_acc * 100.0,
        test_loss.exp()
    );
    println!(
        "Performer OOD:      acc {:.2}%  ppl {:.2}",
        ood_acc * 100.0,
        ood_loss.exp()
    );
    assert!(
        test_acc > base_acc,
        "trained model must beat the empirical baseline"
    );

    std::fs::create_dir_all("results")?;
    std::fs::write("results/train_mlm_curve.csv", curve.to_csv())?;
    state.save_checkpoint(std::path::Path::new("results/train_mlm.ckpt"))?;
    println!("\ncurve -> results/train_mlm_curve.csv, checkpoint -> results/train_mlm.ckpt");
    Ok(())
}
