//! Exporters: Chrome-trace JSON for span traces and Prometheus-style
//! text exposition for the metrics registry.
//!
//! The trace format is the Chrome Trace Event JSON object form —
//! `{"traceEvents": [...]}` with `B`/`E` duration events and one
//! `thread_name` metadata event per thread — loadable directly in
//! `chrome://tracing` or Perfetto. [`validate_chrome_trace`] re-parses
//! an emitted document and checks that every thread's begin/end events
//! balance and nest, which is what the CI trace smoke asserts.

use anyhow::{bail, Result};

use crate::jsonx::{arr, num, obj, s, Json};

use super::registry::{Metric, MetricsRegistry};
use super::trace::{Phase, ThreadTrace};

/// Render drained thread traces as a Chrome Trace Event JSON document.
pub fn chrome_trace(traces: &[ThreadTrace]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for t in traces {
        if t.events.is_empty() && t.dropped == 0 {
            continue;
        }
        let tid = num(t.thread_id as f64);
        // name the thread row (metadata event)
        events.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(1.0)),
            ("tid", tid.clone()),
            (
                "args",
                obj(vec![(
                    "name",
                    s(if t.thread_name.is_empty() { "unnamed" } else { &t.thread_name }),
                )]),
            ),
        ]));
        for e in &t.events {
            let mut fields = vec![
                ("name", s(e.name)),
                ("ph", s(match e.phase {
                    Phase::Begin => "B",
                    Phase::End => "E",
                })),
                ("pid", num(1.0)),
                ("tid", tid.clone()),
                ("ts", num(e.t_us as f64)),
                ("cat", s("performer")),
            ];
            if let Some(a) = e.arg {
                fields.push(("args", obj(vec![("n", num(a as f64))])));
            }
            events.push(obj(fields));
        }
    }
    let dropped: u64 = traces.iter().map(|t| t.dropped).sum();
    obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
        ("droppedEvents", num(dropped as f64)),
    ])
}

/// What [`validate_chrome_trace`] measured.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceSummary {
    /// begin/end event pairs (complete spans)
    pub spans: usize,
    /// distinct thread rows carrying events
    pub threads: usize,
    /// events overwritten by ring overflow before export
    pub dropped: u64,
}

/// Check a Chrome-trace document for balanced, properly nested spans:
/// on every thread each `E` must close the most recent open `B` of the
/// same name, and no span may stay open. Returns the span/thread counts
/// on success; any orphan or crossing is a loud error.
pub fn validate_chrome_trace(doc: &Json) -> Result<TraceSummary> {
    let events = doc.req("traceEvents")?.as_arr()?;
    let mut stacks: std::collections::BTreeMap<u64, Vec<String>> = Default::default();
    let mut spans = 0usize;
    for e in events {
        let ph = e.req("ph")?.as_str()?;
        if ph == "M" {
            continue;
        }
        let tid = e.req("tid")?.as_f64()? as u64;
        let name = e.req("name")?.as_str()?;
        match ph {
            "B" => stacks.entry(tid).or_default().push(name.to_string()),
            "E" => {
                let open = stacks.entry(tid).or_default().pop();
                match open {
                    Some(top) if top == name => spans += 1,
                    Some(top) => bail!("span crossing on tid {tid}: '{name}' ends inside '{top}'"),
                    None => bail!("orphan end event '{name}' on tid {tid}"),
                }
            }
            other => bail!("unexpected event phase '{other}'"),
        }
    }
    for (tid, stack) in &stacks {
        if !stack.is_empty() {
            bail!("unbalanced spans on tid {tid}: {stack:?} never ended");
        }
    }
    Ok(TraceSummary {
        spans,
        threads: stacks.len(),
        dropped: doc.f64_or("droppedEvents", 0.0) as u64,
    })
}

/// Render the registry in Prometheus text exposition format: counters
/// and gauges as single samples, histograms as cumulative `_bucket`
/// series with log2 `le` labels plus `_sum` and `_count`.
pub fn prometheus(reg: &MetricsRegistry) -> String {
    let mut out = String::new();
    for (name, metric) in reg.snapshot() {
        match metric {
            Metric::Counter(c) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
            }
            Metric::Gauge(g) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
            }
            Metric::Histogram(h) => {
                out.push_str(&format!("# TYPE {name} histogram\n"));
                let counts = h.bucket_counts();
                let mut cum = 0u64;
                for (i, c) in counts.iter().enumerate() {
                    cum += c;
                    if *c > 0 || i + 1 == counts.len() {
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"{}\"}} {cum}\n",
                            super::registry::Histogram::bucket_upper_bound(i)
                        ));
                    }
                }
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                out.push_str(&format!("{name}_sum {}\n", h.sum()));
                out.push_str(&format!("{name}_count {}\n", h.count()));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Event;

    fn ev(name: &'static str, phase: Phase, t_us: u64) -> Event {
        Event { name, phase, t_us, arg: None }
    }

    fn thread(id: u64, events: Vec<Event>) -> ThreadTrace {
        ThreadTrace {
            thread_id: id,
            thread_name: format!("t{id}"),
            events,
            dropped: 0,
        }
    }

    #[test]
    fn chrome_trace_roundtrips_and_validates() {
        let traces = vec![
            thread(
                1,
                vec![
                    ev("outer", Phase::Begin, 0),
                    ev("inner", Phase::Begin, 5),
                    ev("inner", Phase::End, 9),
                    ev("outer", Phase::End, 12),
                ],
            ),
            thread(2, vec![ev("write", Phase::Begin, 2), ev("write", Phase::End, 8)]),
        ];
        let doc = chrome_trace(&traces);
        // must be loadable JSON, not just our in-memory tree
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let summary = validate_chrome_trace(&parsed).unwrap();
        assert_eq!((summary.spans, summary.threads, summary.dropped), (3, 2, 0));
    }

    #[test]
    fn validation_rejects_orphans_and_crossings() {
        let orphan = chrome_trace(&[thread(1, vec![ev("a", Phase::End, 1)])]);
        assert!(validate_chrome_trace(&orphan).is_err());
        let open = chrome_trace(&[thread(1, vec![ev("a", Phase::Begin, 1)])]);
        assert!(validate_chrome_trace(&open).is_err());
        let crossed = chrome_trace(&[thread(
            1,
            vec![
                ev("a", Phase::Begin, 1),
                ev("b", Phase::Begin, 2),
                ev("a", Phase::End, 3),
                ev("b", Phase::End, 4),
            ],
        )]);
        assert!(validate_chrome_trace(&crossed).is_err());
    }

    #[test]
    fn prometheus_exposition_shape() {
        let reg = MetricsRegistry::new();
        reg.counter("req_total").add(7);
        reg.gauge("resident_bytes").set(4096);
        let h = reg.histogram("latency_us");
        h.observe(10);
        h.observe(3000);
        let text = prometheus(&reg);
        assert!(text.contains("# TYPE req_total counter\nreq_total 7\n"), "{text}");
        assert!(text.contains("# TYPE resident_bytes gauge\nresident_bytes 4096\n"), "{text}");
        assert!(text.contains("# TYPE latency_us histogram\n"), "{text}");
        assert!(text.contains("latency_us_bucket{le=\"16\"} 1\n"), "{text}");
        assert!(text.contains("latency_us_bucket{le=\"4096\"} 2\n"), "{text}");
        assert!(text.contains("latency_us_bucket{le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("latency_us_sum 3010\n"), "{text}");
        assert!(text.contains("latency_us_count 2\n"), "{text}");
    }
}
