//! The metrics registry: named atomic counters, gauges and log2
//! histograms with handle semantics.
//!
//! Every instrument is a cheap-clone handle (`Arc<AtomicU64>` inside),
//! so the hot path records with one relaxed atomic RMW and never takes
//! a lock; the registry's mutex guards only name→handle resolution at
//! registration time and snapshotting at export time. Histograms use
//! 32 fixed log2 buckets (bucket *i* counts values in `[2^i, 2^{i+1})`),
//! so their memory footprint is a constant 34 words no matter how many
//! samples they absorb — the bound the serving metrics rely on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of log2 buckets in a [`Histogram`] — values ≥ `2^31` land in
/// the last bucket.
pub const HISTOGRAM_BUCKETS: usize = 32;

/// A monotonically increasing counter (lock-free, relaxed ordering).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A fresh unregistered counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable gauge (lock-free, relaxed ordering; last write wins).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// A fresh unregistered gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Add `n` to the value.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Subtract `n`, saturating at zero (a racing over-subtract must
    /// not wrap a byte gauge to 2^64).
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Debug)]
struct HistogramInner {
    /// bucket i counts values in [2^i, 2^{i+1}); values of 0 count as 1
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for HistogramInner {
    fn default() -> Self {
        HistogramInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// A fixed-bucket log2 histogram: lock-free `AtomicU64` buckets, bounded
/// memory (34 words regardless of sample count), quantiles answered from
/// the buckets with at most one bucket width (2×) of overestimation.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    /// A fresh unregistered histogram with empty buckets.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Index of the bucket that counts `value`.
    pub fn bucket_index(value: u64) -> usize {
        let v = value.max(1);
        ((63 - v.leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
    }

    /// Exclusive upper bound of bucket `i` (`2^{i+1}`).
    pub fn bucket_upper_bound(i: usize) -> u64 {
        1u64 << (i + 1).min(63)
    }

    /// Record one value.
    pub fn observe(&self, value: u64) {
        self.0.buckets[Self::bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Record a duration in microseconds (sub-microsecond durations
    /// count as 1µs so they are never invisible).
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_micros().max(1) as u64);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum() as f64 / n as f64
    }

    /// A copy of the per-bucket counts — always exactly
    /// [`HISTOGRAM_BUCKETS`] entries, whatever the sample count.
    pub fn bucket_counts(&self) -> [u64; HISTOGRAM_BUCKETS] {
        std::array::from_fn(|i| self.0.buckets[i].load(Ordering::Relaxed))
    }

    /// Approximate `q`-quantile: the upper bound of the bucket holding
    /// the ⌈q·n⌉-th smallest sample, i.e. an overestimate by less than
    /// one bucket width (strictly above the true sample, at most 2× it).
    /// Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_upper_bound(i);
            }
        }
        Self::bucket_upper_bound(HISTOGRAM_BUCKETS - 1)
    }

    /// [`Self::quantile`] read back as a microsecond duration.
    pub fn quantile_duration(&self, q: f64) -> Duration {
        Duration::from_micros(self.quantile(q))
    }
}

/// One registered instrument, by kind.
#[derive(Clone, Debug)]
pub enum Metric {
    /// a monotone counter
    Counter(Counter),
    /// a settable gauge
    Gauge(Gauge),
    /// a log2 histogram
    Histogram(Histogram),
}

/// A name→instrument registry. `counter`/`gauge`/`histogram` get or
/// create a handle; the same name always resolves to the same
/// underlying atomics, so independent components can share a series.
/// Names are sanitized to Prometheus charset (`[a-zA-Z0-9_:]`, other
/// bytes become `_`) at registration.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

fn sanitize(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_none_or(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get or create the counter `name`. Panics if `name` is already
    /// registered as a different kind — two components disagreeing on a
    /// series' kind is a programming error worth failing loudly on.
    pub fn counter(&self, name: &str) -> Counter {
        let name = sanitize(name);
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m.entry(name.clone()).or_insert_with(|| Metric::Counter(Counter::new())) {
            Metric::Counter(c) => c.clone(),
            other => panic!("metric '{name}' already registered as {other:?}, not a counter"),
        }
    }

    /// Get or create the gauge `name` (same kind-mismatch contract as
    /// [`Self::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        let name = sanitize(name);
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m.entry(name.clone()).or_insert_with(|| Metric::Gauge(Gauge::new())) {
            Metric::Gauge(g) => g.clone(),
            other => panic!("metric '{name}' already registered as {other:?}, not a gauge"),
        }
    }

    /// Get or create the histogram `name` (same kind-mismatch contract
    /// as [`Self::counter`]).
    pub fn histogram(&self, name: &str) -> Histogram {
        let name = sanitize(name);
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m.entry(name.clone()).or_insert_with(|| Metric::Histogram(Histogram::new())) {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric '{name}' already registered as {other:?}, not a histogram"),
        }
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.metrics.lock().expect("metrics registry poisoned").keys().cloned().collect()
    }

    /// Snapshot of every registered instrument (name-sorted handles;
    /// values read through the handles stay live).
    pub fn snapshot(&self) -> Vec<(String, Metric)> {
        self.metrics
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_the_series() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("hits");
        let b = reg.counter("hits");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.names(), vec!["hits".to_string()]);
    }

    #[test]
    fn gauge_sub_saturates() {
        let g = Gauge::new();
        g.set(5);
        g.sub(7);
        assert_eq!(g.get(), 0);
        g.add(4);
        g.sub(1);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn names_are_sanitized() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("stream-pool.native/requests");
        c.inc();
        assert_eq!(reg.names(), vec!["stream_pool_native_requests".to_string()]);
        // the sanitized spelling resolves to the same series
        assert_eq!(reg.counter("stream_pool_native_requests").get(), 1);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.observe(10);
        }
        for _ in 0..10 {
            h.observe(10_000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.sum(), 90 * 10 + 10 * 10_000);
        // 10 ∈ [8,16) → bucket 3, upper bound 16
        assert_eq!(h.quantile(0.5), 16);
        // 10_000 ∈ [8192,16384) → bucket 13, upper bound 16384
        assert_eq!(h.quantile(0.99), 16_384);
        assert!(h.quantile(0.5) < h.quantile(0.99));
    }

    #[test]
    fn histogram_memory_is_constant_in_samples() {
        // the O(1)-memory regression the registry exists for: the
        // footprint is the fixed bucket array however many samples land
        let h = Histogram::new();
        assert_eq!(h.bucket_counts().len(), HISTOGRAM_BUCKETS);
        for i in 0..100_000u64 {
            h.observe(i);
        }
        assert_eq!(h.bucket_counts().len(), HISTOGRAM_BUCKETS);
        assert_eq!(h.count(), 100_000);
        assert_eq!(
            std::mem::size_of::<HistogramInner>(),
            (HISTOGRAM_BUCKETS + 2) * std::mem::size_of::<u64>()
        );
    }

    #[test]
    fn quantile_error_is_at_most_one_bucket_width() {
        // property test: for log-uniform random samples, the histogram
        // quantile strictly exceeds the true order-statistic and is at
        // most one bucket width (2x) above it
        let mut rng = crate::rng::Pcg64::new(0xC0FFEE);
        for round in 0..20 {
            let n = 200 + (round * 37) % 800;
            let h = Histogram::new();
            let mut samples: Vec<u64> = (0..n)
                .map(|_| {
                    let exp = rng.below(24) as u32;
                    1u64 << exp | rng.below(1 << exp.max(1)) as u64
                })
                .collect();
            for &s in &samples {
                h.observe(s);
            }
            samples.sort_unstable();
            for q in [0.5, 0.9, 0.95, 0.99] {
                let k = ((q * n as f64).ceil().max(1.0) as usize).min(n) - 1;
                let truth = samples[k];
                let est = h.quantile(q);
                assert!(
                    truth < est && est <= 2 * truth,
                    "q={q}: true {truth}, estimate {est} (round {round})"
                );
            }
        }
    }

    #[test]
    fn registry_survives_parallel_hammering() {
        // counters exact, histogram totals conserved under 8 threads
        let reg = std::sync::Arc::new(MetricsRegistry::new());
        let threads = 8;
        let per = 10_000u64;
        let mut handles = Vec::new();
        for t in 0..threads {
            let reg = reg.clone();
            handles.push(std::thread::spawn(move || {
                let c = reg.counter("hammer_total");
                let h = reg.histogram("hammer_values");
                for i in 0..per {
                    c.inc();
                    h.observe(t * per + i + 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter("hammer_total").get(), threads * per);
        let h = reg.histogram("hammer_values");
        assert_eq!(h.count(), threads * per);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), threads * per);
    }
}
