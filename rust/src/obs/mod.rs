//! Observability: a dependency-free metrics + tracing substrate for the
//! serving pipeline.
//!
//! Three pieces (see DESIGN.md §Observability):
//!
//! * [`registry`] — named atomic [`Counter`]s/[`Gauge`]s and fixed-bucket
//!   log2 [`Histogram`]s behind a [`MetricsRegistry`]; every instrument
//!   is a cheap-clone lock-free handle with bounded memory, and the
//!   coordinator's `Metrics`/`PersistMetrics` are built on these types.
//! * [`trace`] — span-based tracing: begin/end events in per-thread
//!   fixed-capacity ring buffers, runtime-disabled by default (the off
//!   path is a single relaxed atomic load), instrumenting batcher wait →
//!   wave grouping → per-layer forward → spill enqueue/write → rehydrate.
//! * [`export`] — Chrome-trace JSON (`chrome://tracing`-loadable) and
//!   Prometheus-style text exposition, wired into `performer stream`
//!   (`trace=out.json`, `metrics=out.prom`) and the `xp` reports.

pub mod export;
pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, Metric, MetricsRegistry, HISTOGRAM_BUCKETS};
