//! Span tracing: begin/end events in per-thread fixed-capacity ring
//! buffers, compiled in everywhere and runtime-disabled by default.
//!
//! Protocol:
//!
//! * Instrumented code calls [`span`]/[`span_n`] and holds the returned
//!   guard for the region's lifetime; the guard records a `Begin` event
//!   at creation and the matching `End` on drop, so spans on one thread
//!   always nest and balance by construction.
//! * When tracing is **off** (the default), [`span`] is a single relaxed
//!   atomic load and the guard's drop is a branch on a local bool —
//!   cheap enough to leave compiled into the per-layer forward loop.
//! * When **on**, each event is one `Instant` read plus a push into the
//!   calling thread's ring buffer behind an uncontended per-thread
//!   mutex (contended only while an exporter drains). Buffers hold
//!   [`RING_CAPACITY`] events; overflow overwrites the oldest events
//!   and counts them in `dropped`, so memory stays bounded no matter
//!   how long tracing stays enabled.
//! * A guard created while tracing was on records its `End` even if
//!   tracing was disabled meanwhile — balance is never sacrificed to
//!   the toggle.
//!
//! [`drain`] snapshots and clears every thread's buffer (including
//! threads that have since exited); the Chrome-trace exporter in
//! [`crate::obs::export`] turns the result into a `chrome://tracing`
//! -loadable JSON file.

use std::cell::OnceCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events retained per thread; one event is ~32 bytes, so a thread's
/// buffer tops out around 2 MiB.
pub const RING_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn span recording on or off at runtime.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether spans are currently recorded — the disabled fast path is
/// exactly this one relaxed load.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Begin or end of a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// span opened
    Begin,
    /// span closed
    End,
}

/// One recorded event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// static span name (e.g. `"forward_chunk_batch"`)
    pub name: &'static str,
    /// begin or end
    pub phase: Phase,
    /// microseconds since the process's trace epoch
    pub t_us: u64,
    /// optional numeric argument (batch size, layer index, …)
    pub arg: Option<u64>,
}

/// Everything one thread recorded, as drained by [`drain`].
#[derive(Clone, Debug)]
pub struct ThreadTrace {
    /// stable per-thread id (registration order, starting at 1)
    pub thread_id: u64,
    /// the thread's name at first event (empty if unnamed)
    pub thread_name: String,
    /// events in recording order
    pub events: Vec<Event>,
    /// events overwritten by ring overflow since the last drain
    pub dropped: u64,
}

struct Ring {
    events: VecDeque<Event>,
    dropped: u64,
}

struct ThreadBuf {
    id: u64,
    name: String,
    ring: Mutex<Ring>,
}

static THREADS: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());
static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

thread_local! {
    static LOCAL: OnceCell<Arc<ThreadBuf>> = const { OnceCell::new() };
}

fn local_buf(f: impl FnOnce(&ThreadBuf)) {
    LOCAL.with(|cell| {
        let buf = cell.get_or_init(|| {
            let buf = Arc::new(ThreadBuf {
                id: NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed),
                name: std::thread::current().name().unwrap_or("").to_string(),
                ring: Mutex::new(Ring { events: VecDeque::new(), dropped: 0 }),
            });
            THREADS.lock().expect("trace thread registry poisoned").push(buf.clone());
            buf
        });
        f(buf);
    });
}

fn push(ev: Event) {
    local_buf(|buf| {
        let mut ring = buf.ring.lock().expect("trace ring poisoned");
        if ring.events.len() >= RING_CAPACITY {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(ev);
    });
}

/// The calling thread's trace id (registering it if needed) — lets
/// tests attribute drained events to themselves.
pub fn this_thread_id() -> u64 {
    let mut id = 0;
    local_buf(|buf| id = buf.id);
    id
}

/// RAII span guard: records `Begin` on creation (when tracing is on)
/// and the matching `End` on drop.
#[must_use = "a span measures the region the guard is alive for"]
pub struct Span {
    name: &'static str,
    arg: Option<u64>,
    active: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.active {
            push(Event { name: self.name, phase: Phase::End, t_us: now_us(), arg: self.arg });
        }
    }
}

fn begin(name: &'static str, arg: Option<u64>) -> Span {
    if !enabled() {
        return Span { name, arg, active: false };
    }
    push(Event { name, phase: Phase::Begin, t_us: now_us(), arg });
    Span { name, arg, active: true }
}

/// Open a span named `name` on the calling thread.
pub fn span(name: &'static str) -> Span {
    begin(name, None)
}

/// Open a span carrying a numeric argument (batch size, layer index…).
pub fn span_n(name: &'static str, arg: u64) -> Span {
    begin(name, Some(arg))
}

/// Snapshot and clear every thread's ring buffer. Threads that exited
/// since their last event are included; buffers stay registered, so a
/// later drain picks up whatever was recorded after this one.
pub fn drain() -> Vec<ThreadTrace> {
    let threads = THREADS.lock().expect("trace thread registry poisoned");
    threads
        .iter()
        .map(|buf| {
            let mut ring = buf.ring.lock().expect("trace ring poisoned");
            ThreadTrace {
                thread_id: buf.id,
                thread_name: buf.name.clone(),
                events: ring.events.drain(..).collect(),
                dropped: std::mem::take(&mut ring.dropped),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // tracing is process-global state: serialize the tests that toggle it
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_records_nothing() {
        let _g = LOCK.lock().unwrap();
        set_enabled(false);
        let me = this_thread_id();
        let _ = drain();
        {
            let _s = span("quiet");
        }
        let mine: Vec<Event> = drain()
            .into_iter()
            .filter(|t| t.thread_id == me)
            .flat_map(|t| t.events)
            .collect();
        assert!(mine.is_empty(), "disabled tracing must record nothing: {mine:?}");
    }

    #[test]
    fn spans_nest_and_balance() {
        let _g = LOCK.lock().unwrap();
        let me = this_thread_id();
        let _ = drain();
        set_enabled(true);
        {
            let _outer = span_n("outer", 2);
            let _inner = span("inner");
        }
        set_enabled(false);
        let mine: Vec<Event> = drain()
            .into_iter()
            .filter(|t| t.thread_id == me)
            .flat_map(|t| t.events)
            .collect();
        let shape: Vec<(&str, Phase)> = mine.iter().map(|e| (e.name, e.phase)).collect();
        assert_eq!(
            shape,
            vec![
                ("outer", Phase::Begin),
                ("inner", Phase::Begin),
                ("inner", Phase::End),
                ("outer", Phase::End),
            ]
        );
        assert_eq!(mine[0].arg, Some(2));
        assert!(mine.windows(2).all(|w| w[0].t_us <= w[1].t_us), "timestamps monotone");
    }

    #[test]
    fn end_survives_mid_span_disable() {
        let _g = LOCK.lock().unwrap();
        let me = this_thread_id();
        let _ = drain();
        set_enabled(true);
        let s = span("toggled");
        set_enabled(false);
        drop(s);
        let mine: Vec<Event> = drain()
            .into_iter()
            .filter(|t| t.thread_id == me)
            .flat_map(|t| t.events)
            .collect();
        assert_eq!(mine.len(), 2, "begin must still get its end: {mine:?}");
        assert_eq!((mine[0].phase, mine[1].phase), (Phase::Begin, Phase::End));
    }

    #[test]
    fn ring_overflow_is_bounded_and_counted() {
        let _g = LOCK.lock().unwrap();
        let me = this_thread_id();
        let _ = drain();
        set_enabled(true);
        for _ in 0..(RING_CAPACITY / 2 + 10) {
            let _s = span("tick"); // 2 events each
        }
        set_enabled(false);
        let mine = drain().into_iter().find(|t| t.thread_id == me).unwrap();
        assert_eq!(mine.events.len(), RING_CAPACITY, "buffer must cap at RING_CAPACITY");
        assert_eq!(mine.dropped, 20, "overwritten events are counted");
    }
}
