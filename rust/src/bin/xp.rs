//! `xp` — the experiment harness: one subcommand per table/figure of the
//! paper's evaluation. Each writes an aligned text report to stdout and a
//! CSV under results/, and EXPERIMENTS.md records the measured values.
//!
//!   xp table1   dataset statistics (Appendix C.1 Table 1)
//!   xp fig2     approximation error vs M, iid vs ORF (Fig. 2)
//!   xp fig3     backward compatibility: transplant + finetune (Fig. 3)
//!   xp fig4     protein LM training: 4 attention kinds x (U)/(B) (Fig. 4)
//!   xp fig5     long-context concatenated proteins (Fig. 5)
//!   xp fig6     amino-acid distribution (Appendix C.2 Fig. 6)
//!   xp fig7     attention-matrix patterns of a trained Performer (Figs. 7-9)
//!   xp fig10    amino-acid similarity vs BLOSUM62 (Fig. 10)
//!   xp fig11    approximation-error propagation across layers (Fig. 11)
//!   xp fig12    generalized-attention kernel sweep (Figs. 12/13)
//!   xp table2   accuracy/perplexity on Test + OOD (Appendix C.3 Table 2)
//!   xp thm1     empirical check of the Thm. 1 M = Theta(d log d) scaling
//!   xp stream   streaming-session scaling: per-chunk latency/state vs length,
//!               fused-batch throughput, and spill/rehydrate persistence churn
//!   xp ablation-orf / ablation-resample   design-choice ablations
//!   xp all      everything above in dependency order
//!
//! Heavy knobs scale with XP_STEPS / XP_SEEDS env vars (defaults sized
//! for the single-core budget; see DESIGN.md §Substitutions).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use performer::benchlib::{loglog_slope, Report};
use performer::favor::analysis::AaSimilarity;
use performer::favor::exact::raw_attention_matrix;
use performer::favor::{
    exact_attention, favor_attention, output_error, raw_attention_matrix_favor, AttentionKernel,
    Direction, FeatureKind, FeatureMap, KernelConfig,
};
use performer::linalg::OrfMechanism;
use performer::protein::blosum::{normalized_blosum, offdiag_correlation};
use performer::protein::vocab::{self, AA_BASE, N_STANDARD_AA};
use performer::protein::{
    aa_histogram, empirical_baseline, length_stats, token_frequencies, Corpus, CorpusConfig,
};
use performer::obs::{export, MetricsRegistry};
use performer::rng::Pcg64;
use performer::runtime::{ArtifactMeta, Engine, TensorFile};
use performer::stream::{
    chunked_latency_point, fused_throughput_point, sweep_totals, SessionConfig, SessionManager,
};
use performer::tensor::Mat;
use performer::train::{
    run_training, LoopOptions, NativeAttention, NativeModel, Split, SyntheticConfig, TrainState,
};

fn artifacts_dir() -> PathBuf {
    std::env::var("PERFORMER_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn results_dir() -> PathBuf {
    let p = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&p);
    p
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        bail!("usage: xp <table1|fig2|fig3|fig4|fig5|fig6|fig7|fig10|fig11|fig12|table2|thm1|stream|all>");
    };
    match cmd {
        "table1" => table1(),
        "stream" => stream_scaling(),
        "fig2" => fig2(),
        "fig3" => fig3(),
        "fig4" => fig4(),
        "fig5" => fig5(),
        "fig6" => fig6(),
        "fig7" => fig7(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "table2" => table2(),
        "thm1" => thm1(),
        "ablation-orf" => ablation_orf(),
        "ablation-resample" => ablation_resample(),
        "all" => {
            for f in [
                table1 as fn() -> Result<()>,
                fig6,
                fig2,
                thm1,
                stream_scaling,
                fig11,
                fig12,
                fig4,
                table2,
                fig3,
                fig5,
                fig7,
                fig10,
            ] {
                f()?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// Table 1: dataset statistics
// ---------------------------------------------------------------------------

fn table1() -> Result<()> {
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rep = Report::new(
        "Table 1 — synthetic TrEMBL-surrogate statistics (paper: mean 353, median 289, right-skewed)",
        &["set", "count", "min", "max", "mean", "std", "median"],
    );
    for (name, seed, n) in
        [("Train", 1u64, 8000usize), ("Valid", 2, 1600), ("Test", 3, 1600), ("OOD", 4, 800)]
    {
        let mut rng = Pcg64::new(seed);
        let lens: Vec<usize> = (0..n)
            .map(|_| {
                if name == "OOD" {
                    corpus.sample_ood(&mut rng).1.len()
                } else {
                    corpus.sample_iid(&mut rng).1.len()
                }
            })
            .collect();
        let s = length_stats(&lens);
        rep.row(vec![
            name.into(),
            s.count.to_string(),
            s.min.to_string(),
            s.max.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.std),
            format!("{:.2}", s.median),
        ]);
    }
    // concatenated split: fixed-length by construction (paper: 8192)
    let mut rng = Pcg64::new(5);
    let concat = corpus.concat_stream(1024, 64, &mut rng);
    let lens: Vec<usize> = concat.iter().map(|w| w.len()).collect();
    let s = length_stats(&lens);
    rep.row(vec![
        "Concat".into(),
        s.count.to_string(),
        s.min.to_string(),
        s.max.to_string(),
        format!("{:.2}", s.mean),
        format!("{:.2}", s.std),
        format!("{:.2}", s.median),
    ]);
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("table1.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2: approximation error vs number of features M, iid vs ORF
// ---------------------------------------------------------------------------

fn fig2() -> Result<()> {
    let l = env_usize("XP_FIG2_L", 1024); // paper: 4096 (scaled for 1 core)
    let d = 16; // paper's d
    let seeds = env_usize("XP_SEEDS", 6);
    let mut rng = Pcg64::new(0);
    let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
    let a_exact = raw_attention_matrix(&q, &k, Direction::Bidirectional);
    let out_exact = exact_attention(&q, &k, &v, Direction::Bidirectional);

    let mut rep = Report::new(
        &format!("Fig. 2 — approximation error vs M (L={l}, d={d}; paper: ORF < IID everywhere)"),
        &["M", "mech", "attn_mse", "attn_mse_std", "out_mse", "out_mse_std"],
    );
    for m in [16usize, 32, 64, 128, 256] {
        for (mech, name) in [(OrfMechanism::Iid, "iid"), (OrfMechanism::Regular, "orf")] {
            let mut attn_errs = Vec::new();
            let mut out_errs = Vec::new();
            for s in 0..seeds {
                let fm = FeatureMap::sample(
                    FeatureKind::Softmax,
                    m,
                    d,
                    mech,
                    &mut Pcg64::new(1000 + s as u64),
                );
                let a_hat = raw_attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional);
                attn_errs.push(output_error(&a_hat, &a_exact));
                let out_hat = favor_attention(&fm, &q, &k, &v, Direction::Bidirectional);
                out_errs.push(output_error(&out_hat, &out_exact));
            }
            let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
            let std = |xs: &[f64]| {
                let mu = mean(xs);
                (xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64).sqrt()
            };
            rep.row(vec![
                m.to_string(),
                name.into(),
                format!("{:.3e}", mean(&attn_errs)),
                format!("{:.1e}", std(&attn_errs)),
                format!("{:.3e}", mean(&out_errs)),
                format!("{:.1e}", std(&out_errs)),
            ]);
        }
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig2.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3: backward compatibility — transplant Transformer weights into a
// Performer and fine-tune
// ---------------------------------------------------------------------------

fn fig3() -> Result<()> {
    let steps = env_usize("XP_STEPS", 120);
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));

    // 1. "pretrain" the exact-attention Transformer
    let mut donor = TrainState::new(engine.clone(), "base_exact_bid")?;
    let mut dgen = donor.data_gen(corpus.clone(), 11);
    let opts = LoopOptions {
        steps,
        eval_every: 0,
        eval_batches: 0,
        log_every: 50,
        resample_every: 0,
        quiet: true,
    };
    let donor_curve = run_training(&mut donor, &mut dgen, &opts, 11)?;
    let (_, donor_acc) = donor.evaluate(&mut dgen, Split::Valid, 6)?;

    // 2. transplant into the softmax-approximating Performer
    let mut perf = TrainState::new(engine.clone(), "base_perf_softmax_bid")?;
    let copied = perf.transplant_from(&donor);
    let mut pgen = perf.data_gen(corpus.clone(), 12);
    let (_, zero_shot) = perf.evaluate(&mut pgen, Split::Valid, 6)?;

    // 3. a fresh Performer for comparison (trained from scratch)
    let mut scratch = TrainState::new(engine.clone(), "base_perf_softmax_bid")?;
    let mut sgen = scratch.data_gen(corpus.clone(), 13);
    let scratch_curve = run_training(&mut scratch, &mut sgen, &opts, 13)?;

    // 4. fine-tune the transplanted Performer; it should recover much
    // faster than from-scratch training (the Fig. 3 claim)
    let fine_steps = (steps / 3).max(20);
    let fopts = LoopOptions { steps: fine_steps, ..opts };
    let fine_curve = run_training(&mut perf, &mut pgen, &fopts, 14)?;
    let (_, recovered) = perf.evaluate(&mut pgen, Split::Valid, 6)?;

    let mut rep = Report::new(
        "Fig. 3 — backward compatibility (paper: non-zero zero-shot acc, fast recovery on fine-tune)",
        &["quantity", "value"],
    );
    rep.row(vec!["params transplanted".into(), copied.to_string()]);
    rep.row(vec!["donor Transformer valid acc".into(), format!("{donor_acc:.4}")]);
    rep.row(vec!["Performer zero-shot acc (transplant)".into(), format!("{zero_shot:.4}")]);
    rep.row(vec![
        format!("Performer acc after {fine_steps} fine-tune steps"),
        format!("{recovered:.4}"),
    ]);
    rep.row(vec![
        format!("from-scratch Performer acc after {steps} steps"),
        format!("{:.4}", scratch_curve.smoothed_train_acc(10)),
    ]);
    rep.row(vec![
        "donor final train acc".into(),
        format!("{:.4}", donor_curve.smoothed_train_acc(10)),
    ]);
    rep.row(vec![
        format!("fine-tune curve (first {} pts)", fine_curve.train.len().min(8)),
        fine_curve
            .train
            .iter()
            .take(8)
            .map(|p| format!("{:.3}", p.acc))
            .collect::<Vec<_>>()
            .join(" "),
    ]);
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig3.csv"))?;
    std::fs::write(results_dir().join("fig3_finetune_curve.csv"), fine_curve.to_csv())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4: the protein-LM bakeoff — Transformer / Performer-ReLU /
// Performer-Softmax / Reformer(LSH) in (U) and (B) modes
// ---------------------------------------------------------------------------

fn fig4() -> Result<()> {
    let steps = env_usize("XP_STEPS", 120);
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut rep = Report::new(
        "Fig. 4 — TrEMBL-surrogate training (paper ordering: Performer-ReLU ≥ Transformer ≈ Performer-softmax > Reformer)",
        &["model", "dir", "train_acc", "valid_acc", "valid_loss", "steps"],
    );
    let mut curves = BTreeMap::new();
    for dir_tag in ["bid", "uni"] {
        for model in ["exact", "perf_relu", "perf_softmax", "lsh"] {
            let tag = format!("base_{model}_{dir_tag}");
            let mut st = TrainState::new(engine.clone(), &tag)?;
            let mut gen = st.data_gen(corpus.clone(), 21);
            let opts = LoopOptions {
                steps,
                eval_every: (steps / 4).max(1),
                eval_batches: 4,
                log_every: steps,
                resample_every: 0,
                quiet: true,
            };
            let curve = run_training(&mut st, &mut gen, &opts, 21)?;
            let (vl, va) = st.evaluate(&mut gen, Split::Valid, 6)?;
            eprintln!("[fig4] {tag}: train {:.3} valid {:.3}", curve.smoothed_train_acc(10), va);
            rep.row(vec![
                model.into(),
                dir_tag.to_uppercase(),
                format!("{:.4}", curve.smoothed_train_acc(10)),
                format!("{va:.4}"),
                format!("{vl:.4}"),
                steps.to_string(),
            ]);
            // persist checkpoints for table2 / fig7 / fig10
            st.save_checkpoint(&results_dir().join(format!("{tag}.ckpt")))?;
            curves.insert(tag, curve);
        }
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig4.csv"))?;
    for (tag, curve) in curves {
        std::fs::write(results_dir().join(format!("fig4_{tag}.csv")), curve.to_csv())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5: long-context concatenated proteins — Performer at full size vs
// memory-bounded small Transformers
// ---------------------------------------------------------------------------

fn fig5() -> Result<()> {
    let steps = env_usize("XP_FIG5_STEPS", 40);
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut rep = Report::new(
        "Fig. 5 — concatenated long-context training (paper: small Transformer plateaus, Performer keeps climbing)",
        &["model", "L", "params", "train_acc", "mem_attn_bytes"],
    );
    for tag in ["long_perf_relu_uni", "long_exact_l1_uni", "long_exact_l2_uni"] {
        let mut st = TrainState::new(engine.clone(), tag)?;
        let cfg = st.train_exe.meta.config.clone();
        let mut gen = st.data_gen(corpus.clone(), 31);
        let opts = LoopOptions {
            steps,
            eval_every: 0,
            eval_batches: 0,
            log_every: steps,
            resample_every: 0,
            quiet: true,
        };
        let curve = run_training(&mut st, &mut gen, &opts, 31)?;
        // attention memory accounting (per head, fwd): exact stores LxL,
        // FAVOR stores L x M features + M x (d+1) state
        let l = cfg.max_len;
        let dh = cfg.d_model / cfg.n_heads.max(1);
        let mem = if cfg.attention == "exact" {
            4 * l * l
        } else {
            4 * (l * cfg.n_features + cfg.n_features * (dh + 1))
        };
        eprintln!("[fig5] {tag}: train acc {:.3}", curve.smoothed_train_acc(8));
        rep.row(vec![
            tag.into(),
            l.to_string(),
            cfg.param_count.to_string(),
            format!("{:.4}", curve.smoothed_train_acc(8)),
            mem.to_string(),
        ]);
        std::fs::write(results_dir().join(format!("fig5_{tag}.csv")), curve.to_csv())?;
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig5.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6: amino-acid distribution
// ---------------------------------------------------------------------------

fn fig6() -> Result<()> {
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(6);
    let windows: Vec<Vec<u8>> =
        (0..2000).map(|_| corpus.window(&corpus.sample_iid(&mut rng).1, 256)).collect();
    let freqs = token_frequencies(&windows);
    let hist = aa_histogram(&freqs);
    println!("== Fig. 6 — amino-acid distribution (train sample; compare TrEMBL empirical) ==");
    print!("{}", performer::protein::stats::render_histogram(&hist));

    let mut rep = Report::new("Fig. 6 data", &["aa", "class", "fraction", "trembl_pct"]);
    for (letter, class, frac) in &hist {
        let trembl = vocab::TREMBL_FREQ
            .iter()
            .find(|(c, _)| c == letter)
            .map(|(_, p)| *p)
            .unwrap_or(0.0);
        rep.row(vec![
            letter.to_string(),
            class.to_string(),
            format!("{:.4}", frac),
            format!("{trembl:.2}"),
        ]);
    }
    rep.save_csv(&results_dir().join("fig6.csv"))?;
    // correlation with the true TrEMBL distribution should be ~1
    let xs: Vec<f64> = hist.iter().map(|(_, _, f)| *f).collect();
    let ys: Vec<f64> = hist
        .iter()
        .map(|(c, _, _)| {
            vocab::TREMBL_FREQ.iter().find(|(t, _)| t == c).map(|(_, p)| *p).unwrap_or(0.0)
        })
        .collect();
    let n = xs.len() as f64;
    let (mx, my) = (xs.iter().sum::<f64>() / n, ys.iter().sum::<f64>() / n);
    let cov: f64 = xs.iter().zip(&ys).map(|(a, b)| (a - mx) * (b - my)).sum();
    let vx: f64 = xs.iter().map(|a| (a - mx) * (a - mx)).sum();
    let vy: f64 = ys.iter().map(|b| (b - my) * (b - my)).sum();
    println!("corr(sampled, TrEMBL empirical) = {:.4}\n", cov / (vx * vy).sqrt());
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 7-9: attention-pattern visualization of a trained Performer
// ---------------------------------------------------------------------------

/// BPT1_BOVIN (aprotinin) — the paper's example protein (UniProt P00974).
const BPT1_BOVIN: &str =
    "MKMSRLCLSVALLVLLGTLAASTPGCDTSNQAKAQRPDFCLEPPYTGPCKARIIRYFYNAKAGLCQTFVYGGCRAKRNNFKSAEDCMRTCGGA";

fn load_trained_native(tag: &str) -> Result<NativeModel> {
    let fwd_meta = ArtifactMeta::load(&artifacts_dir(), &format!("{tag}_fwd"))?;
    let init = TensorFile::read(&artifacts_dir().join(format!("{tag}_init.bin")))?;
    let ckpt_path = results_dir().join(format!("{tag}.ckpt"));
    let ckpt = if ckpt_path.exists() { Some(TensorFile::read(&ckpt_path)?) } else { None };
    if ckpt.is_none() {
        eprintln!(
            "[fig7/10] no checkpoint at {} — run `xp fig4` first; using init weights",
            ckpt_path.display()
        );
    }
    let lookup = move |name: &str| -> Option<Vec<f32>> {
        for prefix in ["param", "feature"] {
            let key = format!("{prefix}:{name}");
            if let Some(tf) = &ckpt {
                if let Some((_, d)) = tf.get(&key) {
                    return Some(d.to_vec());
                }
            }
            if let Some((_, d)) = init.get(&key) {
                return Some(d.to_vec());
            }
        }
        None
    };
    NativeModel::from_weights(&fwd_meta, &lookup)
}

fn ascii_heatmap(m: &Mat, size: usize) -> String {
    const SHADES: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let step = (m.rows as f64 / size as f64).max(1.0);
    let mut out = String::new();
    let mx = m.data.iter().cloned().fold(0.0f32, f32::max).max(1e-9);
    for i in 0..size.min(m.rows) {
        for j in 0..size.min(m.cols) {
            let r = ((i as f64 * step) as usize).min(m.rows - 1);
            let c = ((j as f64 * step) as usize).min(m.cols - 1);
            let v = (m.at(r, c) / mx).clamp(0.0, 1.0);
            out.push(SHADES[(v * 9.0).round() as usize]);
        }
        out.push('\n');
    }
    out
}

fn fig7() -> Result<()> {
    let model = load_trained_native("base_perf_relu_bid")?;
    let tokens: Vec<u8> = vocab::encode(BPT1_BOVIN);
    let (_, maps) = model.forward(&tokens, true);

    println!("== Figs. 7-9 — attention patterns on BPT1_BOVIN ({} residues) ==", tokens.len());
    let mut diag_heads = 0;
    let mut vert_heads = 0;
    for (li, layer) in maps.iter().enumerate() {
        for (hi, m) in layer.iter().enumerate() {
            // diagonality: mass within |i-j| <= 2 vs total
            let mut near = 0.0f64;
            let mut total = 0.0f64;
            let mut col_mass = vec![0.0f64; m.cols];
            for i in 0..m.rows {
                for j in 0..m.cols {
                    let v = m.at(i, j) as f64;
                    total += v;
                    if i.abs_diff(j) <= 2 {
                        near += v;
                    }
                    col_mass[j] += v;
                }
            }
            let diag_score = near / total.max(1e-12);
            let max_col = col_mass.iter().cloned().fold(0.0, f64::max) / m.rows as f64;
            let kind = if diag_score > 0.3 {
                diag_heads += 1;
                "diagonal"
            } else if max_col > 0.25 {
                vert_heads += 1;
                "vertical"
            } else {
                "mixed"
            };
            println!("layer {li} head {hi}: diag {diag_score:.2}, max-col {max_col:.2} -> {kind}");
            if li == 0 && hi == 0 {
                println!("{}", ascii_heatmap(m, 32));
            }
        }
    }
    println!(
        "summary: {diag_heads} diagonal-ish heads, {vert_heads} vertical-ish heads \
         (paper reports both patterns present)\n"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10: amino-acid similarity matrix vs BLOSUM62
// ---------------------------------------------------------------------------

fn fig10() -> Result<()> {
    let model = load_trained_native("base_perf_relu_bid")?;
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(10);
    let n_seqs = env_usize("XP_FIG10_SEQS", 60);

    let mut sim = AaSimilarity::new(N_STANDARD_AA);
    let mut used = 0;
    while used < n_seqs {
        let (_, seq) = corpus.sample_iid(&mut rng);
        let take: Vec<u8> = seq.into_iter().take(96).collect();
        let ids: Vec<usize> = take.iter().map(|&t| (t - AA_BASE) as usize).collect();
        // skip sequences containing anomalous AAs (outside the 20)
        if ids.iter().any(|&i| i >= N_STANDARD_AA) {
            continue;
        }
        let (_, maps) = model.forward(&take, true);
        for layer in &maps {
            for m in layer {
                sim.accumulate(m, &ids);
            }
        }
        used += 1;
    }
    let s = sim.finish();
    let blosum = normalized_blosum();
    let corr = offdiag_correlation(&s, &blosum);

    // the paper highlights (D,E) and (F,Y) as recovered-similar pairs
    let t = |c| (vocab::aa_token(c).unwrap() - AA_BASE) as usize;
    let mut rep = Report::new(
        "Fig. 10 — attention-derived AA similarity vs normalized BLOSUM62",
        &["quantity", "value"],
    );
    rep.row(vec!["corr(attention-sim, BLOSUM62) offdiag".into(), format!("{corr:.4}")]);
    for (a, b) in [('D', 'E'), ('F', 'Y'), ('D', 'W')] {
        rep.row(vec![format!("sim({a},{b})"), format!("{:.5}", s.at(t(a), t(b)))]);
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig10.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 11: approximation-error propagation across layers
// ---------------------------------------------------------------------------

fn fig11() -> Result<()> {
    // exact-attention weights, replayed with FAVOR attention of growing
    // depth: the error compounds with layers (the paper's argument for
    // why zero-shot transplant degrades and fine-tuning is needed)
    let meta = ArtifactMeta::load(&artifacts_dir(), "base_exact_bid_fwd")?;
    let corpus = Corpus::generate(CorpusConfig::default());
    let mut rng = Pcg64::new(11);
    let (_, seq) = corpus.sample_iid(&mut rng);
    let tokens: Vec<u8> = corpus.window(&seq, 96);
    let d_head = meta.config.d_model / meta.config.n_heads;

    let make_lookup = || -> Result<Box<dyn Fn(&str) -> Option<Vec<f32>>>> {
        let init = TensorFile::read(&artifacts_dir().join("base_exact_bid_init.bin"))?;
        Ok(Box::new(move |name: &str| {
            init.get(&format!("param:{name}")).map(|(_, d)| d.to_vec())
        }))
    };

    let mut rep = Report::new(
        "Fig. 11 — output MSE between exact Transformer and Performer-ized copy vs depth",
        &["layers", "M=32", "M=128", "M=512"],
    );
    for depth in 1..=meta.config.n_layers {
        let mut row = vec![depth.to_string()];
        for m in [32usize, 128, 512] {
            let mut meta_trunc = meta.clone();
            meta_trunc.config.n_layers = depth;
            let exact_t = NativeModel::from_weights(&meta_trunc, &make_lookup()?)?;
            let fm = FeatureMap::sample(
                FeatureKind::Softmax,
                m,
                d_head,
                OrfMechanism::Regular,
                &mut Pcg64::new(777),
            );
            let kernel = AttentionKernel::from_feature_map(
                fm,
                KernelConfig {
                    kind: FeatureKind::Softmax,
                    m,
                    mech: OrfMechanism::Regular,
                    seed: 777,
                    redraw_every: 0,
                },
            );
            let favor_t = NativeModel::from_weights(&meta_trunc, &make_lookup()?)?
                .with_attention(NativeAttention::favor_uniform(kernel, depth));
            let out_exact = exact_t.forward(&tokens, false).0;
            let out_favor = favor_t.forward(&tokens, false).0;
            row.push(format!("{:.4e}", output_error(&out_favor, &out_exact)));
        }
        rep.row(row);
    }
    println!("{}", rep.render());
    println!("(error grows with depth at fixed M and shrinks with M at fixed depth — Fig. 11's two trends)\n");
    rep.save_csv(&results_dir().join("fig11.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Figs. 12/13: generalized-attention kernel sweep
// ---------------------------------------------------------------------------

fn fig12() -> Result<()> {
    let steps = env_usize("XP_STEPS", 120).min(150);
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut rep = Report::new(
        "Figs. 12/13 — GA kernel sweep (paper: ReLU best; exp/identity unstable)",
        &["kernel", "final_train_acc", "valid_acc", "status", "steps_done"],
    );
    for f_name in ["sigmoid", "exp", "relu", "abs", "gelu", "cos", "tanh", "identity"] {
        let tag = format!("ga_{f_name}_bid");
        let mut st = TrainState::new(engine.clone(), &tag)?;
        let mut gen = st.data_gen(corpus.clone(), 41);
        let opts = LoopOptions {
            steps,
            eval_every: 0,
            eval_batches: 0,
            log_every: steps * 2,
            resample_every: 0,
            quiet: true,
        };
        // exp/identity may legitimately NaN (the paper shows those runs
        // dying early); capture that instead of failing the sweep
        match run_training(&mut st, &mut gen, &opts, 41) {
            Ok(curve) => {
                let (_, va) =
                    st.evaluate(&mut gen, Split::Valid, 4).unwrap_or((f64::NAN, f64::NAN));
                eprintln!("[fig12] {f_name}: acc {:.3}", curve.smoothed_train_acc(10));
                rep.row(vec![
                    f_name.into(),
                    format!("{:.4}", curve.smoothed_train_acc(10)),
                    format!("{va:.4}"),
                    "ok".into(),
                    curve.train.len().to_string(),
                ]);
            }
            Err(e) => {
                eprintln!("[fig12] {f_name}: diverged ({e})");
                rep.row(vec![
                    f_name.into(),
                    "nan".into(),
                    "nan".into(),
                    "diverged".into(),
                    "-".into(),
                ]);
            }
        }
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("fig12.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2: accuracy + perplexity on Test and OOD
// ---------------------------------------------------------------------------

fn table2() -> Result<()> {
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let eval_batches = env_usize("XP_EVAL_BATCHES", 8);
    let mut rep = Report::new(
        "Table 2 — single-sequence protein LM (paper: Performer-ReLU best on Test; empirical baseline ~9.9%/17.8)",
        &["dir", "set", "model", "accuracy_%", "perplexity"],
    );

    // empirical baseline from training-set frequencies (Appendix C.2)
    let mut rng = Pcg64::new(50);
    let windows: Vec<Vec<u8>> =
        (0..512).map(|_| corpus.window(&corpus.sample_iid(&mut rng).1, 128)).collect();
    let freqs = token_frequencies(&windows);
    for (set, seed) in [("Test", 51u64), ("OOD", 52)] {
        let mut brng = Pcg64::new(seed);
        let batch_windows: Vec<Vec<u8>> = (0..256)
            .map(|_| {
                let s = if set == "OOD" {
                    corpus.sample_ood(&mut brng).1
                } else {
                    corpus.sample_iid(&mut brng).1
                };
                corpus.window(&s, 128)
            })
            .collect();
        let batch = performer::protein::mlm_batch(
            &batch_windows,
            128,
            performer::protein::MaskPolicy::default(),
            &mut brng,
        );
        let (acc, ppl) = empirical_baseline(&batch, &freqs);
        rep.row(vec![
            "UNI/BID".into(),
            set.into(),
            "Empirical Baseline".into(),
            format!("{:.2}", acc * 100.0),
            format!("{ppl:.2}"),
        ]);
    }

    // trained models from the fig4 checkpoints
    for dir_tag in ["uni", "bid"] {
        for model in ["exact", "perf_relu", "perf_softmax", "lsh"] {
            let tag = format!("base_{model}_{dir_tag}");
            let ckpt = results_dir().join(format!("{tag}.ckpt"));
            if !ckpt.exists() {
                eprintln!("[table2] missing {} — run `xp fig4` first", ckpt.display());
                continue;
            }
            let mut st = TrainState::new(engine.clone(), &tag)?;
            st.load_checkpoint(&ckpt)?;
            let mut gen = st.data_gen(corpus.clone(), 55);
            for (set, split) in [("Test", Split::Test), ("OOD", Split::Ood)] {
                let (loss, acc) = st.evaluate(&mut gen, split, eval_batches)?;
                rep.row(vec![
                    dir_tag.to_uppercase(),
                    set.into(),
                    model.into(),
                    format!("{:.2}", acc * 100.0),
                    format!("{:.2}", loss.exp()),
                ]);
            }
        }
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("table2.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablation: ORF mechanism choice (Sec. 2.4's R-ORF vs H-ORF vs G-ORF)
// ---------------------------------------------------------------------------

fn ablation_orf() -> Result<()> {
    let seeds = env_usize("XP_SEEDS", 8);
    let (l, d, m) = (512usize, 8usize, 64usize);
    let mut rng = Pcg64::new(0);
    let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
    let a = raw_attention_matrix(&q, &k, Direction::Bidirectional);

    let mut rep = Report::new(
        &format!("Ablation — ORF mechanism, attention-matrix MSE (L={l}, d={d}, M={m})"),
        &["mechanism", "mse_mean", "mse_std", "sample_cost"],
    );
    for (mech, name, cost) in [
        (OrfMechanism::Iid, "iid", "O(Md)"),
        (OrfMechanism::Regular, "r-orf", "O(Md^2) Gram-Schmidt"),
        (OrfMechanism::Hadamard, "h-orf", "O(M log d) FWHT"),
        (OrfMechanism::Givens, "g-orf", "O(Md log d) rotations"),
    ] {
        let mut errs = Vec::new();
        for s in 0..seeds {
            let fm = FeatureMap::sample(
                FeatureKind::Softmax, m, d, mech, &mut Pcg64::new(3000 + s as u64));
            errs.push(output_error(
                &raw_attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional), &a));
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        let std = (errs.iter().map(|e| (e - mean) * (e - mean)).sum::<f64>()
            / errs.len() as f64)
            .sqrt();
        rep.row(vec![name.into(), format!("{mean:.4e}"), format!("{std:.1e}"), cost.into()]);
    }
    println!("{}", rep.render());
    println!("(paper Sec. 2.4/2.6: all ORF variants beat iid; H/G-ORF trade a small bias for cheaper sampling)\n");
    rep.save_csv(&results_dir().join("ablation_orf.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Ablation: periodic feature resampling (Sec. 4.2's redrawing strategy)
// ---------------------------------------------------------------------------

fn ablation_resample() -> Result<()> {
    let steps = env_usize("XP_STEPS", 120);
    let engine = Arc::new(Engine::new(artifacts_dir())?);
    let corpus = Arc::new(Corpus::generate(CorpusConfig::default()));
    let mut rep = Report::new(
        "Ablation — FAVOR feature resampling during training (Performer-softmax)",
        &["resample_every", "final_train_acc", "valid_acc"],
    );
    for resample_every in [0usize, 50, 25] {
        let mut st = TrainState::new(engine.clone(), "base_perf_softmax_bid")?;
        let mut gen = st.data_gen(corpus.clone(), 61);
        let opts = LoopOptions {
            steps,
            eval_every: 0,
            eval_batches: 0,
            log_every: steps * 2,
            resample_every,
            quiet: true,
        };
        let curve = run_training(&mut st, &mut gen, &opts, 61)?;
        let (_, va) = st.evaluate(&mut gen, Split::Valid, 6)?;
        eprintln!("[ablation-resample] every={resample_every}: acc {:.3}", va);
        rep.row(vec![
            resample_every.to_string(),
            format!("{:.4}", curve.smoothed_train_acc(10)),
            format!("{va:.4}"),
        ]);
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("ablation_resample.csv"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Streaming sessions: per-chunk latency and resident state must be flat
// in the total streamed length (the stream subsystem's core claim)
// ---------------------------------------------------------------------------

fn stream_scaling() -> Result<()> {
    let chunk = env_usize("XP_STREAM_CHUNK", 256);
    let max_total = env_usize("XP_STREAM_TOTAL", 65536).max(chunk);
    let mut rng = Pcg64::new(0);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());

    // flatness must hold for every streaming kernel, trig GA and FAVOR+
    // alike — the kernel column keeps the claim per-kernel
    let mut rep = Report::new(
        "Streaming sessions — per-chunk latency & resident state vs total length (expect flat)",
        &["kernel", "total_tokens", "chunks", "first_ms", "last_ms", "last/first", "state_bytes"],
    );
    for kind in [FeatureKind::Relu, FeatureKind::Positive] {
        let kmodel = if kind == FeatureKind::Relu {
            model.clone()
        } else {
            Arc::new(NativeModel::synthetic(
                &SyntheticConfig { kind, ..Default::default() },
                &mut Pcg64::new(0),
            ))
        };
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for total in sweep_totals(4096, 4, max_total) {
            let p = chunked_latency_point(&kmodel, &corpus, chunk, total, &mut rng)?;
            xs.push(total as f64);
            ys.push(p.last_secs);
            rep.row(vec![
                kind.name().to_string(),
                total.to_string(),
                p.n_chunks.to_string(),
                format!("{:.3}", p.first_secs * 1e3),
                format!("{:.3}", p.last_secs * 1e3),
                format!("{:.2}", p.flatness_ratio()),
                p.state_bytes.to_string(),
            ]);
        }
        let slope = if xs.len() > 1 { loglog_slope(&xs, &ys) } else { 0.0 };
        println!(
            "[{}] per-chunk latency scaling exponent vs total length: {slope:.3} \
             (0 = flat; exact attention would be ~1)",
            kind.name()
        );
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("stream_scaling.csv"))?;

    // batched execution core: B concurrent sessions, sequential advance
    // vs one fused forward_chunk_batch per round
    let max_b = env_usize("XP_STREAM_SESSIONS", 8);
    let n_chunks = env_usize("XP_STREAM_FUSED_CHUNKS", 8);
    let mut rep = Report::new(
        &format!(
            "Fused multi-session advance — aggregate throughput, sequential vs batched \
             (chunk={chunk}, {n_chunks} chunks/session, {} threads)",
            performer::tensor::matmul_threads()
        ),
        &["sessions", "seq_tok_per_s", "fused_tok_per_s", "speedup", "max_diff"],
    );
    let mut b = 1;
    while b <= max_b {
        let p = fused_throughput_point(&model, &corpus, b, chunk, n_chunks, &mut rng)?;
        rep.row(vec![
            b.to_string(),
            format!("{:.0}", p.seq_tokens_per_sec()),
            format!("{:.0}", p.fused_tokens_per_sec()),
            format!("{:.2}x", p.speedup()),
            format!("{:.2e}", p.max_diff),
        ]);
        b *= 2;
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("stream_batched.csv"))?;

    stream_persist()?;
    Ok(())
}

/// Durable session persistence: force spill/rehydrate churn under a
/// two-session byte budget (spill writes now run on the background
/// writer — the table shows the serving-thread enqueue cost next to the
/// writer-thread commit cost), then a full checkpoint_all → restore_from
/// migration, verifying scores stay *bitwise* identical to an unevicted
/// reference manager throughout. A redraw-scheduled row exercises the
/// epoch-crossing/state-reset churn gauges, and a second table compares
/// delta vs full `checkpoint_all` exports.
fn stream_persist() -> Result<()> {
    let chunk = env_usize("XP_PERSIST_CHUNK", 128);
    let rounds = env_usize("XP_PERSIST_ROUNDS", 4);
    let mut rng = Pcg64::new(7);
    let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
    let corpus = Corpus::generate(CorpusConfig::default());
    let per = SessionManager::new(model.clone(), SessionConfig::default())?.per_session_bytes();

    // one bounded histogram collects every budgeted advance across the
    // whole sweep; the registry is dumped as Prometheus text next to
    // the CSVs so the run is inspectable without re-running
    let reg = MetricsRegistry::new();
    let advance_us = reg.histogram("xp_persist_advance_us");

    let mut rep = Report::new(
        &format!(
            "Durable session persistence — async spill churn under a 2-session \
             budget + full migration ({rounds} rounds x {chunk}-token chunks; \
             scores must stay bitwise identical; redraw>0 rows also count \
             epoch crossings / state resets)"
        ),
        &[
            "sessions", "redraw", "spills", "commits", "rehydr", "enq_us", "write_us",
            "epoch_x", "resets", "restore_ms", "bitwise",
        ],
    );
    // (session count, redraw_every): the last row streams through a live
    // redraw schedule so the churn gauges are exercised end to end
    for &(k, redraw) in &[(2usize, 0u64), (4, 0), (8, 0), (4, 96)] {
        let kmodel = if redraw == 0 {
            model.clone()
        } else {
            Arc::new(NativeModel::synthetic(
                &SyntheticConfig { redraw_every: redraw, ..Default::default() },
                &mut Pcg64::new(7),
            ))
        };
        let dir = std::env::temp_dir()
            .join(format!("xp_persist_{k}_{redraw}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SessionConfig {
            max_state_bytes: 2 * per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(kmodel.clone(), cfg)?;
        let mut reference = SessionManager::new(kmodel.clone(), SessionConfig::default())?;
        let mut bitwise = true;
        for _ in 0..rounds {
            for s in 0..k {
                let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
                let id = format!("u{s}");
                let t_adv = std::time::Instant::now();
                let a = mgr.advance(&id, &toks)?;
                advance_us.observe_duration(t_adv.elapsed());
                let b = reference.advance(&id, &toks)?;
                bitwise &= a.logprob.len() == b.logprob.len()
                    && a
                        .logprob
                        .iter()
                        .zip(&b.logprob)
                        .all(|(x, y)| x.to_bits() == y.to_bits());
            }
        }
        // settle the write-back queue so commit counters are exact
        mgr.sync_spills()?;
        // migration: export every session (resident + spilled), adopt
        // into a fresh replica, and time the adoption
        let export = dir.join("export");
        let written = mgr.checkpoint_all(&export)?;
        let t0 = std::time::Instant::now();
        let mut replica = SessionManager::new(kmodel, SessionConfig::default())?;
        let adopted = replica.restore_from(&export)?;
        let restore_ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(
            written == k && adopted == k,
            "migration must carry all {k} sessions (wrote {written}, adopted {adopted})"
        );
        let st = mgr.stats();
        if redraw > 0 {
            anyhow::ensure!(
                st.epoch_crossings > 0 && st.state_resets > 0,
                "a live redraw schedule must register churn"
            );
        }
        rep.row(vec![
            k.to_string(),
            redraw.to_string(),
            st.spills.to_string(),
            st.spill_commits.to_string(),
            st.rehydrations.to_string(),
            format!("{:.0}", st.spill_enqueue_nanos as f64 / 1e3 / st.spills.max(1) as f64),
            format!(
                "{:.0}",
                st.spill_write_nanos as f64 / 1e3 / st.spill_commits.max(1) as f64
            ),
            st.epoch_crossings.to_string(),
            st.state_resets.to_string(),
            format!("{restore_ms:.2}"),
            if bitwise { "yes".into() } else { "NO".into() },
        ]);
        anyhow::ensure!(bitwise, "spill/rehydrate changed scores for K={k}");
        let _ = std::fs::remove_dir_all(&dir);
    }
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("stream_persist.csv"))?;
    println!(
        "[obs] budgeted advance latency over {} calls: p50 {}us p95 {}us p99 {}us \
         (log2 buckets; quantiles are bucket upper bounds)",
        advance_us.count(),
        advance_us.quantile(0.50),
        advance_us.quantile(0.95),
        advance_us.quantile(0.99),
    );
    let prom = results_dir().join("stream_persist.prom");
    std::fs::write(&prom, export::prometheus(&reg))?;
    println!("[obs] Prometheus dump written to {}", prom.display());

    // ---- delta vs full checkpoint_all: k dirty of N sessions ----
    let n = env_usize("XP_PERSIST_SESSIONS", 8);
    let dirty = (n / 4).max(1);
    let dir = std::env::temp_dir().join(format!("xp_persist_delta_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut mgr = SessionManager::new(model, SessionConfig::default())?;
    for s in 0..n {
        let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
        mgr.advance(&format!("u{s}"), &toks)?;
    }
    let t0 = std::time::Instant::now();
    mgr.checkpoint_all(&dir)?;
    let full_ms = t0.elapsed().as_secs_f64() * 1e3;
    for s in 0..dirty {
        let toks = corpus.concat_stream(chunk, 1, &mut rng).pop().unwrap();
        mgr.advance(&format!("u{s}"), &toks)?;
    }
    let t1 = std::time::Instant::now();
    let d = mgr.checkpoint_delta(&dir)?;
    let delta_ms = t1.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(
        d.written == dirty && d.retained == n - dirty,
        "delta must write exactly the {dirty} dirty session(s) (wrote {}, kept {})",
        d.written,
        d.retained
    );
    let mut rep = Report::new(
        "Incremental checkpoint_all — delta re-snapshots only dirty sessions",
        &["sessions", "dirty", "full_ms", "delta_ms", "delta_written", "delta_retained"],
    );
    rep.row(vec![
        n.to_string(),
        dirty.to_string(),
        format!("{full_ms:.2}"),
        format!("{delta_ms:.2}"),
        d.written.to_string(),
        d.retained.to_string(),
    ]);
    println!("{}", rep.render());
    rep.save_csv(&results_dir().join("stream_persist_delta.csv"))?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

// ---------------------------------------------------------------------------
// Thm. 1: empirical M_opt = Theta(d log d) check
// ---------------------------------------------------------------------------

fn thm1() -> Result<()> {
    let seeds = env_usize("XP_SEEDS", 6);
    let l = 256;
    let target_err = 0.15; // relative L1 error target on the attention matrix
    let mut rep = Report::new(
        "Thm. 1 — features needed for fixed error vs d (expect M* ~ d log d, error ~ 1/sqrt(M))",
        &["d", "M*_measured", "d*log2(d)", "ratio", "slope_log_err_vs_log_M"],
    );
    for d in [4usize, 8, 16, 32] {
        let mut rng = Pcg64::new(d as u64);
        let q = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
        let k = Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect());
        let a = raw_attention_matrix(&q, &k, Direction::Bidirectional);
        let a_norm: f64 =
            a.data.iter().map(|&v| v.abs() as f64).sum::<f64>() / a.data.len() as f64;

        let err_at = |m: usize| -> f64 {
            let mut e = 0.0;
            for s in 0..seeds {
                let fm = FeatureMap::sample(
                    FeatureKind::Softmax,
                    m,
                    d,
                    OrfMechanism::Regular,
                    &mut Pcg64::new(9000 + s as u64),
                );
                let a_hat = raw_attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional);
                e += a_hat.mean_abs_diff(&a) / a_norm;
            }
            e / seeds as f64
        };
        // find smallest power-of-two M with error < target
        let mut m_star = 0;
        let ms = [4usize, 8, 16, 32, 64, 128, 256, 512, 1024];
        let mut errs = Vec::new();
        for &m in &ms {
            let e = err_at(m);
            errs.push(e);
            if e < target_err && m_star == 0 {
                m_star = m;
            }
        }
        let slope = loglog_slope(&ms.iter().map(|&m| m as f64).collect::<Vec<_>>(), &errs);
        let dlogd = d as f64 * (d as f64).log2();
        rep.row(vec![
            d.to_string(),
            if m_star > 0 { m_star.to_string() } else { ">1024".into() },
            format!("{dlogd:.1}"),
            if m_star > 0 { format!("{:.2}", m_star as f64 / dlogd) } else { "-".into() },
            format!("{slope:.2}"),
        ]);
    }
    println!("{}", rep.render());
    println!("(slope ≈ -0.5 confirms the 1/sqrt(M) Monte-Carlo rate; a stable ratio column across d supports M* = O(d log d))\n");
    rep.save_csv(&results_dir().join("thm1.csv"))?;
    Ok(())
}
