//! Networked sharded serving: the wire around the in-process
//! [`crate::coordinator::Coordinator`].
//!
//! The paper's pitch is linear-time attention that makes long-context
//! protein MLM *servable*; this subsystem is the serving tier that
//! claim cashes out into. Four pieces, all dependency-free blocking
//! `std::net` (the build image is offline — no async runtime, no HTTP
//! crate):
//!
//! * [`proto`] — the `PFRMWIRE` frame codec: versioned, CRC32-checked
//!   binary frames carrying the stream ops (open / submit-chunk /
//!   scores / close / fill-mask), the batched submit
//!   ([`Msg::SubmitBatch`]/[`Msg::ScoresBatch`]: many sessions' chunks
//!   in one frame, per-entry status) plus the control ops (checkpoint /
//!   restore / drain), with the `PFRMSNAP` refuse-corruption
//!   discipline;
//! * [`server`] — [`Server`]: acceptor + bounded thread-per-connection
//!   pool over one coordinator. The read loop never blocks on the
//!   model: submits are enqueued and completed out-of-line, so one
//!   pipelined connection fills a whole fused wave. Two-level
//!   admission control (connection cap, [`InflightGate`]) answers
//!   overload with explicit `RetryAfter` frames; `net_*` metrics and
//!   per-request spans;
//! * [`client`] — [`PipelinedClient`]: multiplexes up to `depth`
//!   outstanding requests over one socket, matching replies by the
//!   frame header's request-id on a reader thread (out-of-order
//!   completion safe); absorbs `RetryAfter` with deterministic
//!   per-session jittered back-off. [`Client`] is its depth-1 blocking
//!   wrapper, kept for control planes and simple callers;
//! * [`router`] — [`Router`]: hashes session ids onto N workers over a
//!   slot table, forwards through a shared checkout/checkin
//!   [`BackendPool`] (capped idle connections, stale reap,
//!   evict-on-error with one fresh retry), and coalesces same-shard
//!   submits arriving within a batch window into `SubmitBatch`
//!   forwards. Live-rebalance drains a victim's sessions
//!   (checkpoint-all + close) into a `PFRMBNDL` blob and ships it to a
//!   peer over the same protocol — clients never see the move because
//!   per-shard in-flight counters give the drain a barrier over every
//!   admitted forward.
//!
//! Because causal FAVOR compresses any prefix into a constant-size
//! per-session state, "move this user to another machine" costs a few
//! tens of kilobytes on the wire no matter how many tokens have
//! streamed — the property that makes live migration practical at all.
//!
//! CLI surface: `performer serve addr=…` (worker), `performer route
//! addr=… shards=…` (front), `performer stream addr=…` (client
//! workload), `performer drain addr=… from=… to=…` (rebalance). See
//! README §Serving over TCP and DESIGN.md §Networked serving.

pub mod client;
pub mod proto;
pub mod router;
pub mod server;

pub use client::{Client, Pending, PipelinedClient};
pub use proto::{
    frame_bytes, frame_from_bytes, read_frame, write_frame, Msg, ScoreEntry, WIRE_VERSION,
};
pub use router::{
    BackendPool, Router, RouterConfig, RouterMetrics, RoutingTable, ROUTE_SLOTS,
};
pub use server::{InflightGate, InflightPermit, NetMetrics, Server, ServerConfig};
