//! Networked sharded serving: the wire around the in-process
//! [`crate::coordinator::Coordinator`].
//!
//! The paper's pitch is linear-time attention that makes long-context
//! protein MLM *servable*; this subsystem is the serving tier that
//! claim cashes out into. Four pieces, all dependency-free blocking
//! `std::net` (the build image is offline — no async runtime, no HTTP
//! crate):
//!
//! * [`proto`] — the `PFRMWIRE` frame codec: versioned, CRC32-checked
//!   binary frames carrying the stream ops (open / submit-chunk /
//!   scores / close / fill-mask) plus the control ops (checkpoint /
//!   restore / drain), with the `PFRMSNAP` refuse-corruption
//!   discipline;
//! * [`server`] — [`Server`]: acceptor + bounded thread-per-connection
//!   pool over one coordinator, with two-level admission control
//!   (connection cap, [`InflightGate`]) answering overload with
//!   explicit `RetryAfter` frames, `net_*` metrics and per-request
//!   spans;
//! * [`client`] — [`Client`]: blocking typed wrapper that absorbs
//!   `RetryAfter` back-off, used by the CLI's wire mode, the router's
//!   control plane, tests and benches alike;
//! * [`router`] — [`Router`]: hashes session ids onto N workers over a
//!   slot table and live-rebalances shards by draining a victim's
//!   sessions (checkpoint-all + close) into a `PFRMBNDL` blob and
//!   shipping it to a peer over the same protocol — clients never see
//!   the move because the routing-table lock doubles as the migration
//!   barrier.
//!
//! Because causal FAVOR compresses any prefix into a constant-size
//! per-session state, "move this user to another machine" costs a few
//! tens of kilobytes on the wire no matter how many tokens have
//! streamed — the property that makes live migration practical at all.
//!
//! CLI surface: `performer serve addr=…` (worker), `performer route
//! addr=… shards=…` (front), `performer stream addr=…` (client
//! workload), `performer drain addr=… from=… to=…` (rebalance). See
//! README §Serving over TCP and DESIGN.md §Networked serving.

pub mod client;
pub mod proto;
pub mod router;
pub mod server;

pub use client::Client;
pub use proto::{frame_bytes, frame_from_bytes, read_frame, write_frame, Msg, WIRE_VERSION};
pub use router::{Router, RouterMetrics, RoutingTable, ROUTE_SLOTS};
pub use server::{InflightGate, InflightPermit, NetMetrics, Server, ServerConfig};
