//! The worker-side TCP server: one acceptor plus a bounded
//! thread-per-connection pool in front of a [`Coordinator`].
//!
//! **Pipelined dispatch.** A connection's read loop never blocks on
//! the coordinator: a [`Msg::Submit`] (or [`Msg::SubmitBatch`]) is
//! admitted, enqueued on the coordinator, and handed — as a pending
//! reply receiver — to a per-connection completer thread that writes
//! replies in completion order, tagged with their request-ids. One
//! wire connection can therefore keep a whole fused wave in flight:
//! a pipelined client's burst of submits lands in the coordinator's
//! queue together and batches exactly like in-process
//! `submit_chunks`. Per-connection in-flight is bounded
//! (`max_conn_inflight`); when the bound is hit the read loop blocks,
//! which backpressures the client through TCP instead of queueing
//! unboundedly. Control ops (checkpoint / drain / restore …) are
//! dispatched inline on the read loop — they are queue barriers on the
//! coordinator anyway, and their replies interleave safely because ids
//! disambiguate.
//!
//! Admission control happens at two gates, and both answer with an
//! explicit [`Msg::RetryAfter`] frame instead of silently queuing:
//!
//! * **connection cap** (`max_conns`): a connection over the cap gets
//!   one `RetryAfter` frame and is closed;
//! * **inflight cap** (`max_inflight`): a [`Msg::Submit`] that cannot
//!   take an [`InflightGate`] permit is shed — it never reaches the
//!   coordinator's queue, so a shed request cannot advance a stream —
//!   while already-admitted requests run to completion. A
//!   [`Msg::SubmitBatch`] takes one permit **per entry,
//!   all-or-nothing**: a shed batch provably advanced no entry, so the
//!   client may re-send the whole frame verbatim.
//!
//! Every request is span-traced (`net_request`) and counted in the
//! coordinator's metrics registry under `net_*` (requests, sheds,
//! errors, open connections, inflight, batches, and a log2 latency
//! histogram), so one Prometheus dump covers the wire tier and the
//! serving core it fronts.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, StreamResponse};
use crate::obs::{trace, Counter, Gauge, Histogram, MetricsRegistry};
use crate::persist;

use super::proto::{read_frame, write_frame, Msg, ScoreEntry};

/// Tuning knobs of a [`Server`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// most simultaneous client connections; one over the cap is
    /// answered `RetryAfter` and closed (0 = unbounded)
    pub max_conns: usize,
    /// most submit requests admitted past the [`InflightGate`] at once;
    /// the rest are shed with `RetryAfter` (0 = unbounded)
    pub max_inflight: usize,
    /// back-off hint carried by every `RetryAfter` frame
    pub retry_after_ms: u32,
    /// most pending submit replies per connection before its read loop
    /// stops draining the socket (TCP backpressure; min 1)
    pub max_conn_inflight: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 64,
            max_inflight: 256,
            retry_after_ms: 25,
            max_conn_inflight: 32,
        }
    }
}

/// The wire tier's instruments, registered under `net_*` in the
/// coordinator's registry.
pub struct NetMetrics {
    /// requests answered (any op, any outcome)
    pub requests: Counter,
    /// requests shed with `RetryAfter` (inflight gate or connection cap)
    pub sheds: Counter,
    /// requests answered with an error frame
    pub errors: Counter,
    /// client connections currently open
    pub conns: Gauge,
    /// submit requests currently past the admission gate
    pub inflight: Gauge,
    /// per-request service latency, µs log2 buckets
    pub latency_us: Histogram,
    /// submit-batch frames served
    pub batches: Counter,
    /// individual entries carried by submit-batch frames
    pub batch_entries: Counter,
}

impl NetMetrics {
    /// Instruments registered under `prefix_*` in `reg`.
    pub fn registered(reg: &MetricsRegistry, prefix: &str) -> NetMetrics {
        NetMetrics {
            requests: reg.counter(&format!("{prefix}_requests_total")),
            sheds: reg.counter(&format!("{prefix}_sheds_total")),
            errors: reg.counter(&format!("{prefix}_errors_total")),
            conns: reg.gauge(&format!("{prefix}_open_conns")),
            inflight: reg.gauge(&format!("{prefix}_inflight")),
            latency_us: reg.histogram(&format!("{prefix}_latency_us")),
            batches: reg.counter(&format!("{prefix}_batches_total")),
            batch_entries: reg.counter(&format!("{prefix}_batch_entries_total")),
        }
    }
}

/// Counting admission gate for in-flight submits: lock-free
/// try-acquire, permit released on drop. A capacity of 0 means
/// unbounded (the gate still counts, for the `net_inflight` gauge).
pub struct InflightGate {
    cap: usize,
    cur: Arc<AtomicUsize>,
    gauge: Gauge,
}

/// An admitted request's slot in the [`InflightGate`]; dropping it
/// frees the slot.
pub struct InflightPermit {
    cur: Arc<AtomicUsize>,
    gauge: Gauge,
}

impl InflightGate {
    /// A gate admitting at most `cap` holders (0 = unbounded),
    /// mirroring its occupancy into `gauge`.
    pub fn new(cap: usize, gauge: Gauge) -> InflightGate {
        InflightGate { cap, cur: Arc::new(AtomicUsize::new(0)), gauge }
    }

    /// Take a slot, or `None` when the gate is full — the caller sheds.
    pub fn try_acquire(&self) -> Option<InflightPermit> {
        let admitted = self
            .cur
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                if self.cap != 0 && cur >= self.cap {
                    None
                } else {
                    Some(cur + 1)
                }
            })
            .is_ok();
        if !admitted {
            return None;
        }
        self.gauge.set(self.cur.load(Ordering::Relaxed) as u64);
        Some(InflightPermit { cur: self.cur.clone(), gauge: self.gauge.clone() })
    }
}

impl Drop for InflightPermit {
    fn drop(&mut self) {
        let before = self.cur.fetch_sub(1, Ordering::AcqRel);
        self.gauge.set(before.saturating_sub(1) as u64);
    }
}

/// A running TCP server over one [`Coordinator`]. Dropping it stops
/// the acceptor; established connections drain on their own threads.
pub struct Server {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    gate: Arc<InflightGate>,
    metrics: Arc<NetMetrics>,
}

impl Server {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port — read
    /// it back via [`Self::local_addr`]) and start serving `coord`.
    pub fn start(coord: Arc<Coordinator>, addr: &str, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding server to {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let metrics = Arc::new(NetMetrics::registered(&coord.registry(), "net"));
        let gate = Arc::new(InflightGate::new(cfg.max_inflight, metrics.inflight.clone()));
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_gate = gate.clone();
        let accept_metrics = metrics.clone();
        let acceptor = std::thread::Builder::new().name("net-accept".into()).spawn(move || {
            let open = Arc::new(AtomicUsize::new(0));
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if cfg.max_conns != 0 && open.load(Ordering::Acquire) >= cfg.max_conns {
                    // over the connection cap: answer loudly, then close
                    let mut s = stream;
                    let retry = Msg::RetryAfter { millis: cfg.retry_after_ms };
                    let _ = write_frame(&mut s, 0, &retry);
                    accept_metrics.sheds.inc();
                    continue;
                }
                open.fetch_add(1, Ordering::AcqRel);
                accept_metrics.conns.set(open.load(Ordering::Relaxed) as u64);
                let coord = coord.clone();
                let gate = accept_gate.clone();
                let metrics = accept_metrics.clone();
                let open2 = open.clone();
                let conn_cfg = cfg.clone();
                let spawned = std::thread::Builder::new().name("net-conn".into()).spawn(
                    move || {
                        let _ = handle_conn(stream, &coord, &gate, &metrics, &conn_cfg);
                        let before = open2.fetch_sub(1, Ordering::AcqRel);
                        metrics.conns.set(before.saturating_sub(1) as u64);
                    },
                );
                if spawned.is_err() {
                    let before = open.fetch_sub(1, Ordering::AcqRel);
                    accept_metrics.conns.set(before.saturating_sub(1) as u64);
                }
            }
        })?;
        Ok(Server { local_addr, stop, acceptor: Some(acceptor), gate, metrics })
    }

    /// The address the server actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The wire tier's instruments.
    pub fn metrics(&self) -> Arc<NetMetrics> {
        self.metrics.clone()
    }

    /// The submit admission gate — exposed so tests can saturate it
    /// deterministically.
    pub fn gate(&self) -> Arc<InflightGate> {
        self.gate.clone()
    }

    /// Stop accepting new connections (established ones drain on their
    /// own threads as clients hang up).
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the acceptor with one throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A submit admitted by the read loop, pending on the coordinator:
/// the completer thread waits its receiver(s) and writes the reply.
enum PendingJob {
    /// one [`Msg::Submit`]
    One {
        id: u64,
        session: String,
        rx: Receiver<StreamResponse>,
        _permit: InflightPermit,
        t0: Instant,
    },
    /// one [`Msg::SubmitBatch`]: per-entry receivers, one reply frame
    Batch {
        id: u64,
        entries: Vec<(String, Receiver<StreamResponse>)>,
        _permits: Vec<InflightPermit>,
        t0: Instant,
    },
}

fn handle_conn(
    mut stream: TcpStream,
    coord: &Coordinator,
    gate: &InflightGate,
    metrics: &Arc<NetMetrics>,
    cfg: &ServerConfig,
) -> Result<()> {
    // small frames answer promptly: scores shouldn't sit in Nagle
    let _ = stream.set_nodelay(true);
    // replies go through a mutex-shared clone of the socket: the read
    // loop answers sheds/control ops directly while the completer
    // thread writes submit replies as they finish — frames stay atomic
    // under the lock, ids keep the interleaving unambiguous
    let writer = Arc::new(Mutex::new(
        stream.try_clone().context("cloning the connection for replies")?,
    ));
    let (jobs_tx, jobs_rx) = sync_channel::<PendingJob>(cfg.max_conn_inflight.max(1));
    let completer = {
        let writer = writer.clone();
        let metrics = metrics.clone();
        std::thread::Builder::new()
            .name("net-complete".into())
            .spawn(move || {
                for job in jobs_rx {
                    complete_job(job, &writer, &metrics);
                }
            })
            .context("spawning the reply completer")?
    };
    loop {
        // clean client hang-up and a garbled peer both end the
        // connection; a desynced stream cannot be re-framed anyway
        let Ok((id, msg)) = read_frame(&mut stream) else { break };
        let t0 = Instant::now();
        match msg {
            Msg::Submit { pool, session, tokens } => {
                let _span = trace::span("net_request");
                // load-shed *before* the coordinator's queue: a shed
                // request never advances the stream, so the client can
                // retry it verbatim
                let Some(permit) = gate.try_acquire() else {
                    metrics.sheds.inc();
                    metrics.requests.inc();
                    let shed = Msg::RetryAfter { millis: cfg.retry_after_ms };
                    if write_locked(&writer, id, &shed).is_err() {
                        break;
                    }
                    continue;
                };
                match coord.submit_chunk(&pool, &session, tokens) {
                    Ok(rx) => {
                        let job = PendingJob::One { id, session, rx, _permit: permit, t0 };
                        if jobs_tx.send(job).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        drop(permit);
                        metrics.requests.inc();
                        metrics.errors.inc();
                        if write_locked(&writer, id, &err(format!("{e:#}"))).is_err() {
                            break;
                        }
                    }
                }
            }
            Msg::SubmitBatch { pool, entries } => {
                let _span = trace::span("net_request");
                metrics.batches.inc();
                metrics.batch_entries.add(entries.len() as u64);
                // one permit per entry, all-or-nothing: a shed batch
                // provably advanced no entry's stream, so the whole
                // frame is safe to re-send verbatim
                let mut permits = Vec::with_capacity(entries.len());
                for _ in &entries {
                    match gate.try_acquire() {
                        Some(p) => permits.push(p),
                        None => break,
                    }
                }
                if permits.len() < entries.len() {
                    drop(permits);
                    metrics.sheds.inc();
                    metrics.requests.inc();
                    let shed = Msg::RetryAfter { millis: cfg.retry_after_ms };
                    if write_locked(&writer, id, &shed).is_err() {
                        break;
                    }
                    continue;
                }
                let sessions: Vec<String> =
                    entries.iter().map(|(session, _)| session.clone()).collect();
                // submit_chunks lands the whole batch in the worker's
                // queue together, so distinct sessions fuse into one
                // batched forward wave
                match coord.submit_chunks(&pool, entries) {
                    Ok(rxs) => {
                        let entries = sessions.into_iter().zip(rxs).collect();
                        let job = PendingJob::Batch { id, entries, _permits: permits, t0 };
                        if jobs_tx.send(job).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        drop(permits);
                        metrics.requests.inc();
                        metrics.errors.inc();
                        if write_locked(&writer, id, &err(format!("{e:#}"))).is_err() {
                            break;
                        }
                    }
                }
            }
            other => {
                // control ops stay on the read loop: they are queue
                // barriers on the coordinator, and the pending submits
                // ahead of them were already enqueued in order
                let reply = dispatch(coord, other);
                metrics.requests.inc();
                if matches!(reply, Msg::Error { .. }) {
                    metrics.errors.inc();
                }
                metrics.latency_us.observe_duration(t0.elapsed());
                if write_locked(&writer, id, &reply).is_err() {
                    break;
                }
            }
        }
    }
    // closing the jobs channel lets the completer drain and exit
    drop(jobs_tx);
    let _ = completer.join();
    Ok(())
}

/// Write one reply frame under the shared writer lock.
fn write_locked(writer: &Mutex<TcpStream>, id: u64, msg: &Msg) -> Result<()> {
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, id, msg)
}

/// Turn one coordinator response into the per-entry wire outcome.
fn response_entry(session: &str, got: Result<StreamResponse, String>) -> ScoreEntry {
    match got {
        Ok(resp) => match (resp.error, resp.scores) {
            (None, Some(s)) => ScoreEntry::from_scores(&resp.session, &s),
            (Some(e), _) => ScoreEntry::failed(session, e),
            (None, None) => ScoreEntry::failed(session, "chunk response carried no scores"),
        },
        Err(e) => ScoreEntry::failed(session, e),
    }
}

/// Complete one pending submit: wait for the coordinator, write the
/// reply frame. Write errors are ignored — the read loop notices the
/// dead socket on its side and tears the connection down.
fn complete_job(job: PendingJob, writer: &Mutex<TcpStream>, metrics: &NetMetrics) {
    match job {
        PendingJob::One { id, session, rx, _permit, t0 } => {
            let got = rx.recv().map_err(|_| "stream worker dropped response".to_string());
            let reply = match response_entry(&session, got).into_msg() {
                // surface the entry error without the per-session
                // prefix a batch needs: single submits already know
                // their session
                Msg::Error { message } => {
                    let stripped = message
                        .strip_prefix(&format!("session '{session}': "))
                        .map(str::to_string)
                        .unwrap_or(message);
                    err(stripped)
                }
                scores => scores,
            };
            metrics.requests.inc();
            if matches!(reply, Msg::Error { .. }) {
                metrics.errors.inc();
            }
            metrics.latency_us.observe_duration(t0.elapsed());
            let _ = write_locked(writer, id, &reply);
        }
        PendingJob::Batch { id, entries, _permits, t0 } => {
            let entries: Vec<ScoreEntry> = entries
                .into_iter()
                .map(|(session, rx)| {
                    let got =
                        rx.recv().map_err(|_| "stream worker dropped response".to_string());
                    response_entry(&session, got)
                })
                .collect();
            metrics.requests.inc();
            if entries.iter().any(|e| matches!(e, ScoreEntry::Failed { .. })) {
                metrics.errors.inc();
            }
            metrics.latency_us.observe_duration(t0.elapsed());
            let _ = write_locked(writer, id, &Msg::ScoresBatch { entries });
        }
    }
}

fn err(message: String) -> Msg {
    Msg::Error { message }
}

/// Inline dispatch of the non-submit ops (submits go through the
/// pipelined path in `handle_conn`).
fn dispatch(coord: &Coordinator, msg: Msg) -> Msg {
    let _span = trace::span("net_request");
    match msg {
        Msg::Open { pool, session: _ } => {
            if coord.stream_pools().contains(&pool) {
                Msg::Ok { affected: 0 }
            } else {
                err(format!("no stream pool '{pool}'"))
            }
        }
        Msg::Close { pool, session } => match coord.close_stream(&pool, &session) {
            Ok(()) => Msg::Ok { affected: 0 },
            Err(e) => err(format!("{e:#}")),
        },
        Msg::FillMask { model, tokens } => {
            match coord.fill_mask_timeout(&model, tokens, Duration::from_secs(60)) {
                Ok(resp) => Msg::Filled {
                    positions: resp.predictions.iter().map(|(p, _, _)| *p as u32).collect(),
                    tokens: resp.predictions.iter().map(|(_, t, _)| *t).collect(),
                    probs: resp.predictions.iter().map(|(_, _, p)| *p).collect(),
                    filled: resp.filled,
                },
                Err(e) => err(format!("{e:#}")),
            }
        }
        Msg::Checkpoint { pool, dir, delta } => {
            let res = if delta {
                coord.checkpoint_delta(&pool, Path::new(&dir))
            } else {
                coord.checkpoint_all(&pool, Path::new(&dir))
            };
            match res {
                Ok(n) => Msg::Ok { affected: n as u64 },
                Err(e) => err(format!("{e:#}")),
            }
        }
        Msg::Restore { pool, dir } => match coord.restore_from(&pool, Path::new(&dir)) {
            Ok(n) => Msg::Ok { affected: n as u64 },
            Err(e) => err(format!("{e:#}")),
        },
        Msg::DrainExport { pool } => {
            let dir = scratch_dir("drain");
            let reply = match drain_export(coord, &pool, &dir) {
                Ok(m) => m,
                Err(e) => err(format!("{e:#}")),
            };
            let _ = std::fs::remove_dir_all(&dir);
            reply
        }
        Msg::RestoreBundle { pool, bundle } => {
            let dir = scratch_dir("adopt");
            let reply = match adopt_bundle(coord, &pool, &bundle, &dir) {
                Ok(n) => Msg::Ok { affected: n as u64 },
                Err(e) => err(format!("{e:#}")),
            };
            let _ = std::fs::remove_dir_all(&dir);
            reply
        }
        Msg::AdminDrain { .. } => {
            err("admin-drain is a router op; this peer is a worker".into())
        }
        other => err(format!("unexpected {} frame from a client", other.name())),
    }
}

/// Evacuate the pool through a scratch export directory and pack the
/// result for the wire.
fn drain_export(coord: &Coordinator, pool: &str, dir: &Path) -> Result<Msg> {
    let sessions = coord.drain_stream(pool, dir)? as u64;
    let bundle = persist::bundle_dir(dir)?;
    Ok(Msg::Export { sessions, bundle })
}

/// Unpack a shipped bundle into a scratch directory and adopt it.
fn adopt_bundle(coord: &Coordinator, pool: &str, bundle: &[u8], dir: &Path) -> Result<usize> {
    persist::unbundle_into(bundle, dir)?;
    coord.restore_from(pool, dir)
}

/// A unique scratch directory per migration op (pid + monotonic
/// counter), so concurrent drains never collide.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    static NONCE: AtomicU64 = AtomicU64::new(0);
    let n = NONCE.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("pfrm_net_{tag}_{}_{n}", std::process::id()))
}
