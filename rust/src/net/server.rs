//! The worker-side TCP server: one acceptor plus a bounded
//! thread-per-connection pool in front of a [`Coordinator`].
//!
//! Admission control happens at two gates, and both answer with an
//! explicit [`Msg::RetryAfter`] frame instead of silently queuing:
//!
//! * **connection cap** (`max_conns`): a connection over the cap gets
//!   one `RetryAfter` frame and is closed;
//! * **inflight cap** (`max_inflight`): a [`Msg::Submit`] that cannot
//!   take an [`InflightGate`] permit is shed — it never reaches the
//!   coordinator's queue, so a shed request cannot advance a stream —
//!   while already-admitted requests run to completion.
//!
//! Every request is span-traced (`net_request`) and counted in the
//! coordinator's metrics registry under `net_*` (requests, sheds,
//! errors, open connections, inflight, and a log2 latency histogram),
//! so one Prometheus dump covers the wire tier and the serving core it
//! fronts.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::obs::{trace, Counter, Gauge, Histogram, MetricsRegistry};
use crate::persist;

use super::proto::{read_frame, write_frame, Msg};

/// Tuning knobs of a [`Server`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// most simultaneous client connections; one over the cap is
    /// answered `RetryAfter` and closed (0 = unbounded)
    pub max_conns: usize,
    /// most submit requests admitted past the [`InflightGate`] at once;
    /// the rest are shed with `RetryAfter` (0 = unbounded)
    pub max_inflight: usize,
    /// back-off hint carried by every `RetryAfter` frame
    pub retry_after_ms: u32,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { max_conns: 64, max_inflight: 256, retry_after_ms: 25 }
    }
}

/// The wire tier's instruments, registered under `net_*` in the
/// coordinator's registry.
pub struct NetMetrics {
    /// requests answered (any op, any outcome)
    pub requests: Counter,
    /// requests shed with `RetryAfter` (inflight gate or connection cap)
    pub sheds: Counter,
    /// requests answered with an error frame
    pub errors: Counter,
    /// client connections currently open
    pub conns: Gauge,
    /// submit requests currently past the admission gate
    pub inflight: Gauge,
    /// per-request service latency, µs log2 buckets
    pub latency_us: Histogram,
}

impl NetMetrics {
    /// Instruments registered under `prefix_*` in `reg`.
    pub fn registered(reg: &MetricsRegistry, prefix: &str) -> NetMetrics {
        NetMetrics {
            requests: reg.counter(&format!("{prefix}_requests_total")),
            sheds: reg.counter(&format!("{prefix}_sheds_total")),
            errors: reg.counter(&format!("{prefix}_errors_total")),
            conns: reg.gauge(&format!("{prefix}_open_conns")),
            inflight: reg.gauge(&format!("{prefix}_inflight")),
            latency_us: reg.histogram(&format!("{prefix}_latency_us")),
        }
    }
}

/// Counting admission gate for in-flight submits: lock-free
/// try-acquire, permit released on drop. A capacity of 0 means
/// unbounded (the gate still counts, for the `net_inflight` gauge).
pub struct InflightGate {
    cap: usize,
    cur: Arc<AtomicUsize>,
    gauge: Gauge,
}

/// An admitted request's slot in the [`InflightGate`]; dropping it
/// frees the slot.
pub struct InflightPermit {
    cur: Arc<AtomicUsize>,
    gauge: Gauge,
}

impl InflightGate {
    /// A gate admitting at most `cap` holders (0 = unbounded),
    /// mirroring its occupancy into `gauge`.
    pub fn new(cap: usize, gauge: Gauge) -> InflightGate {
        InflightGate { cap, cur: Arc::new(AtomicUsize::new(0)), gauge }
    }

    /// Take a slot, or `None` when the gate is full — the caller sheds.
    pub fn try_acquire(&self) -> Option<InflightPermit> {
        let admitted = self
            .cur
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                if self.cap != 0 && cur >= self.cap {
                    None
                } else {
                    Some(cur + 1)
                }
            })
            .is_ok();
        if !admitted {
            return None;
        }
        self.gauge.set(self.cur.load(Ordering::Relaxed) as u64);
        Some(InflightPermit { cur: self.cur.clone(), gauge: self.gauge.clone() })
    }
}

impl Drop for InflightPermit {
    fn drop(&mut self) {
        let before = self.cur.fetch_sub(1, Ordering::AcqRel);
        self.gauge.set(before.saturating_sub(1) as u64);
    }
}

/// A running TCP server over one [`Coordinator`]. Dropping it stops
/// the acceptor; established connections drain on their own threads.
pub struct Server {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    gate: Arc<InflightGate>,
    metrics: Arc<NetMetrics>,
}

impl Server {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port — read
    /// it back via [`Self::local_addr`]) and start serving `coord`.
    pub fn start(coord: Arc<Coordinator>, addr: &str, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding server to {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let metrics = Arc::new(NetMetrics::registered(&coord.registry(), "net"));
        let gate = Arc::new(InflightGate::new(cfg.max_inflight, metrics.inflight.clone()));
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_gate = gate.clone();
        let accept_metrics = metrics.clone();
        let acceptor = std::thread::Builder::new().name("net-accept".into()).spawn(move || {
            let open = Arc::new(AtomicUsize::new(0));
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                if cfg.max_conns != 0 && open.load(Ordering::Acquire) >= cfg.max_conns {
                    // over the connection cap: answer loudly, then close
                    let mut s = stream;
                    let retry = Msg::RetryAfter { millis: cfg.retry_after_ms };
                    let _ = write_frame(&mut s, 0, &retry);
                    accept_metrics.sheds.inc();
                    continue;
                }
                open.fetch_add(1, Ordering::AcqRel);
                accept_metrics.conns.set(open.load(Ordering::Relaxed) as u64);
                let coord = coord.clone();
                let gate = accept_gate.clone();
                let metrics = accept_metrics.clone();
                let open2 = open.clone();
                let spawned = std::thread::Builder::new().name("net-conn".into()).spawn(
                    move || {
                        let _ = handle_conn(stream, &coord, &gate, &metrics, cfg.retry_after_ms);
                        let before = open2.fetch_sub(1, Ordering::AcqRel);
                        metrics.conns.set(before.saturating_sub(1) as u64);
                    },
                );
                if spawned.is_err() {
                    let before = open.fetch_sub(1, Ordering::AcqRel);
                    accept_metrics.conns.set(before.saturating_sub(1) as u64);
                }
            }
        })?;
        Ok(Server { local_addr, stop, acceptor: Some(acceptor), gate, metrics })
    }

    /// The address the server actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The wire tier's instruments.
    pub fn metrics(&self) -> Arc<NetMetrics> {
        self.metrics.clone()
    }

    /// The submit admission gate — exposed so tests can saturate it
    /// deterministically.
    pub fn gate(&self) -> Arc<InflightGate> {
        self.gate.clone()
    }

    /// Stop accepting new connections (established ones drain on their
    /// own threads as clients hang up).
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the acceptor with one throwaway connection
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(
    mut stream: TcpStream,
    coord: &Coordinator,
    gate: &InflightGate,
    metrics: &NetMetrics,
    retry_after_ms: u32,
) -> Result<()> {
    // small frames answer promptly: scores shouldn't sit in Nagle
    let _ = stream.set_nodelay(true);
    loop {
        // clean client hang-up and a garbled peer both end the
        // connection; a desynced stream cannot be re-framed anyway
        let Ok((id, msg)) = read_frame(&mut stream) else { break };
        let t0 = Instant::now();
        let reply = dispatch(coord, gate, metrics, retry_after_ms, msg);
        metrics.requests.inc();
        if matches!(reply, Msg::Error { .. }) {
            metrics.errors.inc();
        }
        metrics.latency_us.observe_duration(t0.elapsed());
        write_frame(&mut stream, id, &reply)?;
    }
    Ok(())
}

fn err(message: String) -> Msg {
    Msg::Error { message }
}

fn dispatch(
    coord: &Coordinator,
    gate: &InflightGate,
    metrics: &NetMetrics,
    retry_after_ms: u32,
    msg: Msg,
) -> Msg {
    let _span = trace::span("net_request");
    match msg {
        Msg::Open { pool, session: _ } => {
            if coord.stream_pools().contains(&pool) {
                Msg::Ok { affected: 0 }
            } else {
                err(format!("no stream pool '{pool}'"))
            }
        }
        Msg::Submit { pool, session, tokens } => {
            // load-shed *before* the coordinator's queue: a shed
            // request never advances the stream, so the client can
            // retry it verbatim
            let Some(_permit) = gate.try_acquire() else {
                metrics.sheds.inc();
                return Msg::RetryAfter { millis: retry_after_ms };
            };
            match coord.stream_chunk(&pool, &session, tokens) {
                Ok(resp) => match resp.scores {
                    Some(s) => Msg::from_scores(&resp.session, &s),
                    None => err("chunk response carried no scores".into()),
                },
                Err(e) => err(format!("{e:#}")),
            }
        }
        Msg::Close { pool, session } => match coord.close_stream(&pool, &session) {
            Ok(()) => Msg::Ok { affected: 0 },
            Err(e) => err(format!("{e:#}")),
        },
        Msg::FillMask { model, tokens } => {
            match coord.fill_mask_timeout(&model, tokens, Duration::from_secs(60)) {
                Ok(resp) => Msg::Filled {
                    positions: resp.predictions.iter().map(|(p, _, _)| *p as u32).collect(),
                    tokens: resp.predictions.iter().map(|(_, t, _)| *t).collect(),
                    probs: resp.predictions.iter().map(|(_, _, p)| *p).collect(),
                    filled: resp.filled,
                },
                Err(e) => err(format!("{e:#}")),
            }
        }
        Msg::Checkpoint { pool, dir, delta } => {
            let res = if delta {
                coord.checkpoint_delta(&pool, Path::new(&dir))
            } else {
                coord.checkpoint_all(&pool, Path::new(&dir))
            };
            match res {
                Ok(n) => Msg::Ok { affected: n as u64 },
                Err(e) => err(format!("{e:#}")),
            }
        }
        Msg::Restore { pool, dir } => match coord.restore_from(&pool, Path::new(&dir)) {
            Ok(n) => Msg::Ok { affected: n as u64 },
            Err(e) => err(format!("{e:#}")),
        },
        Msg::DrainExport { pool } => {
            let dir = scratch_dir("drain");
            let reply = match drain_export(coord, &pool, &dir) {
                Ok(m) => m,
                Err(e) => err(format!("{e:#}")),
            };
            let _ = std::fs::remove_dir_all(&dir);
            reply
        }
        Msg::RestoreBundle { pool, bundle } => {
            let dir = scratch_dir("adopt");
            let reply = match adopt_bundle(coord, &pool, &bundle, &dir) {
                Ok(n) => Msg::Ok { affected: n as u64 },
                Err(e) => err(format!("{e:#}")),
            };
            let _ = std::fs::remove_dir_all(&dir);
            reply
        }
        Msg::AdminDrain { .. } => {
            err("admin-drain is a router op; this peer is a worker".into())
        }
        other => err(format!("unexpected {} frame from a client", other.name())),
    }
}

/// Evacuate the pool through a scratch export directory and pack the
/// result for the wire.
fn drain_export(coord: &Coordinator, pool: &str, dir: &Path) -> Result<Msg> {
    let sessions = coord.drain_stream(pool, dir)? as u64;
    let bundle = persist::bundle_dir(dir)?;
    Ok(Msg::Export { sessions, bundle })
}

/// Unpack a shipped bundle into a scratch directory and adopt it.
fn adopt_bundle(coord: &Coordinator, pool: &str, bundle: &[u8], dir: &Path) -> Result<usize> {
    persist::unbundle_into(bundle, dir)?;
    coord.restore_from(pool, dir)
}

/// A unique scratch directory per migration op (pid + monotonic
/// counter), so concurrent drains never collide.
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    static NONCE: AtomicU64 = AtomicU64::new(0);
    let n = NONCE.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("pfrm_net_{tag}_{}_{n}", std::process::id()))
}
