//! Wire clients: a multiplexed [`PipelinedClient`] that keeps up to
//! `depth` requests outstanding on one `TcpStream`, and the blocking
//! [`Client`] — now just a depth-1 wrapper over it.
//!
//! Pipelining rides the request-id already in every `PFRMWIRE` frame
//! header: the writer stamps each request with a fresh id, a dedicated
//! reader thread matches reply frames back to their callers by id, so
//! replies may complete **out of order** without ever mis-routing. The
//! send window is the only flow control — [`PipelinedClient::send`]
//! blocks while `depth` requests are outstanding, so a slow peer
//! backpressures the caller instead of growing an unbounded queue.
//!
//! Both clients transparently absorb [`Msg::RetryAfter`] answers (the
//! server's load-shed signal) by sleeping a **jittered** back-off and
//! re-sending — bounded by [`PipelinedClient::retries`]; set it to 0 to
//! surface the shed as an error instead (the load-shed unit test
//! does). A re-sent submit is safe because a shed request never
//! reached the coordinator's queue, so the stream did not advance. The
//! jitter is deterministic per session (seeded from the session-id
//! hash and the attempt number, no ambient entropy), spreading shed
//! clients over [0.5, 1.5)× the hint so they don't re-arrive in
//! lockstep and shed again as one thundering herd.
//!
//! Ordering caveat: the server admits a connection's requests in
//! arrival order, so two pipelined chunks of the **same** session stay
//! ordered — *unless* the first is shed and retried after the second
//! was already admitted. Callers that pipeline therefore keep at most
//! one outstanding chunk per session (pipelining *across* sessions,
//! as the CLI's `depth=` mode and the bench do).

use std::collections::HashMap;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::rng::{fnv1a64, Pcg64};
use crate::stream::ChunkScores;

use super::proto::{read_frame, write_frame, Msg, ScoreEntry};

/// State shared between a [`PipelinedClient`]'s writer half and its
/// reader thread.
struct PipeShared {
    state: Mutex<PipeState>,
    cv: Condvar,
}

struct PipeState {
    /// reply slots of the outstanding requests, keyed by request-id
    waiters: HashMap<u64, Sender<Result<Msg, String>>>,
    /// requests sent and not yet answered (== waiters.len(), tracked
    /// separately so the send window check is one compare)
    outstanding: usize,
    /// set when the connection died; every later send refuses fast
    dead: Option<String>,
}

/// A handle to one in-flight request; [`Pending::wait`] blocks until
/// its reply arrives (in whatever order the peer answers).
pub struct Pending {
    rx: Receiver<Result<Msg, String>>,
    id: u64,
}

impl Pending {
    /// The request-id this reply will arrive under.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the reply for *this* request arrives.
    pub fn wait(self) -> Result<Msg> {
        match self.rx.recv() {
            Ok(Ok(msg)) => Ok(msg),
            Ok(Err(reason)) => bail!("connection lost awaiting request {}: {reason}", self.id),
            Err(_) => bail!("connection closed before request {} was answered", self.id),
        }
    }
}

/// A multiplexed connection to a [`super::Server`] or
/// [`super::Router`]: up to `depth` requests outstanding, replies
/// matched by request-id on a reader thread.
pub struct PipelinedClient {
    writer: TcpStream,
    next_id: u64,
    depth: usize,
    shared: Arc<PipeShared>,
    reader: Option<JoinHandle<()>>,
    /// how many `RetryAfter` answers to absorb before giving up
    /// (0 = surface the first shed as an error)
    pub retries: u32,
    /// ceiling on the per-attempt back-off sleep, whatever the server
    /// hints
    pub max_backoff: Duration,
}

impl PipelinedClient {
    /// Connect to `addr` (`host:port`) with a send window of `depth`
    /// outstanding requests (clamped to at least 1).
    pub fn connect(addr: &str, depth: usize) -> Result<PipelinedClient> {
        let writer =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = writer.set_nodelay(true);
        let read_half = writer.try_clone().context("cloning stream for the reader")?;
        let shared = Arc::new(PipeShared {
            state: Mutex::new(PipeState {
                waiters: HashMap::new(),
                outstanding: 0,
                dead: None,
            }),
            cv: Condvar::new(),
        });
        let reader_shared = shared.clone();
        let reader = std::thread::Builder::new()
            .name("net-client-reader".into())
            .spawn(move || reader_loop(read_half, &reader_shared))
            .context("spawning client reader thread")?;
        Ok(PipelinedClient {
            writer,
            next_id: 1,
            depth: depth.max(1),
            shared,
            reader: Some(reader),
            retries: 8,
            max_backoff: Duration::from_millis(250),
        })
    }

    /// Connect, retrying for up to `timeout` — rides out a peer that
    /// is still binding its listener (process start-up races in the
    /// multi-process smoke).
    pub fn connect_retry(addr: &str, timeout: Duration, depth: usize) -> Result<PipelinedClient> {
        let t0 = Instant::now();
        loop {
            match Self::connect(addr, depth) {
                Ok(c) => return Ok(c),
                Err(e) if t0.elapsed() >= timeout => {
                    return Err(e).with_context(|| format!("gave up on {addr} after {timeout:?}"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// The send window (most requests outstanding at once).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Issue one request without waiting for its reply. Blocks while
    /// the send window is full; returns the [`Pending`] handle whose
    /// [`Pending::wait`] yields this request's reply — even if the
    /// peer answers other requests first.
    pub fn send(&mut self, msg: &Msg) -> Result<Pending> {
        let id = self.next_id;
        let (tx, rx) = channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            loop {
                if let Some(reason) = &st.dead {
                    bail!("connection lost: {reason}");
                }
                if st.outstanding < self.depth {
                    break;
                }
                st = self.shared.cv.wait(st).unwrap();
            }
            st.outstanding += 1;
            st.waiters.insert(id, tx);
        }
        self.next_id += 1;
        if let Err(e) = write_frame(&mut self.writer, id, msg) {
            let mut st = self.shared.state.lock().unwrap();
            st.waiters.remove(&id);
            st.outstanding = st.outstanding.saturating_sub(1);
            self.shared.cv.notify_all();
            return Err(e);
        }
        Ok(Pending { rx, id })
    }

    /// Send one request and block for its reply, absorbing up to
    /// [`Self::retries`] `RetryAfter` answers with jittered back-off.
    pub fn call(&mut self, msg: &Msg) -> Result<Msg> {
        let key = retry_key(msg).to_string();
        let mut attempt = 0u32;
        loop {
            match self.send(msg)?.wait()? {
                Msg::RetryAfter { millis } if attempt < self.retries => {
                    attempt += 1;
                    std::thread::sleep(self.backoff(millis, &key, attempt));
                }
                Msg::RetryAfter { millis } => bail!(
                    "peer busy: shed {} attempt(s) of a {} (last retry-after hint {millis} ms)",
                    attempt + 1,
                    msg.name()
                ),
                other => return Ok(other),
            }
        }
    }

    /// Complete a pipelined submit issued via [`Self::send`]: wait for
    /// `pending`, absorbing `RetryAfter` sheds by re-sending the same
    /// chunk (safe — a shed never reached the coordinator's queue, so
    /// the stream did not advance) with jittered back-off.
    pub fn finish_submit(
        &mut self,
        pool: &str,
        session: &str,
        tokens: &[u8],
        pending: Pending,
    ) -> Result<ChunkScores> {
        let mut attempt = 0u32;
        let mut p = pending;
        loop {
            match p.wait()? {
                Msg::RetryAfter { millis } if attempt < self.retries => {
                    attempt += 1;
                    std::thread::sleep(self.backoff(millis, session, attempt));
                    p = self.send(&Msg::Submit {
                        pool: pool.into(),
                        session: session.into(),
                        tokens: tokens.to_vec(),
                    })?;
                }
                Msg::RetryAfter { millis } => bail!(
                    "peer busy: shed {} attempt(s) of a submit (last retry-after hint \
                     {millis} ms)",
                    attempt + 1
                ),
                other => {
                    let (sid, scores) = other.into_chunk_scores()?;
                    ensure!(sid == session, "scores for session '{sid}', expected '{session}'");
                    return Ok(scores);
                }
            }
        }
    }

    /// The jittered back-off before retry `attempt`: the server's hint
    /// (capped at [`Self::max_backoff`]) scaled by a deterministic
    /// per-session factor in [0.5, 1.5) — shed clients de-lockstep
    /// without any ambient entropy.
    fn backoff(&self, hint_ms: u32, key: &str, attempt: u32) -> Duration {
        let base = Duration::from_millis(u64::from(hint_ms)).min(self.max_backoff);
        let seed = fnv1a64(key.as_bytes())
            ^ u64::from(attempt).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::new(seed);
        base.mul_f64(0.5 + rng.uniform())
    }

    /// Verify `pool` exists on the serving peer.
    pub fn open(&mut self, pool: &str, session: &str) -> Result<()> {
        let msg = Msg::Open { pool: pool.into(), session: session.into() };
        self.call(&msg)?.into_ok().map(|_| ())
    }

    /// Score `tokens` as the session's next chunk (blocking).
    pub fn submit(&mut self, pool: &str, session: &str, tokens: &[u8]) -> Result<ChunkScores> {
        let msg =
            Msg::Submit { pool: pool.into(), session: session.into(), tokens: tokens.to_vec() };
        let (sid, scores) = self.call(&msg)?.into_chunk_scores()?;
        ensure!(sid == session, "scores for session '{sid}', expected '{session}'");
        Ok(scores)
    }

    /// Score many sessions' next chunks in one frame and one fused
    /// coordinator wave; returns one [`ScoreEntry`] per entry, in
    /// submission order (failures are per-entry). A whole-frame shed is
    /// absorbed like any other `RetryAfter` — the batch is admitted
    /// all-or-nothing, so a re-send never double-advances a stream.
    pub fn submit_batch(
        &mut self,
        pool: &str,
        entries: Vec<(String, Vec<u8>)>,
    ) -> Result<Vec<ScoreEntry>> {
        let n = entries.len();
        let msg = Msg::SubmitBatch { pool: pool.into(), entries };
        match self.call(&msg)? {
            Msg::ScoresBatch { entries } => {
                ensure!(
                    entries.len() == n,
                    "submit-batch sent {n} entries but got {} back",
                    entries.len()
                );
                Ok(entries)
            }
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected a scores-batch frame, got {}", other.name()),
        }
    }

    /// End a stream, releasing its carried state on the server.
    pub fn close(&mut self, pool: &str, session: &str) -> Result<()> {
        let msg = Msg::Close { pool: pool.into(), session: session.into() };
        self.call(&msg)?.into_ok().map(|_| ())
    }

    /// Export the pool's sessions to `dir` on the *server's*
    /// filesystem; returns the sessions written.
    pub fn checkpoint(&mut self, pool: &str, dir: &str, delta: bool) -> Result<usize> {
        let msg = Msg::Checkpoint { pool: pool.into(), dir: dir.into(), delta };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Adopt sessions from `dir` on the *server's* filesystem; returns
    /// the sessions adopted.
    pub fn restore(&mut self, pool: &str, dir: &str) -> Result<usize> {
        let msg = Msg::Restore { pool: pool.into(), dir: dir.into() };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Evacuate every live session of the pool into a `PFRMBNDL` blob;
    /// returns (session count, bundle bytes).
    pub fn drain_export(&mut self, pool: &str) -> Result<(u64, Vec<u8>)> {
        match self.call(&Msg::DrainExport { pool: pool.into() })? {
            Msg::Export { sessions, bundle } => Ok((sessions, bundle)),
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected an export frame, got {}", other.name()),
        }
    }

    /// Hand a `PFRMBNDL` blob to the peer for adoption; returns the
    /// sessions adopted.
    pub fn restore_bundle(&mut self, pool: &str, bundle: Vec<u8>) -> Result<usize> {
        let msg = Msg::RestoreBundle { pool: pool.into(), bundle };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Ask a router to live-rebalance: drain shard `from` into shard
    /// `to`; returns the sessions moved.
    pub fn admin_drain(&mut self, pool: &str, from: u32, to: u32) -> Result<u64> {
        self.call(&Msg::AdminDrain { pool: pool.into(), from, to })?.into_ok()
    }

    /// One-shot fill-mask through a batched pool; returns the filled
    /// sequence plus `(position, token, probability)` predictions.
    #[allow(clippy::type_complexity)]
    pub fn fill_mask(
        &mut self,
        model: &str,
        tokens: Vec<u8>,
    ) -> Result<(Vec<u8>, Vec<(usize, u8, f32)>)> {
        match self.call(&Msg::FillMask { model: model.into(), tokens })? {
            Msg::Filled { filled, positions, tokens, probs } => {
                let preds = positions
                    .into_iter()
                    .zip(tokens)
                    .zip(probs)
                    .map(|((p, t), pr)| (p as usize, t, pr))
                    .collect();
                Ok((filled, preds))
            }
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected a filled frame, got {}", other.name()),
        }
    }
}

impl Drop for PipelinedClient {
    fn drop(&mut self) {
        // wake the reader out of its blocking read, then join it
        let _ = self.writer.shutdown(Shutdown::Both);
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// The reader half: match every reply frame to its waiter by
/// request-id. On any read error (including clean shutdown) every
/// still-outstanding request is completed with the failure reason and
/// the connection is marked dead, so no caller blocks forever.
fn reader_loop(mut stream: TcpStream, shared: &PipeShared) {
    let reason = loop {
        match read_frame(&mut stream) {
            Ok((id, msg)) => {
                let mut st = shared.state.lock().unwrap();
                let Some(tx) = st.waiters.remove(&id) else {
                    // a reply nothing asked for: the framing is
                    // desynced; nothing after it can be trusted
                    break format!("peer answered unknown request id {id}");
                };
                st.outstanding = st.outstanding.saturating_sub(1);
                shared.cv.notify_all();
                drop(st);
                // a waiter that gave up just drops its receiver; fine
                let _ = tx.send(Ok(msg));
            }
            Err(e) => break format!("{e:#}"),
        }
    };
    let mut st = shared.state.lock().unwrap();
    for (_, tx) in st.waiters.drain() {
        let _ = tx.send(Err(reason.clone()));
    }
    st.outstanding = 0;
    st.dead = Some(reason);
    shared.cv.notify_all();
}

/// The jitter key of a request: its session where it has one (so a
/// client's sessions de-lockstep independently), the op name otherwise.
fn retry_key(msg: &Msg) -> &str {
    match msg {
        Msg::Open { session, .. }
        | Msg::Submit { session, .. }
        | Msg::Close { session, .. } => session,
        Msg::SubmitBatch { entries, .. } => {
            entries.first().map_or("batch", |(session, _)| session)
        }
        other => other.name(),
    }
}

/// A blocking connection to a [`super::Server`] or [`super::Router`]:
/// a [`PipelinedClient`] pinned to depth 1, kept as the simple
/// call-and-wait interface the CLI's control ops, the router's
/// migration plane, and the tests use. Derefs to [`PipelinedClient`],
/// so every typed helper (and the `retries`/`max_backoff` knobs) is
/// available directly.
pub struct Client {
    inner: PipelinedClient,
}

impl Client {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        Ok(Client { inner: PipelinedClient::connect(addr, 1)? })
    }

    /// Connect, retrying for up to `timeout`.
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Client> {
        Ok(Client { inner: PipelinedClient::connect_retry(addr, timeout, 1)? })
    }
}

impl std::ops::Deref for Client {
    type Target = PipelinedClient;

    fn deref(&self) -> &PipelinedClient {
        &self.inner
    }
}

impl std::ops::DerefMut for Client {
    fn deref_mut(&mut self) -> &mut PipelinedClient {
        &mut self.inner
    }
}

impl Msg {
    /// Unwrap an [`Msg::Ok`] reply into its affected count.
    fn into_ok(self) -> Result<u64> {
        match self {
            Msg::Ok { affected } => Ok(affected),
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected an ok frame, got {}", other.name()),
        }
    }
}
