//! Blocking wire client: one `TcpStream`, one request in flight,
//! typed wrappers over the [`Msg`] ops.
//!
//! The client transparently absorbs [`Msg::RetryAfter`] answers (the
//! server's load-shed signal) by sleeping the hinted back-off and
//! re-sending — bounded by [`Client::retries`]; set it to 0 to surface
//! the shed as an error instead (the load-shed unit test does). A
//! re-sent submit is safe because a shed request never reached the
//! coordinator's queue, so the stream did not advance.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::stream::ChunkScores;

use super::proto::{read_frame, write_frame, Msg};

/// A blocking connection to a [`super::Server`] or [`super::Router`].
pub struct Client {
    stream: TcpStream,
    next_id: u64,
    /// how many `RetryAfter` answers to absorb before giving up
    /// (0 = surface the first shed as an error)
    pub retries: u32,
    /// ceiling on the per-attempt back-off sleep, whatever the server
    /// hints
    pub max_backoff: Duration,
}

impl Client {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            stream,
            next_id: 1,
            retries: 8,
            max_backoff: Duration::from_millis(250),
        })
    }

    /// Connect, retrying for up to `timeout` — rides out a peer that
    /// is still binding its listener (process start-up races in the
    /// multi-process smoke).
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Client> {
        let t0 = Instant::now();
        loop {
            match Self::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if t0.elapsed() >= timeout => {
                    return Err(e).with_context(|| format!("gave up on {addr} after {timeout:?}"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// Send one request and return its (id-checked) reply, absorbing
    /// up to [`Self::retries`] `RetryAfter` answers.
    pub fn call(&mut self, msg: &Msg) -> Result<Msg> {
        let mut attempt = 0u32;
        loop {
            let id = self.next_id;
            self.next_id += 1;
            write_frame(&mut self.stream, id, msg)?;
            let (rid, reply) = read_frame(&mut self.stream)?;
            ensure!(rid == id, "peer answered request {rid}, expected {id}");
            match reply {
                Msg::RetryAfter { millis } if attempt < self.retries => {
                    attempt += 1;
                    std::thread::sleep(
                        Duration::from_millis(u64::from(millis)).min(self.max_backoff),
                    );
                }
                Msg::RetryAfter { millis } => bail!(
                    "peer busy: shed {} attempt(s) of a {} (last retry-after hint {millis} ms)",
                    attempt + 1,
                    msg.name()
                ),
                other => return Ok(other),
            }
        }
    }

    /// Verify `pool` exists on the serving peer.
    pub fn open(&mut self, pool: &str, session: &str) -> Result<()> {
        let msg = Msg::Open { pool: pool.into(), session: session.into() };
        self.call(&msg)?.into_ok().map(|_| ())
    }

    /// Score `tokens` as the session's next chunk.
    pub fn submit(&mut self, pool: &str, session: &str, tokens: &[u8]) -> Result<ChunkScores> {
        let msg =
            Msg::Submit { pool: pool.into(), session: session.into(), tokens: tokens.to_vec() };
        let (sid, scores) = self.call(&msg)?.into_chunk_scores()?;
        ensure!(sid == session, "scores for session '{sid}', expected '{session}'");
        Ok(scores)
    }

    /// End a stream, releasing its carried state on the server.
    pub fn close(&mut self, pool: &str, session: &str) -> Result<()> {
        let msg = Msg::Close { pool: pool.into(), session: session.into() };
        self.call(&msg)?.into_ok().map(|_| ())
    }

    /// Export the pool's sessions to `dir` on the *server's*
    /// filesystem; returns the sessions written.
    pub fn checkpoint(&mut self, pool: &str, dir: &str, delta: bool) -> Result<usize> {
        let msg = Msg::Checkpoint { pool: pool.into(), dir: dir.into(), delta };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Adopt sessions from `dir` on the *server's* filesystem; returns
    /// the sessions adopted.
    pub fn restore(&mut self, pool: &str, dir: &str) -> Result<usize> {
        let msg = Msg::Restore { pool: pool.into(), dir: dir.into() };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Evacuate every live session of the pool into a `PFRMBNDL` blob;
    /// returns (session count, bundle bytes).
    pub fn drain_export(&mut self, pool: &str) -> Result<(u64, Vec<u8>)> {
        match self.call(&Msg::DrainExport { pool: pool.into() })? {
            Msg::Export { sessions, bundle } => Ok((sessions, bundle)),
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected an export frame, got {}", other.name()),
        }
    }

    /// Hand a `PFRMBNDL` blob to the peer for adoption; returns the
    /// sessions adopted.
    pub fn restore_bundle(&mut self, pool: &str, bundle: Vec<u8>) -> Result<usize> {
        let msg = Msg::RestoreBundle { pool: pool.into(), bundle };
        Ok(self.call(&msg)?.into_ok()? as usize)
    }

    /// Ask a router to live-rebalance: drain shard `from` into shard
    /// `to`; returns the sessions moved.
    pub fn admin_drain(&mut self, pool: &str, from: u32, to: u32) -> Result<u64> {
        self.call(&Msg::AdminDrain { pool: pool.into(), from, to })?.into_ok()
    }

    /// One-shot fill-mask through a batched pool; returns the filled
    /// sequence plus `(position, token, probability)` predictions.
    #[allow(clippy::type_complexity)]
    pub fn fill_mask(
        &mut self,
        model: &str,
        tokens: Vec<u8>,
    ) -> Result<(Vec<u8>, Vec<(usize, u8, f32)>)> {
        match self.call(&Msg::FillMask { model: model.into(), tokens })? {
            Msg::Filled { filled, positions, tokens, probs } => {
                let preds = positions
                    .into_iter()
                    .zip(tokens)
                    .zip(probs)
                    .map(|((p, t), pr)| (p as usize, t, pr))
                    .collect();
                Ok((filled, preds))
            }
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected a filled frame, got {}", other.name()),
        }
    }
}

impl Msg {
    /// Unwrap an [`Msg::Ok`] reply into its affected count.
    fn into_ok(self) -> Result<u64> {
        match self {
            Msg::Ok { affected } => Ok(affected),
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected an ok frame, got {}", other.name()),
        }
    }
}
