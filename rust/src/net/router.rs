//! The shard router: a front process that hashes session ids onto N
//! worker servers, forwards frames, and live-rebalances shards without
//! dropping a token of context.
//!
//! **Placement** is slot-based consistent routing: a session maps to
//! one of [`ROUTE_SLOTS`] slots via `fnv1a64(session) % ROUTE_SLOTS`,
//! and a slot table maps slots to shard indices (initially
//! `slot % n_shards`). Rebalancing rewrites slot entries, never the
//! hash — so sessions that are not being moved keep their placement.
//!
//! **Rebalance** (`admin-drain from to`) is a barrier + migrate + flip:
//! forwards hold the routing table's read lock *across the whole
//! backend round trip*, so the drain's write lock acquires only once
//! every in-flight request has been answered — the victim's export is
//! then guaranteed to capture every chunk the router ever admitted for
//! it. Under the write lock the router asks the victim to
//! [`Msg::DrainExport`] (checkpoint-all + close, answered as one
//! `PFRMBNDL` blob), ships the blob to the target via
//! [`Msg::RestoreBundle`], and only then rewrites the victim's slots —
//! an atomic flip from the clients' point of view. If the target
//! refuses the bundle, the router restores it back into the victim, so
//! a failed rebalance strands no sessions. Drain-on-shutdown is the
//! same path: evacuate the shard, then kill the process.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::rng::fnv1a64;

use super::client::Client;
use super::proto::{read_frame, write_frame, Msg};

/// Number of routing slots sessions hash onto. Plenty for tens of
/// shards while keeping the table trivially small.
pub const ROUTE_SLOTS: usize = 64;

/// The slot table: which shard serves which slice of session space.
pub struct RoutingTable {
    shards: Vec<String>,
    slots: Vec<usize>,
}

impl RoutingTable {
    /// A table over `shards` (worker addresses), slots dealt
    /// round-robin (`slot % n`).
    pub fn new(shards: Vec<String>) -> Result<RoutingTable> {
        ensure!(!shards.is_empty(), "a router needs at least one shard");
        let n = shards.len();
        let slots = (0..ROUTE_SLOTS).map(|i| i % n).collect();
        Ok(RoutingTable { shards, slots })
    }

    /// The slot a session id hashes onto (placement-stable: depends
    /// only on the id).
    pub fn slot_of(session: &str) -> usize {
        (fnv1a64(session.as_bytes()) % ROUTE_SLOTS as u64) as usize
    }

    /// The shard index currently serving a session.
    pub fn shard_of(&self, session: &str) -> usize {
        self.slots[Self::slot_of(session)]
    }

    /// A shard's worker address.
    pub fn addr_of(&self, shard: usize) -> &str {
        &self.shards[shard]
    }

    /// Number of shards in the table.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point every slot of `from` at `to`; returns how many slots
    /// moved.
    pub fn reassign(&mut self, from: usize, to: usize) -> usize {
        let mut moved = 0;
        for s in self.slots.iter_mut() {
            if *s == from {
                *s = to;
                moved += 1;
            }
        }
        moved
    }
}

/// The router's own instruments (it runs in its own process, so it has
/// its own registry rather than a coordinator's).
pub struct RouterMetrics {
    /// frames forwarded to a shard
    pub forwarded: Counter,
    /// live rebalances performed
    pub drains: Counter,
    /// requests answered with an error frame
    pub errors: Counter,
    /// end-to-end forward latency (client frame in → reply out), µs
    pub latency_us: Histogram,
}

impl RouterMetrics {
    fn registered(reg: &MetricsRegistry) -> RouterMetrics {
        RouterMetrics {
            forwarded: reg.counter("route_forwarded_total"),
            drains: reg.counter("route_drains_total"),
            errors: reg.counter("route_errors_total"),
            latency_us: reg.histogram("route_latency_us"),
        }
    }
}

/// A running shard router. Dropping it stops the acceptor.
pub struct Router {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    metrics: Arc<RouterMetrics>,
    registry: Arc<MetricsRegistry>,
}

impl Router {
    /// Bind `addr` and route sessions across `shards` (worker
    /// addresses).
    pub fn start(addr: &str, shards: Vec<String>) -> Result<Router> {
        let table = Arc::new(RwLock::new(RoutingTable::new(shards)?));
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding router to {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let registry = Arc::new(MetricsRegistry::new());
        let metrics = Arc::new(RouterMetrics::registered(&registry));
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_metrics = metrics.clone();
        let acceptor = std::thread::Builder::new().name("route-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let table = table.clone();
                let metrics = accept_metrics.clone();
                let _ = std::thread::Builder::new()
                    .name("route-conn".into())
                    .spawn(move || handle_conn(stream, &table, &metrics));
            }
        })?;
        Ok(Router { local_addr, stop, acceptor: Some(acceptor), metrics, registry })
    }

    /// The address the router actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The router's instruments.
    pub fn metrics(&self) -> Arc<RouterMetrics> {
        self.metrics.clone()
    }

    /// The router's metrics registry (for a Prometheus dump).
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        self.registry.clone()
    }

    /// Stop accepting new connections.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(
    mut stream: TcpStream,
    table: &RwLock<RoutingTable>,
    metrics: &RouterMetrics,
) {
    let _ = stream.set_nodelay(true);
    // backend connections are cached per worker address for the
    // lifetime of this client connection
    let mut backends: HashMap<String, TcpStream> = HashMap::new();
    loop {
        let Ok((id, msg)) = read_frame(&mut stream) else { break };
        let t0 = Instant::now();
        let reply = match &msg {
            Msg::Open { session, .. }
            | Msg::Submit { session, .. }
            | Msg::Close { session, .. } => {
                // hold the read lock across the round trip: a drain's
                // write lock then waits for every in-flight forward —
                // that is the rebalance barrier
                let guard = table.read().unwrap();
                let addr = guard.addr_of(guard.shard_of(session)).to_string();
                metrics.forwarded.inc();
                forward(&mut backends, &addr, id, &msg)
            }
            // no session to hash: pin by model name so repeat requests
            // hit the same worker's warm pool
            Msg::FillMask { model, .. } => {
                let guard = table.read().unwrap();
                let addr = guard.addr_of(guard.shard_of(model)).to_string();
                metrics.forwarded.inc();
                forward(&mut backends, &addr, id, &msg)
            }
            Msg::AdminDrain { pool, from, to } => {
                match drain(table, pool, *from as usize, *to as usize) {
                    Ok(moved) => {
                        metrics.drains.inc();
                        Msg::Ok { affected: moved }
                    }
                    Err(e) => Msg::Error { message: format!("{e:#}") },
                }
            }
            other => Msg::Error {
                message: format!("router cannot route a {} frame", other.name()),
            },
        };
        if matches!(reply, Msg::Error { .. }) {
            metrics.errors.inc();
        }
        metrics.latency_us.observe_duration(t0.elapsed());
        if write_frame(&mut stream, id, &reply).is_err() {
            break;
        }
    }
}

/// Forward one frame to a worker and relay its reply (including
/// `RetryAfter` — backpressure propagates to the client untouched). A
/// dead cached connection is dropped and retried once fresh.
fn forward(backends: &mut HashMap<String, TcpStream>, addr: &str, id: u64, msg: &Msg) -> Msg {
    for fresh in [false, true] {
        if fresh {
            backends.remove(addr);
        }
        match try_forward(backends, addr, id, msg) {
            Ok(reply) => return reply,
            Err(_) if !fresh => continue,
            Err(e) => return Msg::Error { message: format!("shard {addr} unreachable: {e:#}") },
        }
    }
    unreachable!("the fresh attempt either returned or errored")
}

fn try_forward(
    backends: &mut HashMap<String, TcpStream>,
    addr: &str,
    id: u64,
    msg: &Msg,
) -> Result<Msg> {
    if !backends.contains_key(addr) {
        let s = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = s.set_nodelay(true);
        backends.insert(addr.to_string(), s);
    }
    let s = backends.get_mut(addr).expect("just inserted");
    write_frame(s, id, msg)?;
    let (rid, reply) = read_frame(s)?;
    ensure!(rid == id, "shard {addr} answered request {rid}, expected {id}");
    Ok(reply)
}

/// Live rebalance under the table's write lock: export the victim,
/// adopt into the target, flip the slots. See the module docs for the
/// barrier argument and the failure-rollback contract.
fn drain(table: &RwLock<RoutingTable>, pool: &str, from: usize, to: usize) -> Result<u64> {
    let mut t = table.write().unwrap();
    ensure!(from != to, "drain source and target are both shard {from}");
    let n = t.n_shards();
    ensure!(from < n && to < n, "shard index out of range (have {n} shards)");
    let victim = t.addr_of(from).to_string();
    let target = t.addr_of(to).to_string();

    let mut vc = Client::connect_retry(&victim, Duration::from_secs(5))
        .with_context(|| format!("reaching drain victim shard {from}"))?;
    let (sessions, bundle) = vc
        .drain_export(pool)
        .with_context(|| format!("evacuating shard {from} ({victim})"))?;

    let adopt = Client::connect_retry(&target, Duration::from_secs(5))
        .and_then(|mut tc| tc.restore_bundle(pool, bundle.clone()));
    let adopted = match adopt {
        Ok(n) => n,
        Err(e) => {
            // the victim already closed its sessions; put them back so
            // a failed rebalance strands nothing
            let rollback = vc.restore_bundle(pool, bundle);
            let note = match rollback {
                Ok(_) => "sessions restored to the victim",
                Err(_) => "rollback to the victim ALSO failed — bundle lost",
            };
            return Err(e).with_context(|| format!("target shard {to} refused the bundle; {note}"));
        }
    };
    ensure!(
        adopted as u64 == sessions,
        "victim exported {sessions} session(s) but target adopted {adopted}"
    );
    t.reassign(from, to);
    Ok(sessions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_deal_round_robin_and_reassign_moves_them() {
        let mut t = RoutingTable::new(vec!["a".into(), "b".into()]).unwrap();
        assert_eq!(t.n_shards(), 2);
        let on_b = (0..ROUTE_SLOTS).filter(|i| i % 2 == 1).count();
        let moved = t.reassign(1, 0);
        assert_eq!(moved, on_b);
        assert_eq!(t.shard_of("user-0"), 0, "every session routes to shard 0 after the move");
        assert_eq!(t.reassign(1, 0), 0, "shard 1 already empty");
    }

    /// The CI multi-process smoke drains shard 0 into shard 1 and then
    /// kills shard 0's worker, relying on the workload's two sessions
    /// landing one per shard. Pin that placement so a hash or slot
    /// change shows up here, not as a flaky smoke.
    #[test]
    fn smoke_workload_placement_is_pinned() {
        let t = RoutingTable::new(vec!["a".into(), "b".into()]).unwrap();
        assert_eq!(RoutingTable::slot_of("user-0"), 7);
        assert_eq!(RoutingTable::slot_of("user-1"), 20);
        assert_eq!(t.shard_of("user-0"), 1);
        assert_eq!(t.shard_of("user-1"), 0);
    }
}
