//! The shard router: a front process that hashes session ids onto N
//! worker servers, forwards frames, and live-rebalances shards without
//! dropping a token of context.
//!
//! **Placement** is slot-based consistent routing: a session maps to
//! one of [`ROUTE_SLOTS`] slots via `fnv1a64(session) % ROUTE_SLOTS`,
//! and a slot table maps slots to shard indices (initially
//! `slot % n_shards`). Rebalancing rewrites slot entries, never the
//! hash — so sessions that are not being moved keep their placement.
//!
//! **Forwarding** goes through a shared [`BackendPool`]: backend
//! connections are checked out per forward and checked back in after,
//! capped per address with stale-idle reaping — so a thousand client
//! connections share a handful of worker sockets instead of opening
//! one each. A frame error on a pooled connection evicts it and
//! retries once on a fresh dial before the client sees an error.
//!
//! **Coalescing**: same-shard [`Msg::Submit`]s that arrive within a
//! short batch window are merged into one [`Msg::SubmitBatch`] forward
//! (per-entry replies fan back out to the individual clients), so N
//! concurrent clients cost the backend one round trip and one fused
//! wave instead of N. The read loops never block on a backend: submit
//! replies complete on per-connection completer threads in whatever
//! order the shards answer, tagged by request-id.
//!
//! **Rebalance** (`admin-drain from to`) is a barrier + migrate + flip.
//! Every forward **registers** with its shard — a per-shard in-flight
//! counter incremented under the routing table's read lock, released
//! when the backend answers. The drain takes the table's write lock
//! (so no new forward can resolve a shard) and then waits for the
//! victim's counter to reach zero: every admitted request — including
//! those parked in a coalescing window — has been answered before the
//! export begins, so the victim's bundle captures every chunk the
//! router ever admitted for it. The counter replaces PR 8's
//! read-lock-held-across-the-round-trip barrier with the same
//! guarantee at a fraction of the contention: the read lock is now
//! held only for the table lookup, not the backend round trip. Under
//! the write lock the router asks the victim to [`Msg::DrainExport`]
//! (checkpoint-all + close, answered as one `PFRMBNDL` blob), ships
//! the blob to the target via [`Msg::RestoreBundle`], and only then
//! rewrites the victim's slots — an atomic flip from the clients'
//! point of view. If the target refuses the bundle, the router
//! restores it back into the victim, so a failed rebalance strands no
//! sessions.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::obs::{Counter, Histogram, MetricsRegistry};
use crate::rng::fnv1a64;

use super::client::Client;
use super::proto::{read_frame, write_frame, Msg, ScoreEntry};

/// Number of routing slots sessions hash onto. Plenty for tens of
/// shards while keeping the table trivially small.
pub const ROUTE_SLOTS: usize = 64;

/// Most pending submit replies per client connection before its read
/// loop stops draining the socket (mirrors the server's bound).
const MAX_CONN_INFLIGHT: usize = 64;

/// The slot table: which shard serves which slice of session space.
pub struct RoutingTable {
    shards: Vec<String>,
    slots: Vec<usize>,
}

impl RoutingTable {
    /// A table over `shards` (worker addresses), slots dealt
    /// round-robin (`slot % n`).
    pub fn new(shards: Vec<String>) -> Result<RoutingTable> {
        ensure!(!shards.is_empty(), "a router needs at least one shard");
        let n = shards.len();
        let slots = (0..ROUTE_SLOTS).map(|i| i % n).collect();
        Ok(RoutingTable { shards, slots })
    }

    /// The slot a session id hashes onto (placement-stable: depends
    /// only on the id).
    pub fn slot_of(session: &str) -> usize {
        (fnv1a64(session.as_bytes()) % ROUTE_SLOTS as u64) as usize
    }

    /// The shard index currently serving a session.
    pub fn shard_of(&self, session: &str) -> usize {
        self.slots[Self::slot_of(session)]
    }

    /// A shard's worker address.
    pub fn addr_of(&self, shard: usize) -> &str {
        &self.shards[shard]
    }

    /// Number of shards in the table.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point every slot of `from` at `to`; returns how many slots
    /// moved.
    pub fn reassign(&mut self, from: usize, to: usize) -> usize {
        let mut moved = 0;
        for s in self.slots.iter_mut() {
            if *s == from {
                *s = to;
                moved += 1;
            }
        }
        moved
    }
}

/// Tuning knobs of a [`Router`].
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// most idle backend connections kept per worker address; a
    /// checkin over the cap closes the socket instead
    pub pool_size: usize,
    /// idle age beyond which a pooled connection is reaped at checkout
    /// instead of reused
    pub idle_max: Duration,
    /// most same-shard submits coalesced into one `SubmitBatch`
    /// forward (1 disables coalescing; default matches the worker's
    /// fused-wave width)
    pub max_coalesce: usize,
    /// how long the coalescer holds a window open for same-shard
    /// company after its first submit
    pub coalesce_window: Duration,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            pool_size: 4,
            idle_max: Duration::from_secs(30),
            max_coalesce: crate::coordinator::STREAM_MAX_BATCH,
            coalesce_window: Duration::from_millis(1),
        }
    }
}

/// The router's own instruments (it runs in its own process, so it has
/// its own registry rather than a coordinator's).
pub struct RouterMetrics {
    /// frames forwarded to a shard
    pub forwarded: Counter,
    /// live rebalances performed
    pub drains: Counter,
    /// requests answered with an error frame
    pub errors: Counter,
    /// end-to-end forward latency (client frame in → reply out), µs
    pub latency_us: Histogram,
    /// backend connections dialed
    pub pool_dials: Counter,
    /// forwards served on a reused pooled connection
    pub pool_reuses: Counter,
    /// pooled connections evicted (frame error or stale idle)
    pub pool_evictions: Counter,
    /// submits merged into a coalesced `SubmitBatch` forward
    pub coalesced: Counter,
    /// coalesced `SubmitBatch` frames forwarded
    pub batches: Counter,
}

impl RouterMetrics {
    /// Instruments registered under `route_*` in `reg`.
    pub fn registered(reg: &MetricsRegistry) -> RouterMetrics {
        RouterMetrics {
            forwarded: reg.counter("route_forwarded_total"),
            drains: reg.counter("route_drains_total"),
            errors: reg.counter("route_errors_total"),
            latency_us: reg.histogram("route_latency_us"),
            pool_dials: reg.counter("route_pool_dials_total"),
            pool_reuses: reg.counter("route_pool_reuses_total"),
            pool_evictions: reg.counter("route_pool_evictions_total"),
            coalesced: reg.counter("route_coalesced_total"),
            batches: reg.counter("route_batches_total"),
        }
    }
}

/// Shared checkout/checkin pool of backend worker connections: capped
/// idle list per address, stale-idle reap at checkout, and
/// evict + one fresh retry on frame errors — a dead pooled socket
/// costs a reconnect, never a client-visible error.
pub struct BackendPool {
    idle: Mutex<HashMap<String, Vec<(TcpStream, Instant)>>>,
    cap: usize,
    idle_max: Duration,
    metrics: Arc<RouterMetrics>,
}

impl BackendPool {
    /// An empty pool keeping at most `cap` idle connections per
    /// address, reaping those idle longer than `idle_max`.
    pub fn new(cap: usize, idle_max: Duration, metrics: Arc<RouterMetrics>) -> BackendPool {
        BackendPool { idle: Mutex::new(HashMap::new()), cap, idle_max, metrics }
    }

    /// A connection to `addr`: the freshest non-stale idle one, else a
    /// new dial. Stale idles encountered on the way are dropped.
    fn checkout(&self, addr: &str) -> Result<TcpStream> {
        {
            let mut idle = self.idle.lock().unwrap();
            if let Some(conns) = idle.get_mut(addr) {
                while let Some((conn, since)) = conns.pop() {
                    if since.elapsed() > self.idle_max {
                        // too old to trust: the peer may have closed it
                        self.metrics.pool_evictions.inc();
                        continue;
                    }
                    self.metrics.pool_reuses.inc();
                    return Ok(conn);
                }
            }
        }
        self.dial(addr)
    }

    fn dial(&self, addr: &str) -> Result<TcpStream> {
        let conn =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = conn.set_nodelay(true);
        self.metrics.pool_dials.inc();
        Ok(conn)
    }

    /// Return a healthy connection for reuse; over-cap checkins close
    /// the socket instead.
    fn checkin(&self, addr: &str, conn: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        let conns = idle.entry(addr.to_string()).or_default();
        if conns.len() < self.cap {
            conns.push((conn, Instant::now()));
        }
    }

    /// Round-trip one frame to `addr` and return the reply. A frame
    /// error on the first (possibly pooled) connection evicts it and
    /// retries exactly once on a fresh dial; only a second failure
    /// reaches the caller as an error frame.
    pub fn forward(&self, addr: &str, msg: &Msg) -> Msg {
        // backend-side ids come from one process-wide sequence: replies
        // on a pooled connection can never be attributed to the wrong
        // forward even if a stale reply were ever left behind
        static BACKEND_ID: AtomicU64 = AtomicU64::new(1);
        for fresh in [false, true] {
            let id = BACKEND_ID.fetch_add(1, Ordering::Relaxed);
            let conn = if fresh { self.dial(addr) } else { self.checkout(addr) };
            let mut conn = match conn {
                Ok(c) => c,
                Err(_) if !fresh => continue,
                Err(e) => {
                    return Msg::Error { message: format!("shard {addr} unreachable: {e:#}") }
                }
            };
            match round_trip(&mut conn, id, msg) {
                Ok(reply) => {
                    self.checkin(addr, conn);
                    return reply;
                }
                Err(_) if !fresh => {
                    // the pooled socket was dead or desynced: drop it
                    // (eviction) and retry once on a fresh dial
                    self.metrics.pool_evictions.inc();
                }
                Err(e) => {
                    return Msg::Error { message: format!("shard {addr} unreachable: {e:#}") }
                }
            }
        }
        unreachable!("the fresh attempt either returned or errored")
    }
}

fn round_trip(conn: &mut TcpStream, id: u64, msg: &Msg) -> Result<Msg> {
    write_frame(conn, id, msg)?;
    let (rid, reply) = read_frame(conn)?;
    ensure!(rid == id, "backend answered request {rid}, expected {id}");
    Ok(reply)
}

/// Per-shard in-flight counter: forwards register while admitted, the
/// drain waits for zero. See the module docs for the barrier argument.
struct ShardInflight {
    n: Mutex<usize>,
    cv: Condvar,
}

/// RAII registration of one forward with its shard's counter.
struct InflightGuard {
    shard: Arc<ShardInflight>,
}

impl InflightGuard {
    fn enter(shard: &Arc<ShardInflight>) -> InflightGuard {
        *shard.n.lock().unwrap() += 1;
        InflightGuard { shard: shard.clone() }
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let mut n = self.shard.n.lock().unwrap();
        *n = n.saturating_sub(1);
        if *n == 0 {
            self.shard.cv.notify_all();
        }
    }
}

/// One submit parked in a shard's coalescing window.
struct CoalesceEntry {
    pool: String,
    session: String,
    tokens: Vec<u8>,
    reply: Sender<Msg>,
    /// keeps the forward registered with its shard until answered —
    /// a drain's barrier covers entries still parked in the window
    _guard: InflightGuard,
}

/// Everything a connection thread needs, shared router-wide.
struct Shared {
    table: RwLock<RoutingTable>,
    /// one counter per shard index, fixed at start
    inflight: Vec<Arc<ShardInflight>>,
    pool: BackendPool,
    /// one coalescer worker per backend address, spawned lazily;
    /// cleared on shutdown so the workers exit
    coalescers: Mutex<HashMap<String, Sender<CoalesceEntry>>>,
    cfg: RouterConfig,
    metrics: Arc<RouterMetrics>,
}

impl Shared {
    /// Resolve a key's shard and register the forward with it, under
    /// one read-lock acquisition — the admission point the drain
    /// barrier is defined against.
    fn admit(&self, key: &str) -> (String, InflightGuard) {
        let t = self.table.read().unwrap();
        let shard = t.shard_of(key);
        let guard = InflightGuard::enter(&self.inflight[shard]);
        (t.addr_of(shard).to_string(), guard)
    }

    /// The coalescer feeding `addr`, spawned on first use.
    fn coalescer(self: &Arc<Self>, addr: &str) -> Result<Sender<CoalesceEntry>> {
        let mut map = self.coalescers.lock().unwrap();
        if let Some(tx) = map.get(addr) {
            return Ok(tx.clone());
        }
        let (tx, rx) = channel();
        let shared = self.clone();
        let addr_owned = addr.to_string();
        std::thread::Builder::new()
            .name("route-coalesce".into())
            .spawn(move || coalesce_loop(&rx, &addr_owned, &shared))
            .context("spawning a coalescer")?;
        map.insert(addr.to_string(), tx.clone());
        Ok(tx)
    }

    /// Wait until shard `shard` has zero registered forwards. Called
    /// with the table's write lock held, so no new forward can
    /// register while we wait.
    fn wait_idle(&self, shard: usize) {
        let s = &self.inflight[shard];
        let mut n = s.n.lock().unwrap();
        while *n > 0 {
            n = s.cv.wait(n).unwrap();
        }
    }
}

/// A running shard router. Dropping it stops the acceptor.
pub struct Router {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    registry: Arc<MetricsRegistry>,
}

impl Router {
    /// Bind `addr` and route sessions across `shards` (worker
    /// addresses) with default tuning.
    pub fn start(addr: &str, shards: Vec<String>) -> Result<Router> {
        Self::start_with(addr, shards, RouterConfig::default())
    }

    /// Bind `addr` and route sessions across `shards` with explicit
    /// tuning.
    pub fn start_with(addr: &str, shards: Vec<String>, cfg: RouterConfig) -> Result<Router> {
        let table = RoutingTable::new(shards)?;
        let n_shards = table.n_shards();
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding router to {addr}"))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let registry = Arc::new(MetricsRegistry::new());
        let metrics = Arc::new(RouterMetrics::registered(&registry));
        let shared = Arc::new(Shared {
            table: RwLock::new(table),
            inflight: (0..n_shards)
                .map(|_| Arc::new(ShardInflight { n: Mutex::new(0), cv: Condvar::new() }))
                .collect(),
            pool: BackendPool::new(
                cfg.pool_size.max(1),
                cfg.idle_max,
                metrics.clone(),
            ),
            coalescers: Mutex::new(HashMap::new()),
            cfg,
            metrics,
        });
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_shared = shared.clone();
        let acceptor = std::thread::Builder::new().name("route-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let shared = accept_shared.clone();
                let _ = std::thread::Builder::new()
                    .name("route-conn".into())
                    .spawn(move || handle_conn(stream, &shared));
            }
        })?;
        Ok(Router { local_addr, stop, acceptor: Some(acceptor), shared, registry })
    }

    /// The address the router actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The router's instruments.
    pub fn metrics(&self) -> Arc<RouterMetrics> {
        self.shared.metrics.clone()
    }

    /// The router's metrics registry (for a Prometheus dump).
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        self.registry.clone()
    }

    /// Stop accepting new connections and retire the coalescer
    /// workers.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // dropping the senders ends each coalescer's recv loop
        self.shared.coalescers.lock().unwrap().clear();
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One submit's pending reply, as seen by the completer thread.
enum RouteJob {
    /// a plain forwarded submit
    One { id: u64, rx: Receiver<Msg>, t0: Instant },
    /// a client `SubmitBatch` split per-entry across shards and
    /// reassembled in order
    Batch { id: u64, entries: Vec<(String, Receiver<Msg>)>, t0: Instant },
}

fn handle_conn(mut stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else { return };
    let writer = Arc::new(Mutex::new(write_half));
    let (jobs_tx, jobs_rx) = sync_channel::<RouteJob>(MAX_CONN_INFLIGHT);
    let completer = {
        let writer = writer.clone();
        let metrics = shared.metrics.clone();
        std::thread::Builder::new().name("route-complete".into()).spawn(move || {
            for job in jobs_rx {
                finish_job(job, &writer, &metrics);
            }
        })
    };
    let Ok(completer) = completer else { return };
    loop {
        let Ok((id, msg)) = read_frame(&mut stream) else { break };
        let t0 = Instant::now();
        match msg {
            Msg::Submit { pool, session, tokens } => {
                shared.metrics.forwarded.inc();
                let (addr, guard) = shared.admit(&session);
                let (reply_tx, reply_rx) = channel();
                let entry = CoalesceEntry {
                    pool,
                    session,
                    tokens,
                    reply: reply_tx,
                    _guard: guard,
                };
                enqueue_entry(shared, &addr, entry);
                if jobs_tx.send(RouteJob::One { id, rx: reply_rx, t0 }).is_err() {
                    break;
                }
            }
            Msg::SubmitBatch { pool, entries } => {
                // split per-entry across shards; every entry registers
                // with its shard under ONE read-lock acquisition, so a
                // concurrent drain either sees all of them or none
                let mut parked = Vec::with_capacity(entries.len());
                let mut slots = Vec::with_capacity(entries.len());
                {
                    let t = shared.table.read().unwrap();
                    for (session, tokens) in entries {
                        shared.metrics.forwarded.inc();
                        let shard = t.shard_of(&session);
                        let guard = InflightGuard::enter(&shared.inflight[shard]);
                        let addr = t.addr_of(shard).to_string();
                        let (reply_tx, reply_rx) = channel();
                        slots.push((session.clone(), reply_rx));
                        parked.push((
                            addr,
                            CoalesceEntry {
                                pool: pool.clone(),
                                session,
                                tokens,
                                reply: reply_tx,
                                _guard: guard,
                            },
                        ));
                    }
                }
                for (addr, entry) in parked {
                    enqueue_entry(shared, &addr, entry);
                }
                if jobs_tx.send(RouteJob::Batch { id, entries: slots, t0 }).is_err() {
                    break;
                }
            }
            Msg::Open { ref session, .. } | Msg::Close { ref session, .. } => {
                let (addr, _guard) = shared.admit(session);
                let reply = shared.pool.forward(&addr, &msg);
                shared.metrics.forwarded.inc();
                if finish_inline(shared, &writer, id, &reply, t0).is_err() {
                    break;
                }
            }
            // no session to hash: pin by model name so repeat requests
            // hit the same worker's warm pool
            Msg::FillMask { ref model, .. } => {
                let (addr, _guard) = shared.admit(model);
                let reply = shared.pool.forward(&addr, &msg);
                shared.metrics.forwarded.inc();
                if finish_inline(shared, &writer, id, &reply, t0).is_err() {
                    break;
                }
            }
            Msg::AdminDrain { pool, from, to } => {
                let reply = match drain(shared, &pool, from as usize, to as usize) {
                    Ok(moved) => {
                        shared.metrics.drains.inc();
                        Msg::Ok { affected: moved }
                    }
                    Err(e) => Msg::Error { message: format!("{e:#}") },
                };
                if finish_inline(shared, &writer, id, &reply, t0).is_err() {
                    break;
                }
            }
            other => {
                let reply = Msg::Error {
                    message: format!("router cannot route a {} frame", other.name()),
                };
                if finish_inline(shared, &writer, id, &reply, t0).is_err() {
                    break;
                }
            }
        }
    }
    drop(jobs_tx);
    let _ = completer.join();
}

/// Hand one submit to its shard's coalescer; a coalescer that cannot
/// be reached answers the entry with an error instead of dropping it.
fn enqueue_entry(shared: &Arc<Shared>, addr: &str, entry: CoalesceEntry) {
    let sent = match shared.coalescer(addr) {
        Ok(tx) => tx.send(entry).map_err(|e| e.0),
        Err(_) => Err(entry),
    };
    if let Err(entry) = sent {
        let _ = entry.reply.send(Msg::Error {
            message: format!("router lost its forwarding lane to {addr}"),
        });
    }
}

/// Write an inline (non-pipelined) reply and record its metrics.
fn finish_inline(
    shared: &Arc<Shared>,
    writer: &Mutex<TcpStream>,
    id: u64,
    reply: &Msg,
    t0: Instant,
) -> Result<()> {
    if matches!(reply, Msg::Error { .. }) {
        shared.metrics.errors.inc();
    }
    shared.metrics.latency_us.observe_duration(t0.elapsed());
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, id, reply)
}

/// Complete one pending submit reply on the completer thread.
fn finish_job(job: RouteJob, writer: &Mutex<TcpStream>, metrics: &RouterMetrics) {
    match job {
        RouteJob::One { id, rx, t0 } => {
            let reply = rx.recv().unwrap_or(Msg::Error {
                message: "router dropped the forwarded request".into(),
            });
            if matches!(reply, Msg::Error { .. }) {
                metrics.errors.inc();
            }
            metrics.latency_us.observe_duration(t0.elapsed());
            let mut w = writer.lock().unwrap();
            let _ = write_frame(&mut *w, id, &reply);
        }
        RouteJob::Batch { id, entries, t0 } => {
            let entries: Vec<ScoreEntry> = entries
                .into_iter()
                .map(|(session, rx)| match rx.recv() {
                    Ok(Msg::Scores { session, offset, logprob, argmax, argmax_prob }) => {
                        ScoreEntry::Scores { session, offset, logprob, argmax, argmax_prob }
                    }
                    Ok(Msg::Error { message }) => ScoreEntry::failed(&session, message),
                    // a whole-batch client retry cannot be offered once
                    // entries span shards (some may have served);
                    // surface the shed per-entry instead
                    Ok(Msg::RetryAfter { millis }) => ScoreEntry::failed(
                        &session,
                        format!("shard busy (retry-after hint {millis} ms)"),
                    ),
                    Ok(other) => ScoreEntry::failed(
                        &session,
                        format!("unexpected {} reply to a submit", other.name()),
                    ),
                    Err(_) => {
                        ScoreEntry::failed(&session, "router dropped the forwarded request")
                    }
                })
                .collect();
            if entries.iter().any(|e| matches!(e, ScoreEntry::Failed { .. })) {
                metrics.errors.inc();
            }
            metrics.latency_us.observe_duration(t0.elapsed());
            let mut w = writer.lock().unwrap();
            let _ = write_frame(&mut *w, id, &Msg::ScoresBatch { entries });
        }
    }
}

/// One shard's coalescer: batch same-shard submits arriving within the
/// window, forward one frame, fan the per-entry replies back out.
fn coalesce_loop(rx: &Receiver<CoalesceEntry>, addr: &str, shared: &Arc<Shared>) {
    let window = shared.cfg.coalesce_window;
    let cap = shared.cfg.max_coalesce.max(1);
    while let Ok(first) = rx.recv() {
        let mut batch = vec![first];
        let deadline = Instant::now() + window;
        while batch.len() < cap {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(entry) => batch.push(entry),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        flush_window(addr, batch, shared);
    }
}

/// Forward one coalescing window: group by stream pool (order
/// preserved within a group), single entries as plain submits, groups
/// as one `SubmitBatch`, and distribute the per-entry outcomes.
fn flush_window(addr: &str, batch: Vec<CoalesceEntry>, shared: &Arc<Shared>) {
    let mut groups: Vec<(String, Vec<CoalesceEntry>)> = Vec::new();
    for entry in batch {
        match groups.iter_mut().find(|(pool, _)| *pool == entry.pool) {
            Some((_, v)) => v.push(entry),
            None => groups.push((entry.pool.clone(), vec![entry])),
        }
    }
    for (pool, entries) in groups {
        if entries.len() == 1 {
            let entry = &entries[0];
            let msg = Msg::Submit {
                pool,
                session: entry.session.clone(),
                tokens: entry.tokens.clone(),
            };
            let reply = shared.pool.forward(addr, &msg);
            let _ = entry.reply.send(reply);
            continue;
        }
        shared.metrics.batches.inc();
        shared.metrics.coalesced.add(entries.len() as u64);
        let frame = Msg::SubmitBatch {
            pool,
            entries: entries
                .iter()
                .map(|e| (e.session.clone(), e.tokens.clone()))
                .collect(),
        };
        match shared.pool.forward(addr, &frame) {
            Msg::ScoresBatch { entries: replies } if replies.len() == entries.len() => {
                for (entry, outcome) in entries.iter().zip(replies) {
                    let _ = entry.reply.send(outcome.into_msg());
                }
            }
            // a whole-frame shed or error answered the *batch*: every
            // merged client gets it verbatim — the worker's batch
            // admission is all-or-nothing, so none of them advanced
            whole @ (Msg::RetryAfter { .. } | Msg::Error { .. }) => {
                for entry in &entries {
                    let _ = entry.reply.send(whole.clone());
                }
            }
            other => {
                let msg = Msg::Error {
                    message: format!("unexpected {} reply to a submit-batch", other.name()),
                };
                for entry in &entries {
                    let _ = entry.reply.send(msg.clone());
                }
            }
        }
    }
}

/// Live rebalance: write-lock the table (no new forward resolves),
/// wait the victim's in-flight counter down to zero (every admitted
/// forward answered — the barrier), export the victim, adopt into the
/// target, flip the slots. See the module docs for the full argument
/// and the failure-rollback contract.
fn drain(shared: &Arc<Shared>, pool: &str, from: usize, to: usize) -> Result<u64> {
    let mut t = shared.table.write().unwrap();
    ensure!(from != to, "drain source and target are both shard {from}");
    let n = t.n_shards();
    ensure!(from < n && to < n, "shard index out of range (have {n} shards)");
    let victim = t.addr_of(from).to_string();
    let target = t.addr_of(to).to_string();

    // the barrier: every forward admitted before the write lock —
    // including submits still parked in a coalescing window, which
    // hold their registration until answered — completes before the
    // export below runs
    shared.wait_idle(from);

    // the migration control plane uses its own dedicated connection:
    // pooled data-plane sockets stay untouched
    let mut vc = Client::connect_retry(&victim, Duration::from_secs(5))
        .with_context(|| format!("reaching drain victim shard {from}"))?;
    let (sessions, bundle) = vc
        .drain_export(pool)
        .with_context(|| format!("evacuating shard {from} ({victim})"))?;

    let adopt = Client::connect_retry(&target, Duration::from_secs(5))
        .and_then(|mut tc| tc.restore_bundle(pool, bundle.clone()));
    let adopted = match adopt {
        Ok(n) => n,
        Err(e) => {
            // the victim already closed its sessions; put them back so
            // a failed rebalance strands nothing
            let rollback = vc.restore_bundle(pool, bundle);
            let note = match rollback {
                Ok(_) => "sessions restored to the victim",
                Err(_) => "rollback to the victim ALSO failed — bundle lost",
            };
            return Err(e).with_context(|| format!("target shard {to} refused the bundle; {note}"));
        }
    };
    ensure!(
        adopted as u64 == sessions,
        "victim exported {sessions} session(s) but target adopted {adopted}"
    );
    t.reassign(from, to);
    Ok(sessions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_deal_round_robin_and_reassign_moves_them() {
        let mut t = RoutingTable::new(vec!["a".into(), "b".into()]).unwrap();
        assert_eq!(t.n_shards(), 2);
        let on_b = (0..ROUTE_SLOTS).filter(|i| i % 2 == 1).count();
        let moved = t.reassign(1, 0);
        assert_eq!(moved, on_b);
        assert_eq!(t.shard_of("user-0"), 0, "every session routes to shard 0 after the move");
        assert_eq!(t.reassign(1, 0), 0, "shard 1 already empty");
    }

    /// The CI multi-process smoke drains shard 0 into shard 1 and then
    /// kills shard 0's worker, relying on the workload's two sessions
    /// landing one per shard. Pin that placement so a hash or slot
    /// change shows up here, not as a flaky smoke.
    #[test]
    fn smoke_workload_placement_is_pinned() {
        let t = RoutingTable::new(vec!["a".into(), "b".into()]).unwrap();
        assert_eq!(RoutingTable::slot_of("user-0"), 7);
        assert_eq!(RoutingTable::slot_of("user-1"), 20);
        assert_eq!(t.shard_of("user-0"), 1);
        assert_eq!(t.shard_of("user-1"), 0);
    }

    #[test]
    fn inflight_guard_counts_and_wakes() {
        let shard = Arc::new(ShardInflight { n: Mutex::new(0), cv: Condvar::new() });
        let g1 = InflightGuard::enter(&shard);
        let g2 = InflightGuard::enter(&shard);
        assert_eq!(*shard.n.lock().unwrap(), 2);
        drop(g1);
        assert_eq!(*shard.n.lock().unwrap(), 1);
        // wait_idle must return once the last guard drops
        let waiter = {
            let shard = shard.clone();
            std::thread::spawn(move || {
                let mut n = shard.n.lock().unwrap();
                while *n > 0 {
                    n = shard.cv.wait(n).unwrap();
                }
            })
        };
        drop(g2);
        waiter.join().unwrap();
        assert_eq!(*shard.n.lock().unwrap(), 0);
    }
}
