//! `PFRMWIRE` — the versioned binary frame codec of the networked
//! serving tier.
//!
//! One frame per request or response, over a plain `TcpStream`:
//!
//! ```text
//! "PFRMWIRE" | u32 version | u32 op | u64 request-id | u32 payload_len
//! payload_len bytes of op-specific payload
//! u32 CRC32 over header + payload
//! ```
//!
//! All integers little-endian; floats travel as their IEEE-754 bit
//! patterns, so scores survive the wire bit-for-bit (the CI smoke
//! diffs score CSVs byte-identical across in-process vs networked
//! runs). The codec follows the `PFRMSNAP` discipline: decode refuses
//! truncation, trailing bytes, bad magic, unknown versions, absurd
//! claimed lengths (checked against [`MAX_PAYLOAD`] *before* any
//! allocation) and CRC mismatches outright — a frame either decodes to
//! exactly what was sent or errors, never to a partial read.
//!
//! The request-id is echoed on the response frame, so a client can pin
//! each answer to its question even through a forwarding router — and
//! it is what makes pipelining safe: [`super::PipelinedClient`] keeps
//! many requests outstanding and matches replies by id, whatever order
//! they complete in. [`Msg::SubmitBatch`]/[`Msg::ScoresBatch`] go one
//! further and carry many sessions' chunks in a single frame, so one
//! round trip feeds one fused coordinator wave.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::persist::crc32;
use crate::stream::ChunkScores;

/// Magic prefix of every frame.
pub const WIRE_MAGIC: &[u8; 8] = b"PFRMWIRE";

/// Current wire protocol version.
pub const WIRE_VERSION: u32 = 1;

/// Fixed frame header length: magic + version + op + request-id +
/// payload length.
pub const HEADER_LEN: usize = 8 + 4 + 4 + 8 + 4;

/// Hard ceiling on a frame's payload — a corrupt or hostile length
/// field is refused before any buffer is allocated. Sized to fit a
/// full migration bundle of a busy shard with room to spare.
pub const MAX_PAYLOAD: usize = 256 << 20;

// op tags: requests
const OP_OPEN: u32 = 1;
const OP_SUBMIT: u32 = 2;
const OP_CLOSE: u32 = 3;
const OP_FILL_MASK: u32 = 4;
const OP_CHECKPOINT: u32 = 5;
const OP_RESTORE: u32 = 6;
const OP_DRAIN_EXPORT: u32 = 7;
const OP_RESTORE_BUNDLE: u32 = 8;
const OP_ADMIN_DRAIN: u32 = 9;
const OP_SUBMIT_BATCH: u32 = 10;
// op tags: responses
const OP_OK: u32 = 100;
const OP_SCORES: u32 = 101;
const OP_FILLED: u32 = 102;
const OP_EXPORT: u32 = 103;
const OP_RETRY_AFTER: u32 = 104;
const OP_ERROR: u32 = 105;
const OP_SCORES_BATCH: u32 = 106;

// per-entry tags inside a scores-batch payload
const ENTRY_SCORES: u8 = 0;
const ENTRY_FAILED: u8 = 1;

/// One entry of a [`Msg::ScoresBatch`] reply: a session's chunk either
/// scored or failed. Status is **per entry** so one bad session cannot
/// poison the rest of the batch — its siblings still carry scores.
#[derive(Clone, Debug, PartialEq)]
pub enum ScoreEntry {
    /// the entry's chunk was scored
    Scores {
        /// session the scores belong to
        session: String,
        /// stream offset of the chunk's first token
        offset: u64,
        /// per-token log-probability of the true token
        logprob: Vec<f32>,
        /// per-token argmax prediction
        argmax: Vec<u8>,
        /// per-token argmax probability
        argmax_prob: Vec<f32>,
    },
    /// the entry failed; sibling entries are unaffected
    Failed {
        /// session whose chunk failed
        session: String,
        /// what went wrong
        message: String,
    },
}

impl ScoreEntry {
    /// Build a scored entry from a scorer's chunk result.
    pub fn from_scores(session: &str, s: &ChunkScores) -> ScoreEntry {
        ScoreEntry::Scores {
            session: session.to_string(),
            offset: s.offset as u64,
            logprob: s.logprob.clone(),
            argmax: s.argmax.clone(),
            argmax_prob: s.argmax_prob.clone(),
        }
    }

    /// Build a failed entry.
    pub fn failed(session: &str, message: impl Into<String>) -> ScoreEntry {
        ScoreEntry::Failed { session: session.to_string(), message: message.into() }
    }

    /// The session this entry answers.
    pub fn session(&self) -> &str {
        match self {
            ScoreEntry::Scores { session, .. } | ScoreEntry::Failed { session, .. } => session,
        }
    }

    /// Unpack into the in-process score type, or the entry's error.
    pub fn into_chunk_scores(self) -> Result<(String, ChunkScores)> {
        match self {
            ScoreEntry::Scores { session, offset, logprob, argmax, argmax_prob } => Ok((
                session,
                ChunkScores { offset: offset as usize, logprob, argmax, argmax_prob },
            )),
            ScoreEntry::Failed { session, message } => {
                bail!("server: session '{session}': {message}")
            }
        }
    }

    /// The single-request reply message carrying the same outcome
    /// (the router uses this to fan a coalesced batch reply back out
    /// to the individual clients it merged).
    pub fn into_msg(self) -> Msg {
        match self {
            ScoreEntry::Scores { session, offset, logprob, argmax, argmax_prob } => {
                Msg::Scores { session, offset, logprob, argmax, argmax_prob }
            }
            ScoreEntry::Failed { session, message } => {
                Msg::Error { message: format!("session '{session}': {message}") }
            }
        }
    }
}

/// Every message the wire carries — requests and responses share the
/// frame format and differ only in op tag.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// request: verify a stream pool exists and the peer is serving
    /// (a session is created lazily by its first [`Self::Submit`])
    Open {
        /// stream pool the session will live in
        pool: String,
        /// session id (advisory here; routing hashes it)
        session: String,
    },
    /// request: score `tokens` as the session's next chunk
    Submit {
        /// stream pool the session lives in
        pool: String,
        /// session id
        session: String,
        /// the next chunk of the session's token stream
        tokens: Vec<u8>,
    },
    /// request: end a stream, releasing its carried state
    Close {
        /// stream pool the session lives in
        pool: String,
        /// session id
        session: String,
    },
    /// request: one-shot fill-mask inference through a batched pool
    FillMask {
        /// model pool (artifact tag) to run on
        model: String,
        /// token sequence with mask tokens to fill
        tokens: Vec<u8>,
    },
    /// request: export a pool's sessions to a directory on the
    /// *server's* filesystem (full or delta)
    Checkpoint {
        /// stream pool to export
        pool: String,
        /// server-side target directory
        dir: String,
        /// true = incremental (`checkpoint_delta`), false = full
        delta: bool,
    },
    /// request: adopt sessions from a directory on the *server's*
    /// filesystem
    Restore {
        /// stream pool to adopt into
        pool: String,
        /// server-side source directory
        dir: String,
    },
    /// request: evacuate every live session and return them as a
    /// `PFRMBNDL` blob (the migration hand-off; answered by
    /// [`Self::Export`])
    DrainExport {
        /// stream pool to evacuate
        pool: String,
    },
    /// request: adopt every session packed in a `PFRMBNDL` blob
    RestoreBundle {
        /// stream pool to adopt into
        pool: String,
        /// the bundle bytes ([`crate::persist::bundle_dir`])
        bundle: Vec<u8>,
    },
    /// request: score many sessions' next chunks in **one** frame and
    /// one coordinator wave — the round trip amortizes across the
    /// batch, and distinct sessions fuse into one batched forward pass.
    /// Answered by [`Self::ScoresBatch`] with per-entry status (or one
    /// whole-frame [`Self::RetryAfter`] when the peer sheds the batch —
    /// all-or-nothing, so a shed never advances any entry's stream).
    SubmitBatch {
        /// stream pool the sessions live in
        pool: String,
        /// `(session, tokens)` — the next chunk per session, in order
        entries: Vec<(String, Vec<u8>)>,
    },
    /// request (router only): live-rebalance — drain shard `from` and
    /// migrate its sessions into shard `to`
    AdminDrain {
        /// stream pool on the workers
        pool: String,
        /// shard index to evacuate
        from: u32,
        /// shard index that adopts the sessions
        to: u32,
    },
    /// response: generic success, with an op-specific count (sessions
    /// exported/adopted/moved; 0 where meaningless)
    Ok {
        /// op-specific affected count
        affected: u64,
    },
    /// response to [`Self::Submit`]: per-token scores for the chunk
    Scores {
        /// session the scores belong to
        session: String,
        /// stream offset of the chunk's first token
        offset: u64,
        /// per-token log-probability of the true token
        logprob: Vec<f32>,
        /// per-token argmax prediction
        argmax: Vec<u8>,
        /// per-token argmax probability
        argmax_prob: Vec<f32>,
    },
    /// response to [`Self::FillMask`]
    Filled {
        /// the input with every answerable mask filled
        filled: Vec<u8>,
        /// filled positions, aligned with `tokens`/`probs`
        positions: Vec<u32>,
        /// predicted token per filled position
        tokens: Vec<u8>,
        /// prediction probability per filled position
        probs: Vec<f32>,
    },
    /// response to [`Self::DrainExport`]: the evacuated sessions
    Export {
        /// how many sessions the bundle holds
        sessions: u64,
        /// `PFRMBNDL` blob ([`crate::persist::unbundle_into`] reads it)
        bundle: Vec<u8>,
    },
    /// response to [`Self::SubmitBatch`]: one [`ScoreEntry`] per
    /// submitted entry, in submission order
    ScoresBatch {
        /// per-entry outcome, aligned with the request's entries
        entries: Vec<ScoreEntry>,
    },
    /// response: load-shed — the peer is over its admission limit;
    /// retry after the given hint instead of queuing unboundedly
    RetryAfter {
        /// suggested client back-off before retrying
        millis: u32,
    },
    /// response: the request failed
    Error {
        /// what went wrong
        message: String,
    },
}

impl Msg {
    /// The message's op tag on the wire.
    fn op(&self) -> u32 {
        match self {
            Msg::Open { .. } => OP_OPEN,
            Msg::Submit { .. } => OP_SUBMIT,
            Msg::Close { .. } => OP_CLOSE,
            Msg::FillMask { .. } => OP_FILL_MASK,
            Msg::Checkpoint { .. } => OP_CHECKPOINT,
            Msg::Restore { .. } => OP_RESTORE,
            Msg::DrainExport { .. } => OP_DRAIN_EXPORT,
            Msg::RestoreBundle { .. } => OP_RESTORE_BUNDLE,
            Msg::SubmitBatch { .. } => OP_SUBMIT_BATCH,
            Msg::AdminDrain { .. } => OP_ADMIN_DRAIN,
            Msg::Ok { .. } => OP_OK,
            Msg::Scores { .. } => OP_SCORES,
            Msg::Filled { .. } => OP_FILLED,
            Msg::Export { .. } => OP_EXPORT,
            Msg::RetryAfter { .. } => OP_RETRY_AFTER,
            Msg::Error { .. } => OP_ERROR,
            Msg::ScoresBatch { .. } => OP_SCORES_BATCH,
        }
    }

    /// Human-readable op name, for error messages and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Open { .. } => "open",
            Msg::Submit { .. } => "submit",
            Msg::Close { .. } => "close",
            Msg::FillMask { .. } => "fill-mask",
            Msg::Checkpoint { .. } => "checkpoint",
            Msg::Restore { .. } => "restore",
            Msg::DrainExport { .. } => "drain-export",
            Msg::RestoreBundle { .. } => "restore-bundle",
            Msg::SubmitBatch { .. } => "submit-batch",
            Msg::AdminDrain { .. } => "admin-drain",
            Msg::Ok { .. } => "ok",
            Msg::Scores { .. } => "scores",
            Msg::Filled { .. } => "filled",
            Msg::Export { .. } => "export",
            Msg::RetryAfter { .. } => "retry-after",
            Msg::Error { .. } => "error",
            Msg::ScoresBatch { .. } => "scores-batch",
        }
    }

    /// Build a [`Self::Scores`] response from a scorer's chunk result.
    pub fn from_scores(session: &str, s: &ChunkScores) -> Msg {
        Msg::Scores {
            session: session.to_string(),
            offset: s.offset as u64,
            logprob: s.logprob.clone(),
            argmax: s.argmax.clone(),
            argmax_prob: s.argmax_prob.clone(),
        }
    }

    /// Unpack a [`Self::Scores`] response into the in-process score
    /// type the rest of the stack speaks.
    pub fn into_chunk_scores(self) -> Result<(String, ChunkScores)> {
        match self {
            Msg::Scores { session, offset, logprob, argmax, argmax_prob } => Ok((
                session,
                ChunkScores { offset: offset as usize, logprob, argmax, argmax_prob },
            )),
            Msg::Error { message } => bail!("server: {message}"),
            other => bail!("expected a scores frame, got {}", other.name()),
        }
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut e = Enc(Vec::new());
        match self {
            Msg::Open { pool, session } => {
                e.str(pool);
                e.str(session);
            }
            Msg::Submit { pool, session, tokens } => {
                e.str(pool);
                e.str(session);
                e.bytes(tokens);
            }
            Msg::Close { pool, session } => {
                e.str(pool);
                e.str(session);
            }
            Msg::FillMask { model, tokens } => {
                e.str(model);
                e.bytes(tokens);
            }
            Msg::Checkpoint { pool, dir, delta } => {
                e.str(pool);
                e.str(dir);
                e.0.push(u8::from(*delta));
            }
            Msg::Restore { pool, dir } => {
                e.str(pool);
                e.str(dir);
            }
            Msg::DrainExport { pool } => e.str(pool),
            Msg::RestoreBundle { pool, bundle } => {
                e.str(pool);
                e.bytes(bundle);
            }
            Msg::SubmitBatch { pool, entries } => {
                e.str(pool);
                e.u32(entries.len() as u32);
                for (session, tokens) in entries {
                    e.str(session);
                    e.bytes(tokens);
                }
            }
            Msg::AdminDrain { pool, from, to } => {
                e.str(pool);
                e.u32(*from);
                e.u32(*to);
            }
            Msg::Ok { affected } => e.u64(*affected),
            Msg::Scores { session, offset, logprob, argmax, argmax_prob } => {
                e.str(session);
                e.u64(*offset);
                e.f32s(logprob);
                e.bytes(argmax);
                e.f32s(argmax_prob);
            }
            Msg::Filled { filled, positions, tokens, probs } => {
                e.bytes(filled);
                e.u32s(positions);
                e.bytes(tokens);
                e.f32s(probs);
            }
            Msg::Export { sessions, bundle } => {
                e.u64(*sessions);
                e.bytes(bundle);
            }
            Msg::RetryAfter { millis } => e.u32(*millis),
            Msg::Error { message } => e.str(message),
            Msg::ScoresBatch { entries } => {
                e.u32(entries.len() as u32);
                for entry in entries {
                    match entry {
                        ScoreEntry::Scores { session, offset, logprob, argmax, argmax_prob } => {
                            e.0.push(ENTRY_SCORES);
                            e.str(session);
                            e.u64(*offset);
                            e.f32s(logprob);
                            e.bytes(argmax);
                            e.f32s(argmax_prob);
                        }
                        ScoreEntry::Failed { session, message } => {
                            e.0.push(ENTRY_FAILED);
                            e.str(session);
                            e.str(message);
                        }
                    }
                }
            }
        }
        e.0
    }

    fn decode(op: u32, payload: &[u8]) -> Result<Msg> {
        let mut d = Dec { buf: payload };
        let msg = match op {
            OP_OPEN => Msg::Open { pool: d.str()?, session: d.str()? },
            OP_SUBMIT => {
                Msg::Submit { pool: d.str()?, session: d.str()?, tokens: d.bytes()? }
            }
            OP_CLOSE => Msg::Close { pool: d.str()?, session: d.str()? },
            OP_FILL_MASK => Msg::FillMask { model: d.str()?, tokens: d.bytes()? },
            OP_CHECKPOINT => {
                Msg::Checkpoint { pool: d.str()?, dir: d.str()?, delta: d.u8()? != 0 }
            }
            OP_RESTORE => Msg::Restore { pool: d.str()?, dir: d.str()? },
            OP_DRAIN_EXPORT => Msg::DrainExport { pool: d.str()? },
            OP_RESTORE_BUNDLE => {
                Msg::RestoreBundle { pool: d.str()?, bundle: d.bytes()? }
            }
            OP_SUBMIT_BATCH => {
                let pool = d.str()?;
                let n = d.u32()? as usize;
                // every entry needs at least its two length prefixes
                ensure!(n * 8 <= d.buf.len() + 7, "submit-batch claims {n} entries — truncated");
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push((d.str()?, d.bytes()?));
                }
                Msg::SubmitBatch { pool, entries }
            }
            OP_ADMIN_DRAIN => {
                Msg::AdminDrain { pool: d.str()?, from: d.u32()?, to: d.u32()? }
            }
            OP_OK => Msg::Ok { affected: d.u64()? },
            OP_SCORES => Msg::Scores {
                session: d.str()?,
                offset: d.u64()?,
                logprob: d.f32s()?,
                argmax: d.bytes()?,
                argmax_prob: d.f32s()?,
            },
            OP_FILLED => Msg::Filled {
                filled: d.bytes()?,
                positions: d.u32s()?,
                tokens: d.bytes()?,
                probs: d.f32s()?,
            },
            OP_EXPORT => Msg::Export { sessions: d.u64()?, bundle: d.bytes()? },
            OP_RETRY_AFTER => Msg::RetryAfter { millis: d.u32()? },
            OP_ERROR => Msg::Error { message: d.str()? },
            OP_SCORES_BATCH => {
                let n = d.u32()? as usize;
                // every entry carries at least its one-byte status tag
                ensure!(n <= d.buf.len(), "scores-batch claims {n} entries — truncated");
                let mut entries = Vec::with_capacity(n);
                for _ in 0..n {
                    entries.push(match d.u8()? {
                        ENTRY_SCORES => ScoreEntry::Scores {
                            session: d.str()?,
                            offset: d.u64()?,
                            logprob: d.f32s()?,
                            argmax: d.bytes()?,
                            argmax_prob: d.f32s()?,
                        },
                        ENTRY_FAILED => {
                            ScoreEntry::Failed { session: d.str()?, message: d.str()? }
                        }
                        tag => bail!("unknown scores-batch entry tag {tag}"),
                    });
                }
                Msg::ScoresBatch { entries }
            }
            other => bail!("unknown wire op {other}"),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Encode one frame to bytes (header + payload + CRC32).
pub fn frame_bytes(id: u64, msg: &Msg) -> Vec<u8> {
    let payload = msg.encode_payload();
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    out.extend_from_slice(WIRE_MAGIC);
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.extend_from_slice(&msg.op().to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write one frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, id: u64, msg: &Msg) -> Result<()> {
    w.write_all(&frame_bytes(id, msg)).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read exactly one frame. Errors on EOF mid-frame, bad magic, version
/// mismatch, an over-[`MAX_PAYLOAD`] length claim (before allocating),
/// CRC mismatch, or a payload that does not decode to exactly one
/// message.
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u64, Msg)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("reading frame header")?;
    ensure!(&header[..8] == WIRE_MAGIC, "bad frame magic: peer is not speaking PFRMWIRE");
    let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    ensure!(
        version == WIRE_VERSION,
        "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
    );
    let op = u32::from_le_bytes(header[12..16].try_into().unwrap());
    let id = u64::from_le_bytes(header[16..24].try_into().unwrap());
    let len = u32::from_le_bytes(header[24..28].try_into().unwrap()) as usize;
    ensure!(len <= MAX_PAYLOAD, "frame claims a {len}-byte payload, over the {MAX_PAYLOAD} cap");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let mut crc_buf = [0u8; 4];
    r.read_exact(&mut crc_buf).context("reading frame checksum")?;
    let stored = u32::from_le_bytes(crc_buf);
    let mut whole = Vec::with_capacity(HEADER_LEN + len);
    whole.extend_from_slice(&header);
    whole.extend_from_slice(&payload);
    let actual = crc32(&whole);
    ensure!(
        stored == actual,
        "frame checksum mismatch (stored {stored:#010x}, computed {actual:#010x})"
    );
    let msg = Msg::decode(op, &payload)?;
    Ok((id, msg))
}

/// Decode one frame from a byte slice, refusing trailing bytes — the
/// strict entry point the property tests hammer.
pub fn frame_from_bytes(bytes: &[u8]) -> Result<(u64, Msg)> {
    let mut r = bytes;
    let frame = read_frame(&mut r)?;
    ensure!(r.is_empty(), "{} trailing bytes after the frame", r.len());
    Ok(frame)
}

/// Little-endian payload writer. Vectors and strings are u32
/// length-prefixed.
struct Enc(Vec<u8>);

impl Enc {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.0.extend_from_slice(v);
    }

    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.u32(*x);
        }
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            // bit pattern, not decimal: scores must survive bit-for-bit
            self.u32(x.to_bits());
        }
    }
}

/// Strict little-endian payload reader: every read yields exactly the
/// requested bytes or errors, claimed element counts are checked
/// against the bytes actually present before allocating, and
/// [`Dec::finish`] refuses leftovers.
struct Dec<'a> {
    buf: &'a [u8],
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let left = self.buf.len();
        ensure!(left >= n, "payload truncated: wanted {n} bytes, {left} left");
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn str(&mut self) -> Result<String> {
        let raw = self.bytes()?;
        String::from_utf8(raw).map_err(|_| anyhow::anyhow!("string field is not UTF-8"))
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        ensure!(n * 4 <= self.buf.len() + 3, "u32 vector claims {n} elements — truncated");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        ensure!(n * 4 <= self.buf.len() + 3, "f32 vector claims {n} elements — truncated");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_bits(self.u32()?));
        }
        Ok(out)
    }

    fn finish(self) -> Result<()> {
        ensure!(self.buf.is_empty(), "{} trailing bytes after the payload", self.buf.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_round_trips() {
        let msgs = vec![
            Msg::Open { pool: "native".into(), session: "user-0".into() },
            Msg::Submit { pool: "native".into(), session: "u".into(), tokens: vec![1, 2, 3] },
            Msg::Close { pool: "native".into(), session: "u".into() },
            Msg::FillMask { model: "base".into(), tokens: vec![9, 9] },
            Msg::Checkpoint { pool: "p".into(), dir: "/tmp/x".into(), delta: true },
            Msg::Restore { pool: "p".into(), dir: "/tmp/x".into() },
            Msg::DrainExport { pool: "p".into() },
            Msg::RestoreBundle { pool: "p".into(), bundle: vec![0xde, 0xad] },
            Msg::AdminDrain { pool: "p".into(), from: 0, to: 1 },
            Msg::Ok { affected: 7 },
            Msg::Scores {
                session: "u".into(),
                offset: 64,
                logprob: vec![-0.5, f32::NEG_INFINITY],
                argmax: vec![4, 5],
                argmax_prob: vec![0.25, 1.0],
            },
            Msg::Filled {
                filled: vec![1, 2],
                positions: vec![1],
                tokens: vec![7],
                probs: vec![0.9],
            },
            Msg::Export { sessions: 2, bundle: vec![1; 32] },
            Msg::RetryAfter { millis: 25 },
            Msg::Error { message: "boom".into() },
            Msg::SubmitBatch {
                pool: "native".into(),
                entries: vec![
                    ("user-0".into(), vec![1, 2, 3]),
                    ("user-1".into(), vec![]),
                ],
            },
            Msg::SubmitBatch { pool: "p".into(), entries: vec![] },
            Msg::ScoresBatch {
                entries: vec![
                    ScoreEntry::Scores {
                        session: "user-0".into(),
                        offset: 128,
                        logprob: vec![-0.25, f32::NEG_INFINITY],
                        argmax: vec![3, 4],
                        argmax_prob: vec![0.5, 0.75],
                    },
                    ScoreEntry::Failed { session: "user-1".into(), message: "boom".into() },
                ],
            },
            Msg::ScoresBatch { entries: vec![] },
        ];
        for (i, msg) in msgs.into_iter().enumerate() {
            let bytes = frame_bytes(i as u64, &msg);
            let (id, back) = frame_from_bytes(&bytes).unwrap();
            assert_eq!(id, i as u64);
            assert_eq!(back, msg);
            // a re-encode of the decoded message is bitwise identical
            assert_eq!(frame_bytes(id, &back), bytes);
        }
    }

    #[test]
    fn oversized_length_refused_before_allocation() {
        let mut bytes = frame_bytes(1, &Msg::Ok { affected: 0 });
        bytes[24..28].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = frame_from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "wrong error: {err:#}");
    }

    #[test]
    fn wrong_version_refused() {
        let mut bytes = frame_bytes(1, &Msg::Ok { affected: 0 });
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = frame_from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "wrong error: {err:#}");
    }
}
