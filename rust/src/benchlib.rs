//! Benchmark harness substrate (criterion is not in the offline
//! registry). Provides warmup + repeated sampling with median/mean/σ,
//! throughput accounting, and aligned table/CSV output — enough to
//! regenerate every timing figure in the paper with honest statistics.

use std::time::Instant;

/// One benchmark measurement series.
#[derive(Clone, Debug)]
pub struct Sample {
    /// label of the series (what was measured)
    pub name: String,
    /// per-iteration wall times, seconds
    pub times: Vec<f64>,
}

impl Sample {
    /// Median of the sample times.
    pub fn median(&self) -> f64 {
        let mut t = self.times.clone();
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if t.is_empty() {
            return f64::NAN;
        }
        let n = t.len();
        if n % 2 == 0 { (t[n / 2 - 1] + t[n / 2]) / 2.0 } else { t[n / 2] }
    }

    /// Mean of the sample times.
    pub fn mean(&self) -> f64 {
        self.times.iter().sum::<f64>() / self.times.len().max(1) as f64
    }

    /// Standard deviation of the sample times.
    pub fn std(&self) -> f64 {
        let m = self.mean();
        (self.times.iter().map(|t| (t - m) * (t - m)).sum::<f64>()
            / self.times.len().max(1) as f64)
            .sqrt()
    }

    /// Fastest sample time.
    pub fn min(&self) -> f64 {
        self.times.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Benchmark runner: fixed warmup iterations then `samples` timed runs,
/// with a wall-clock budget so quadratic baselines can't stall a sweep.
pub struct Bench {
    /// untimed iterations before sampling
    pub warmup: usize,
    /// timed iterations
    pub samples: usize,
    /// wall-clock budget for one run (warmup + samples)
    pub max_total_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 7, max_total_secs: 30.0 }
    }
}

impl Bench {
    /// Short configuration for smoke modes.
    pub fn quick() -> Self {
        Bench { warmup: 1, samples: 3, max_total_secs: 10.0 }
    }

    /// Time `f` (which must perform one full iteration per call).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        let budget = Instant::now();
        for _ in 0..self.warmup {
            std::hint::black_box(f());
            if budget.elapsed().as_secs_f64() > self.max_total_secs {
                break;
            }
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if budget.elapsed().as_secs_f64() > self.max_total_secs {
                break;
            }
        }
        Sample { name: name.to_string(), times }
    }
}

/// Accumulates rows of a figure/table and renders them.
pub struct Report {
    /// report title line
    pub title: String,
    /// column headers
    pub columns: Vec<String>,
    /// data rows, each matching the column arity
    pub rows: Vec<Vec<String>>,
}

impl Report {
    /// Empty report with the given title and columns.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Report {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the column arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Aligned text table (what the xp harness prints).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV form (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV next to the experiment outputs.
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Least-squares slope of log(y) vs log(x): the empirical scaling
/// exponent (Fig. 1's "linear vs quadratic" claim, quantified).
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in lx.iter().zip(&ly) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_stats() {
        let s = Sample { name: "t".into(), times: vec![1.0, 2.0, 3.0, 4.0, 100.0] };
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert!((s.mean() - 22.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_counts() {
        let b = Bench { warmup: 1, samples: 5, max_total_secs: 5.0 };
        let mut count = 0;
        let s = b.run("noop", || {
            count += 1;
            count
        });
        assert_eq!(s.times.len(), 5);
        assert_eq!(count, 6); // warmup + samples
    }

    #[test]
    fn report_render_and_csv() {
        let mut r = Report::new("Fig X", &["L", "time"]);
        r.row(vec!["128".into(), "1.5ms".into()]);
        r.row(vec!["4096".into(), "2.0ms".into()]);
        let txt = r.render();
        assert!(txt.contains("Fig X"));
        assert!(txt.contains("4096"));
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn loglog_slope_detects_quadratic() {
        let xs = [128.0, 256.0, 512.0, 1024.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        let slope = loglog_slope(&xs, &ys);
        assert!((slope - 2.0).abs() < 1e-6);
    }

    #[test]
    fn loglog_slope_detects_linear() {
        let xs = [128.0, 256.0, 512.0, 1024.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x).collect();
        assert!((loglog_slope(&xs, &ys) - 1.0).abs() < 1e-6);
    }
}
