//! Minimal JSON substrate (parse + serialize).
//!
//! serde isn't available in the offline registry, and the only JSON this
//! repo exchanges is the artifact metadata contract written by
//! `python/compile/aot.py` plus experiment/config files — a hand-rolled
//! recursive-descent parser covers it with zero dependencies.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
/// A parsed JSON value.
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (f64 precision)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys — serialization is canonical)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup; a missing key is a loud error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// The string value, or an error for other types.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The numeric value, or an error for other types.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The numeric value truncated to usize.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The boolean value, or an error for other types.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The array elements, or an error for other types.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Field as string, with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str().ok()).unwrap_or(default).to_string()
    }

    /// Field as usize, with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_f64().ok()).map(|v| v as usize).unwrap_or(default)
    }

    /// Field as f64, with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64().ok()).unwrap_or(default)
    }

    /// Field as bool, with a default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    // -- serialization -----------------------------------------------------

    /// Serialize (canonical: sorted object keys, minimal whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder conveniences for experiment output.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Array builder.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

/// Number builder.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// String builder.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}, found '{}'", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // continue multi-byte UTF-8 sequences verbatim
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| anyhow!("bad number '{txt}': {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.req("d").unwrap().req("e").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""line\nbreak \"quoted\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "line\nbreak \"quoted\" A");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"inputs":[{"dtype":"f32","name":"x","shape":[2,3]}],"kind":"fwd"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn real_meta_shape() {
        let meta = r#"{
          "kind": "fwd",
          "config": {"d_model": 64, "attention": "favor-relu"},
          "inputs": [
            {"name": "embed", "role": "param", "shape": [30, 64], "dtype": "f32"},
            {"name": "tokens", "role": "tokens", "shape": [4, 64], "dtype": "i32"}
          ],
          "outputs": [{"name": "logits", "shape": [4, 64, 30], "dtype": "f32"}]
        }"#;
        let j = Json::parse(meta).unwrap();
        let ins = j.req("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins.len(), 2);
        assert_eq!(ins[1].str_or("role", ""), "tokens");
        assert_eq!(j.req("config").unwrap().usize_or("d_model", 0), 64);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ∆\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∆");
    }
}
