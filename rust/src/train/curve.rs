//! Training-curve recording: the (step, loss, accuracy) series every
//! training figure in the paper plots (Figs. 3, 4, 5, 12, 13).

use std::fmt::Write as _;

#[derive(Clone, Copy, Debug, PartialEq)]
/// One (step, loss, accuracy) observation.
pub struct Point {
    /// optimizer step
    pub step: usize,
    /// loss at the step
    pub loss: f64,
    /// accuracy at the step
    pub acc: f64,
}

/// Train + validation series for one run.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    /// run label (artifact tag)
    pub name: String,
    /// training series
    pub train: Vec<Point>,
    /// validation series
    pub valid: Vec<Point>,
}

impl Curve {
    /// Empty curve for a named run.
    pub fn new(name: &str) -> Self {
        Curve { name: name.to_string(), ..Default::default() }
    }

    /// Append a training observation.
    pub fn push_train(&mut self, step: usize, loss: f64, acc: f64) {
        self.train.push(Point { step, loss, acc });
    }

    /// Append a validation observation.
    pub fn push_valid(&mut self, step: usize, loss: f64, acc: f64) {
        self.valid.push(Point { step, loss, acc });
    }

    /// Last recorded training accuracy (NaN if none).
    pub fn final_train_acc(&self) -> f64 {
        self.train.last().map(|p| p.acc).unwrap_or(f64::NAN)
    }

    /// Last recorded validation accuracy (NaN if none).
    pub fn final_valid_acc(&self) -> f64 {
        self.valid.last().map(|p| p.acc).unwrap_or(f64::NAN)
    }

    /// Best validation accuracy seen (NaN if none).
    pub fn best_valid_acc(&self) -> f64 {
        self.valid.iter().map(|p| p.acc).fold(f64::NAN, f64::max)
    }

    /// Smoothed (trailing-window mean) train accuracy, for noisy small
    /// batches.
    pub fn smoothed_train_acc(&self, window: usize) -> f64 {
        let n = self.train.len();
        if n == 0 {
            return f64::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.train[lo..];
        slice.iter().map(|p| p.acc).sum::<f64>() / slice.len() as f64
    }

    /// CSV: series,step,loss,acc
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,step,loss,acc\n");
        for p in &self.train {
            let _ = writeln!(out, "train,{},{:.6},{:.6}", p.step, p.loss, p.acc);
        }
        for p in &self.valid {
            let _ = writeln!(out, "valid,{},{:.6},{:.6}", p.step, p.loss, p.acc);
        }
        out
    }

    /// Terminal sparkline of train loss (quick visual check in logs).
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.train.is_empty() {
            return String::new();
        }
        let lo = self.train.iter().map(|p| p.loss).fold(f64::INFINITY, f64::min);
        let hi = self.train.iter().map(|p| p.loss).fold(f64::NEG_INFINITY, f64::max);
        let span = (hi - lo).max(1e-9);
        self.train
            .iter()
            .map(|p| BARS[(((p.loss - lo) / span) * 7.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut c = Curve::new("run");
        c.push_train(0, 3.0, 0.1);
        c.push_train(10, 2.0, 0.2);
        c.push_valid(10, 2.5, 0.15);
        assert_eq!(c.final_train_acc(), 0.2);
        assert_eq!(c.final_valid_acc(), 0.15);
        assert_eq!(c.best_valid_acc(), 0.15);
    }

    #[test]
    fn smoothing_window() {
        let mut c = Curve::new("run");
        for i in 0..10 {
            c.push_train(i, 1.0, i as f64 / 10.0);
        }
        let s = c.smoothed_train_acc(5);
        assert!((s - 0.7).abs() < 1e-9); // mean of .5 .6 .7 .8 .9
    }

    #[test]
    fn csv_shape() {
        let mut c = Curve::new("r");
        c.push_train(1, 2.0, 0.1);
        c.push_valid(1, 2.1, 0.12);
        let csv = c.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.contains("valid,1,"));
    }

    #[test]
    fn sparkline_length_matches_points() {
        let mut c = Curve::new("r");
        for i in 0..5 {
            c.push_train(i, 5.0 - i as f64, 0.0);
        }
        assert_eq!(c.sparkline().chars().count(), 5);
    }
}
