//! The training driver: owns the AOT `train_step` executable and the
//! host-resident training state (params, Adam moments, FAVOR features),
//! streams batches from the protein pipeline, and records curves.
//!
//! One step = one PJRT execute of the whole jitted train_step (forward +
//! backward + Adam), exactly the paper's jax.jit training setup — the
//! coordinator only generates data, shuttles state and logs.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::favor::{FeatureKind, FeatureMap};
use crate::linalg::OrfMechanism;
use crate::protein::{lm_batch, mlm_batch, Batch, Corpus, MaskPolicy};
use crate::rng::Pcg64;
use crate::runtime::{Engine, Executable, HostValue, Role, TensorFile};

use super::curve::Curve;
use super::native_model::NativeModel;
use super::slim::{ChunkedTrainConfig, NativeTrainer};

/// Which data split a batch is drawn from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// training draw of the IID families
    Train,
    /// validation draw of the IID families
    Valid,
    /// test draw of the IID families
    Test,
    /// held-out families
    Ood,
}

/// Streams fixed-shape batches for a given artifact config.
pub struct DataGen {
    /// corpus batches are drawn from
    pub corpus: Arc<Corpus>,
    /// sequence length per row
    pub l: usize,
    /// batch size
    pub b: usize,
    /// next-token LM targets (true) vs BERT-style MLM (false)
    pub unidirectional: bool,
    /// long-context concatenated-protein task (Fig. 5) vs single-sequence
    pub concat: bool,
    policy: MaskPolicy,
    rngs: [Pcg64; 4],
}

impl DataGen {
    /// Generator with per-split independent rng streams.
    pub fn new(corpus: Arc<Corpus>, l: usize, b: usize, unidirectional: bool,
               concat: bool, seed: u64) -> Self {
        let mut root = Pcg64::new(seed ^ 0x9e3779b97f4a7c15);
        DataGen {
            corpus,
            l,
            b,
            unidirectional,
            concat,
            policy: MaskPolicy::default(),
            rngs: [root.fork(1), root.fork(2), root.fork(3), root.fork(4)],
        }
    }

    /// The next fixed-shape batch of the split.
    pub fn next_batch(&mut self, split: Split) -> Batch {
        let rng = &mut self.rngs[match split {
            Split::Train => 0,
            Split::Valid => 1,
            Split::Test => 2,
            Split::Ood => 3,
        }];
        let windows: Vec<Vec<u8>> = if self.concat {
            self.corpus.concat_stream(self.l, self.b, rng)
        } else {
            (0..self.b)
                .map(|_| {
                    let seq = match split {
                        Split::Ood => self.corpus.sample_ood(rng).1,
                        _ => self.corpus.sample_iid(rng).1,
                    };
                    self.corpus.window(&seq, self.l)
                })
                .collect()
        };
        if self.unidirectional {
            lm_batch(&windows, self.l)
        } else {
            mlm_batch(&windows, self.l, self.policy, rng)
        }
    }
}

/// Host-resident model/optimizer state, in the artifact's slot order.
pub struct TrainState {
    /// engine executions go through
    pub engine: Arc<Engine>,
    /// artifact tag
    pub tag: String,
    /// compiled train step
    pub train_exe: Arc<Executable>,
    /// compiled eval step (if the artifact ships one)
    pub eval_exe: Option<Arc<Executable>>,
    /// parameters in artifact slot order
    pub params: Vec<Vec<f32>>,
    /// Adam first moments
    pub opt_m: Vec<Vec<f32>>,
    /// Adam second moments
    pub opt_v: Vec<Vec<f32>>,
    /// optimizer step counter (f32: fed to the artifact)
    pub step: f32,
    /// FAVOR feature draws in artifact slot order
    pub features: Vec<Vec<f32>>,
    /// names of the param slots (artifact order), for checkpoints and
    /// weight transplant
    pub param_names: Vec<String>,
    /// names of the feature slots (artifact order)
    pub feature_names: Vec<String>,
    /// native SLiM chunked trainer, when enabled: train/eval steps
    /// route through it instead of the AOT executables, with params and
    /// Adam moments mirrored back into the artifact slots after every
    /// step so checkpoints and transplant keep working unchanged
    pub chunked: Option<NativeTrainer>,
}

impl TrainState {
    /// Bootstrap from `{tag}_train` + `{tag}_init.bin`.
    pub fn new(engine: Arc<Engine>, tag: &str) -> Result<TrainState> {
        let train_exe = engine.load(&format!("{tag}_train"))?;
        let eval_exe = if engine.exists(&format!("{tag}_eval")) {
            Some(engine.load(&format!("{tag}_eval"))?)
        } else {
            None
        };
        let init = TensorFile::read(&engine.artifacts_dir().join(format!("{tag}_init.bin")))
            .with_context(|| format!("init tensors for {tag}"))?;

        let meta = &train_exe.meta;
        let param_idx = meta.input_indices(Role::Param);
        let feat_idx = meta.input_indices(Role::Feature);

        let mut params = Vec::with_capacity(param_idx.len());
        let mut param_names = Vec::with_capacity(param_idx.len());
        for &i in &param_idx {
            let slot = &meta.inputs[i];
            let (_, data) = init
                .get(&format!("param:{}", slot.name))
                .ok_or_else(|| anyhow!("init missing param:{}", slot.name))?;
            if data.len() != slot.elements() {
                bail!("init param {} wrong size", slot.name);
            }
            params.push(data.to_vec());
            param_names.push(slot.name.clone());
        }
        let mut features = Vec::with_capacity(feat_idx.len());
        let mut feature_names = Vec::with_capacity(feat_idx.len());
        for &i in &feat_idx {
            let slot = &meta.inputs[i];
            let (_, data) = init
                .get(&format!("feature:{}", slot.name))
                .ok_or_else(|| anyhow!("init missing feature:{}", slot.name))?;
            features.push(data.to_vec());
            feature_names.push(slot.name.clone());
        }
        let opt_m: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let opt_v = opt_m.clone();

        Ok(TrainState {
            engine,
            tag: tag.to_string(),
            train_exe,
            eval_exe,
            params,
            opt_m,
            opt_v,
            step: 0.0,
            features,
            param_names,
            feature_names,
            chunked: None,
        })
    }

    /// Switch this state's train/eval steps to the native SLiM chunked
    /// path (`train::slim`): builds a [`NativeModel`] from the
    /// artifact's metadata plus the current host params/features, and
    /// adopts the current Adam moments and step counter so training
    /// resumes exactly where the AOT path left it. Requires a causal
    /// FAVOR artifact.
    pub fn enable_chunked(&mut self, cfg: ChunkedTrainConfig, lr: f32) -> Result<()> {
        let lookup = |name: &str| -> Option<Vec<f32>> {
            if let Some(i) = self.param_names.iter().position(|n| n == name) {
                return Some(self.params[i].clone());
            }
            self.feature_names
                .iter()
                .position(|n| n == name)
                .map(|i| self.features[i].clone())
        };
        let model = NativeModel::from_weights(&self.train_exe.meta, &lookup)?;
        let tag = format!("{}-slim", self.tag);
        let trainer = NativeTrainer::new(model, cfg, lr, &tag)?;
        self.chunked = Some(trainer);
        self.sync_chunked_from_host();
        Ok(())
    }

    /// Push the host-slot params, Adam moments and step counter into
    /// the chunked trainer (no-op when chunked mode is off). Called
    /// after checkpoint restore and weight transplant so the native
    /// model never drifts from the artifact slots.
    pub fn sync_chunked_from_host(&mut self) {
        let Some(mut trainer) = self.chunked.take() else { return };
        for (name, slot) in trainer.model_mut().param_slots_mut() {
            if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                if self.params[i].len() == slot.len() {
                    slot.copy_from_slice(&self.params[i]);
                }
            }
        }
        let (ms, vs) = trainer.opt_slots_mut();
        for (name, slot) in ms {
            if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                if self.opt_m[i].len() == slot.len() {
                    slot.copy_from_slice(&self.opt_m[i]);
                }
            }
        }
        for (name, slot) in vs {
            if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                if self.opt_v[i].len() == slot.len() {
                    slot.copy_from_slice(&self.opt_v[i]);
                }
            }
        }
        trainer.set_step(self.step);
        self.chunked = Some(trainer);
    }

    /// One SLiM step through the native trainer, mirroring its params,
    /// moments and step counter back into the artifact slots.
    fn chunked_train_step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        let mut trainer = self.chunked.take().expect("chunked trainer enabled");
        let res = trainer.train_step(batch);
        if res.is_ok() {
            for (name, data) in trainer.model().param_slots() {
                if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                    if self.params[i].len() == data.len() {
                        self.params[i].copy_from_slice(data);
                    }
                }
            }
            let (ms, vs) = trainer.opt_slots();
            for (name, data) in ms {
                if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                    if self.opt_m[i].len() == data.len() {
                        self.opt_m[i].copy_from_slice(data);
                    }
                }
            }
            for (name, data) in vs {
                if let Some(i) = self.param_names.iter().position(|n| *n == name) {
                    if self.opt_v[i].len() == data.len() {
                        self.opt_v[i].copy_from_slice(data);
                    }
                }
            }
            self.step = trainer.step();
        }
        self.chunked = Some(trainer);
        res
    }

    /// A generator matching this artifact's shapes.
    pub fn data_gen(&self, corpus: Arc<Corpus>, seed: u64) -> DataGen {
        let cfg = &self.train_exe.meta.config;
        DataGen::new(
            corpus,
            cfg.max_len,
            cfg.batch,
            cfg.unidirectional,
            self.tag.starts_with("long"),
            seed,
        )
    }

    /// Execute one train step; updates state in place, returns (loss, acc).
    /// Routes through the native SLiM trainer when chunked mode is on.
    pub fn train_step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        if self.chunked.is_some() {
            return self.chunked_train_step(batch);
        }
        let meta = &self.train_exe.meta;
        let mut inputs: Vec<HostValue> = Vec::with_capacity(meta.inputs.len());
        // artifact input order: params, m, v, step, features, tokens,
        // targets, weights — but we index by role to stay contract-driven.
        let mut p_it = self.params.iter();
        let mut m_it = self.opt_m.iter();
        let mut v_it = self.opt_v.iter();
        let mut f_it = self.features.iter();
        for slot in &meta.inputs {
            inputs.push(match slot.role {
                Role::Param => HostValue::F32(p_it.next().unwrap().clone()),
                Role::OptM => HostValue::F32(m_it.next().unwrap().clone()),
                Role::OptV => HostValue::F32(v_it.next().unwrap().clone()),
                Role::OptStep => HostValue::F32(vec![self.step]),
                Role::Feature => HostValue::F32(f_it.next().unwrap().clone()),
                Role::Tokens => HostValue::I32(batch.tokens.clone()),
                Role::Targets => HostValue::I32(batch.targets.clone()),
                Role::Weights => HostValue::F32(batch.weights.clone()),
                other => bail!("unexpected train input role {other:?}"),
            });
        }
        let outputs = self.train_exe.run(&inputs)?;

        // demux outputs by the metadata roles
        let mut loss = f32::NAN;
        let mut acc = f32::NAN;
        let (mut pi, mut mi, mut vi) = (0usize, 0usize, 0usize);
        for (slot, val) in meta.outputs.iter().zip(outputs) {
            match (slot.role, val) {
                (Role::Param, HostValue::F32(v)) => {
                    self.params[pi] = v;
                    pi += 1;
                }
                (Role::OptM, HostValue::F32(v)) => {
                    self.opt_m[mi] = v;
                    mi += 1;
                }
                (Role::OptV, HostValue::F32(v)) => {
                    self.opt_v[vi] = v;
                    vi += 1;
                }
                (Role::OptStep, HostValue::F32(v)) => self.step = v[0],
                (Role::Loss, HostValue::F32(v)) => loss = v[0],
                (Role::Acc, HostValue::F32(v)) => acc = v[0],
                (r, _) => bail!("unexpected train output role {r:?}"),
            }
        }
        if !loss.is_finite() {
            bail!("{}: non-finite loss at step {}", self.tag, self.step);
        }
        Ok((loss, acc))
    }

    /// Evaluate (loss, acc) on one batch without updating state.
    pub fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        if let Some(trainer) = &self.chunked {
            return trainer.eval_step(batch);
        }
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no eval artifact", self.tag))?;
        let meta = &exe.meta;
        let mut inputs = Vec::with_capacity(meta.inputs.len());
        let mut p_it = self.params.iter();
        let mut f_it = self.features.iter();
        for slot in &meta.inputs {
            inputs.push(match slot.role {
                Role::Param => HostValue::F32(p_it.next().unwrap().clone()),
                Role::Feature => HostValue::F32(f_it.next().unwrap().clone()),
                Role::Tokens => HostValue::I32(batch.tokens.clone()),
                Role::Targets => HostValue::I32(batch.targets.clone()),
                Role::Weights => HostValue::F32(batch.weights.clone()),
                other => bail!("unexpected eval input role {other:?}"),
            });
        }
        let out = exe.run(&inputs)?;
        Ok((out[0].scalar_f32()?, out[1].scalar_f32()?))
    }

    /// Mean (loss, acc) over `n` batches from a split.
    pub fn evaluate(&self, gen: &mut DataGen, split: Split, n: usize) -> Result<(f64, f64)> {
        let mut loss = 0.0;
        let mut acc = 0.0;
        for _ in 0..n {
            let b = gen.next_batch(split);
            let (l, a) = self.eval_step(&b)?;
            loss += l as f64;
            acc += a as f64;
        }
        Ok((loss / n as f64, acc / n as f64))
    }

    /// Resample the FAVOR projection features natively (paper Sec. 4.2's
    /// redrawing strategy): regenerates W (and b) with matching shapes.
    pub fn resample_features(&mut self, rng: &mut Pcg64) -> Result<()> {
        if self.chunked.is_some() {
            // the native kernels redraw on their own epoch schedule;
            // swapping the host feature slots under them would desync
            return Ok(());
        }
        let meta = &self.train_exe.meta;
        let attention = meta.config.attention.clone();
        if !attention.starts_with("favor-") {
            return Ok(()); // nothing to resample for exact/lsh/identity
        }
        let kind = FeatureKind::parse_or_err(attention.trim_start_matches("favor-"))
            .map_err(|e| anyhow!("artifact attention '{attention}': {e}"))?;
        let feat_idx = meta.input_indices(Role::Feature);
        for (slot_pos, &i) in feat_idx.iter().enumerate() {
            let slot = &meta.inputs[i];
            match slot.name.as_str() {
                "w" => {
                    let (m, d) = (slot.shape[0], slot.shape[1]);
                    let fm = FeatureMap::sample(kind, m, d, OrfMechanism::Regular, rng);
                    self.features[slot_pos] = fm.w.data;
                }
                "b" => {
                    let m = slot.shape[0];
                    self.features[slot_pos] = if kind == FeatureKind::Softmax {
                        (0..m)
                            .map(|_| rng.uniform_in(0.0, std::f64::consts::TAU) as f32)
                            .collect()
                    } else {
                        vec![0.0; m]
                    };
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Transplant parameters by name from another state (Fig. 3's
    /// backward-compatibility experiment: Transformer -> Performer).
    /// Returns the number of tensors copied.
    pub fn transplant_from(&mut self, donor: &TrainState) -> usize {
        let mut copied = 0;
        for (i, name) in self.param_names.iter().enumerate() {
            if let Some(j) = donor.param_names.iter().position(|n| n == name) {
                if donor.params[j].len() == self.params[i].len() {
                    self.params[i] = donor.params[j].clone();
                    copied += 1;
                }
            }
        }
        self.sync_chunked_from_host();
        copied
    }

    /// Save params + opt state + features to a PFRMTENS checkpoint.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tf = TensorFile::default();
        for (name, data) in self.param_names.iter().zip(&self.params) {
            tf.entries.push((format!("param:{name}"), vec![data.len()], data.clone()));
        }
        for (name, data) in self.param_names.iter().zip(&self.opt_m) {
            tf.entries.push((format!("opt_m:{name}"), vec![data.len()], data.clone()));
        }
        for (name, data) in self.param_names.iter().zip(&self.opt_v) {
            tf.entries.push((format!("opt_v:{name}"), vec![data.len()], data.clone()));
        }
        for (name, data) in self.feature_names.iter().zip(&self.features) {
            tf.entries.push((format!("feature:{name}"), vec![data.len()], data.clone()));
        }
        tf.entries.push(("step".into(), vec![], vec![self.step]));
        tf.write(path)
    }

    /// Restore a checkpoint written by `save_checkpoint`.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let tf = TensorFile::read(path)?;
        for (i, name) in self.param_names.iter().enumerate() {
            if let Some((_, data)) = tf.get(&format!("param:{name}")) {
                self.params[i] = data.to_vec();
            }
            if let Some((_, data)) = tf.get(&format!("opt_m:{name}")) {
                self.opt_m[i] = data.to_vec();
            }
            if let Some((_, data)) = tf.get(&format!("opt_v:{name}")) {
                self.opt_v[i] = data.to_vec();
            }
        }
        for (i, name) in self.feature_names.iter().enumerate() {
            if let Some((_, data)) = tf.get(&format!("feature:{name}")) {
                self.features[i] = data.to_vec();
            }
        }
        if let Some((_, s)) = tf.get("step") {
            self.step = s[0];
        }
        self.sync_chunked_from_host();
        Ok(())
    }
}

/// Anything [`run_training`] can drive: the AOT-artifact
/// [`TrainState`] or the fully native SLiM [`NativeTrainer`].
pub trait TrainStep {
    /// tag used in logs and curve records
    fn tag(&self) -> &str;
    /// one optimizer step; returns (loss, acc)
    fn train_step(&mut self, batch: &Batch) -> Result<(f32, f32)>;
    /// (loss, acc) on one batch without updating state
    fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)>;
    /// whether [`Self::eval_step`] is available
    fn supports_eval(&self) -> bool;
    /// redraw FAVOR features (no-op where the kernel schedule owns it)
    fn resample_features(&mut self, rng: &mut Pcg64) -> Result<()>;
}

impl TrainStep for TrainState {
    fn tag(&self) -> &str {
        &self.tag
    }

    fn train_step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        TrainState::train_step(self, batch)
    }

    fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        TrainState::eval_step(self, batch)
    }

    fn supports_eval(&self) -> bool {
        self.eval_exe.is_some() || self.chunked.is_some()
    }

    fn resample_features(&mut self, rng: &mut Pcg64) -> Result<()> {
        TrainState::resample_features(self, rng)
    }
}

impl TrainStep for NativeTrainer {
    fn tag(&self) -> &str {
        NativeTrainer::tag(self)
    }

    fn train_step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        NativeTrainer::train_step(self, batch)
    }

    fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        NativeTrainer::eval_step(self, batch)
    }

    fn supports_eval(&self) -> bool {
        true
    }

    fn resample_features(&mut self, _rng: &mut Pcg64) -> Result<()> {
        // the kernel redraw schedule (redraw_every) owns feature draws
        Ok(())
    }
}

/// Knobs for [`run_training`].
pub struct LoopOptions {
    /// optimizer steps
    pub steps: usize,
    /// validation cadence (0 = never)
    pub eval_every: usize,
    /// batches per evaluation
    pub eval_batches: usize,
    /// logging cadence
    pub log_every: usize,
    /// redraw FAVOR features every N steps (0 = never)
    pub resample_every: usize,
    /// suppress progress logging
    pub quiet: bool,
}

/// Run the training loop per the options; returns the recorded curve.
/// Generic over [`TrainStep`], so the same loop drives AOT-artifact
/// training and native SLiM chunked training.
pub fn run_training<S: TrainStep>(
    state: &mut S,
    gen: &mut DataGen,
    opts: &LoopOptions,
    seed: u64,
) -> Result<Curve> {
    let mut curve = Curve::new(state.tag());
    let mut rng = Pcg64::new(seed ^ 0xabcdef);
    let t0 = std::time::Instant::now();
    for step in 1..=opts.steps {
        if opts.resample_every > 0 && step % opts.resample_every == 0 {
            state.resample_features(&mut rng)?;
        }
        let batch = gen.next_batch(Split::Train);
        let (loss, acc) = state.train_step(&batch)?;
        curve.push_train(step, loss as f64, acc as f64);
        if !opts.quiet && (step % opts.log_every == 0 || step == 1) {
            eprintln!(
                "[{}] step {step}/{} loss {loss:.4} acc {acc:.3} ({:.2} s/step)",
                state.tag(),
                opts.steps,
                t0.elapsed().as_secs_f64() / step as f64
            );
        }
        if state.supports_eval() && opts.eval_every > 0 && step % opts.eval_every == 0 {
            let mut vl = 0.0f64;
            let mut va = 0.0f64;
            for _ in 0..opts.eval_batches {
                let b = gen.next_batch(Split::Valid);
                let (l, a) = state.eval_step(&b)?;
                vl += l as f64;
                va += a as f64;
            }
            let n = opts.eval_batches.max(1) as f64;
            let (vl, va) = (vl / n, va / n);
            curve.push_valid(step, vl, va);
            if !opts.quiet {
                eprintln!("[{}]   valid loss {vl:.4} acc {va:.3}", state.tag());
            }
        }
    }
    Ok(curve)
}
