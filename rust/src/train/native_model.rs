//! Native forward pass of the Performer/Transformer model, operating on
//! checkpoint weights with the `tensor` substrate.
//!
//! Two purposes:
//!   * analysis — Figs. 7–10 need per-layer, per-head *attention
//!     matrices* from a trained model, which the AOT artifacts (logits
//!     only) don't expose; this replays the model natively and captures
//!     them via the Appendix C.4 one-hot probe equivalents;
//!   * cross-validation — `rust/tests/native_vs_hlo.rs` checks this
//!     implementation's logits against the AOT (Pallas-kerneled) HLO,
//!     pinning both implementations to the same math.

use anyhow::{anyhow, bail, Result};

use crate::favor::{
    attention_matrix_exact, attention_matrix_favor, exact_attention, favor_attention,
    identity_attention, Direction, FeatureKind, FeatureMap,
};
use crate::linalg::OrfMechanism;
use crate::rng::Pcg64;
use crate::runtime::{ArtifactMeta, Role};
use crate::stream::StreamState;
use crate::tensor::Mat;

/// A dense layer (w: in×out, b: out).
struct Dense {
    w: Mat,
    b: Vec<f32>,
}

impl Dense {
    fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.matmul(&self.w);
        for i in 0..out.rows {
            for (v, b) in out.row_mut(i).iter_mut().zip(&self.b) {
                *v += b;
            }
        }
        out
    }
}

struct LayerNorm {
    g: Vec<f32>,
    b: Vec<f32>,
}

impl LayerNorm {
    fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for i in 0..out.rows {
            let row = out.row_mut(i);
            let n = row.len() as f32;
            let mu = row.iter().sum::<f32>() / n;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.g[j] * (*v - mu) * inv + self.b[j];
            }
        }
        out
    }
}

struct Layer {
    ln1: LayerNorm,
    qkv: Dense,
    proj: Dense,
    ln2: LayerNorm,
    ff1: Dense,
    ff2: Dense,
}

/// Which attention the native model runs (matches the artifact config).
pub enum NativeAttention {
    Exact,
    Favor(FeatureMap),
    Identity,
}

/// The assembled native model.
pub struct NativeModel {
    pub d_model: usize,
    pub n_heads: usize,
    pub vocab_size: usize,
    pub direction: Direction,
    embed: Mat,
    lnf: LayerNorm,
    layers: Vec<Layer>,
    pub attention: NativeAttention,
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())
}

/// Sinusoidal position encodings, matching model.py exactly.
fn positions(l: usize, d: usize) -> Mat {
    positions_from(0, l, d)
}

/// Position encodings for rows [offset, offset+l) of a longer stream —
/// row r here equals row offset+r of `positions(offset + l, d)`, so
/// chunked forwards see exactly the single-shot encodings.
fn positions_from(offset: usize, l: usize, d: usize) -> Mat {
    Mat::from_fn(l, d, |pos, i| {
        let angle =
            (offset + pos) as f64 / 10000f64.powf((2 * (i / 2)) as f64 / d as f64);
        if i % 2 == 0 { angle.sin() as f32 } else { angle.cos() as f32 }
    })
}

/// Shape of a synthetically initialized [`NativeModel`] — used by the
/// streaming tests/benches and the `stream` CLI demo, which need a
/// Performer stack without compiled artifacts on disk.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub n_features: usize,
    pub kind: FeatureKind,
    pub direction: Direction,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            vocab_size: crate::protein::vocab::VOCAB_SIZE,
            n_features: 32,
            kind: FeatureKind::Relu,
            direction: Direction::Unidirectional,
        }
    }
}

impl NativeModel {
    /// Build from an artifact's metadata + a name->(shape, data) weight
    /// lookup (init.bin or a checkpoint read as TensorFile entries).
    pub fn from_weights(
        meta: &ArtifactMeta,
        lookup: &dyn Fn(&str) -> Option<Vec<f32>>,
    ) -> Result<NativeModel> {
        let cfg = &meta.config;
        let d = cfg.d_model;
        let shapes: std::collections::HashMap<&str, &[usize]> = meta
            .inputs
            .iter()
            .filter(|s| s.role == Role::Param || s.role == Role::Feature)
            .map(|s| (s.name.as_str(), s.shape.as_slice()))
            .collect();
        let fetch_mat = |name: &str| -> Result<Mat> {
            let data = lookup(name).ok_or_else(|| anyhow!("missing weight {name}"))?;
            let shape = shapes.get(name).ok_or_else(|| anyhow!("no shape for {name}"))?;
            match shape.len() {
                2 => Ok(Mat::from_vec(shape[0], shape[1], data)),
                1 => Ok(Mat::from_vec(1, shape[0], data)),
                n => bail!("{name}: unsupported rank {n}"),
            }
        };
        let fetch_vec = |name: &str| -> Result<Vec<f32>> {
            lookup(name).ok_or_else(|| anyhow!("missing weight {name}"))
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            layers.push(Layer {
                ln1: LayerNorm { g: fetch_vec(&p("ln1/g"))?, b: fetch_vec(&p("ln1/b"))? },
                qkv: Dense { w: fetch_mat(&p("qkv/w"))?, b: fetch_vec(&p("qkv/b"))? },
                proj: Dense { w: fetch_mat(&p("proj/w"))?, b: fetch_vec(&p("proj/b"))? },
                ln2: LayerNorm { g: fetch_vec(&p("ln2/g"))?, b: fetch_vec(&p("ln2/b"))? },
                ff1: Dense { w: fetch_mat(&p("ff1/w"))?, b: fetch_vec(&p("ff1/b"))? },
                ff2: Dense { w: fetch_mat(&p("ff2/w"))?, b: fetch_vec(&p("ff2/b"))? },
            });
        }

        let attention = if cfg.attention.starts_with("favor-") {
            let kind = FeatureKind::parse(cfg.attention.trim_start_matches("favor-"))
                .ok_or_else(|| anyhow!("unknown attention {}", cfg.attention))?;
            let w_shape = shapes.get("w").copied().unwrap_or(&[0, 0]);
            let w = Mat::from_vec(w_shape[0], w_shape[1], fetch_vec("w")?);
            let b = fetch_vec("b").unwrap_or_else(|_| vec![0.0; w_shape[0]]);
            let kernel_eps = if kind == FeatureKind::Softmax { 0.0 } else { 1e-3 };
            NativeAttention::Favor(FeatureMap::from_parts(kind, w, b, kernel_eps))
        } else if cfg.attention == "exact" {
            NativeAttention::Exact
        } else if cfg.attention == "identity" {
            NativeAttention::Identity
        } else {
            bail!("native model does not support attention '{}'", cfg.attention);
        };

        let embed = fetch_mat("embed")?;
        Ok(NativeModel {
            d_model: d,
            n_heads: cfg.n_heads,
            vocab_size: embed.rows,
            direction: if cfg.unidirectional {
                Direction::Unidirectional
            } else {
                Direction::Bidirectional
            },
            embed,
            lnf: LayerNorm { g: fetch_vec("lnf/g")?, b: fetch_vec("lnf/b")? },
            layers,
            attention,
        })
    }

    fn head_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        match &self.attention {
            NativeAttention::Exact => exact_attention(q, k, v, self.direction),
            NativeAttention::Favor(fm) => favor_attention(fm, q, k, v, self.direction),
            NativeAttention::Identity => identity_attention(q, k, v, self.direction),
        }
    }

    /// The attention matrix a head *would* apply (for visualization).
    fn head_attention_matrix(&self, q: &Mat, k: &Mat) -> Mat {
        match &self.attention {
            NativeAttention::Exact | NativeAttention::Identity => {
                attention_matrix_exact(q, k, self.direction)
            }
            NativeAttention::Favor(fm) => attention_matrix_favor(fm, q, k, self.direction),
        }
    }

    /// Forward pass for one sequence. Returns logits (L×vocab) and, if
    /// `capture_attention`, the per-layer per-head attention matrices.
    pub fn forward(
        &self,
        tokens: &[u8],
        capture_attention: bool,
    ) -> (Mat, Vec<Vec<Mat>>) {
        let l = tokens.len();
        let d = self.d_model;
        let h = self.n_heads;
        let dh = d / h;
        let scale = (d as f32).sqrt();

        let mut x = Mat::from_fn(l, d, |i, j| self.embed.at(tokens[i] as usize, j) * scale);
        x.add_assign(&positions(l, d));

        let mut attn_maps: Vec<Vec<Mat>> = Vec::new();
        for layer in &self.layers {
            // attention block
            let normed = layer.ln1.apply(&x);
            let qkv = layer.qkv.apply(&normed); // (L, 3d)
            let mut head_outs = Mat::zeros(l, d);
            let mut layer_maps = Vec::new();
            for head in 0..h {
                let slice = |which: usize| -> Mat {
                    Mat::from_fn(l, dh, |i, j| qkv.at(i, which * d + head * dh + j))
                };
                let (q, k, v) = (slice(0), slice(1), slice(2));
                let out = self.head_attention(&q, &k, &v);
                for i in 0..l {
                    for j in 0..dh {
                        *head_outs.at_mut(i, head * dh + j) = out.at(i, j);
                    }
                }
                if capture_attention {
                    layer_maps.push(self.head_attention_matrix(&q, &k));
                }
            }
            if capture_attention {
                attn_maps.push(layer_maps);
            }
            x.add_assign(&layer.proj.apply(&head_outs));

            // MLP block
            let normed = layer.ln2.apply(&x);
            let mut hmid = layer.ff1.apply(&normed);
            for v in &mut hmid.data {
                *v = gelu(*v);
            }
            x.add_assign(&layer.ff2.apply(&hmid));
        }

        let xf = self.lnf.apply(&x);
        let logits = xf.matmul(&self.embed.t());
        (logits, attn_maps)
    }

    /// Swap the attention mechanism (e.g. exact -> FAVOR on the same
    /// weights — the Fig. 11 error-propagation experiment).
    pub fn with_attention(mut self, attention: NativeAttention) -> Self {
        self.attention = attention;
        self
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Whether this model can be driven chunk-by-chunk: streaming needs
    /// the causal direction (prefix-sum recurrence) and FAVOR attention
    /// (exact attention has no constant-size carried state).
    pub fn is_streamable(&self) -> bool {
        self.direction == Direction::Unidirectional
            && matches!(self.attention, NativeAttention::Favor(_))
    }

    /// Fresh per-layer, per-head streaming attention states for
    /// [`NativeModel::forward_chunk`].
    pub fn make_stream_states(&self) -> Result<Vec<Vec<StreamState>>> {
        let NativeAttention::Favor(fm) = &self.attention else {
            bail!("streaming requires FAVOR attention (exact has no constant-size state)");
        };
        if self.direction != Direction::Unidirectional {
            bail!("streaming requires a unidirectional (causal) model");
        }
        let dh = self.d_model / self.n_heads;
        Ok((0..self.layers.len())
            .map(|_| (0..self.n_heads).map(|_| StreamState::new(fm.m(), dh)).collect())
            .collect())
    }

    /// Streaming forward: run one chunk of a longer token stream through
    /// the whole stack, carrying the per-layer per-head FAVOR prefix-sum
    /// states across calls. `pos_offset` is the global index of
    /// `tokens[0]` in the stream. Feeding a stream chunk by chunk (any
    /// chunking) produces the same logits as a single [`Self::forward`]
    /// over the concatenation, in O(layers·heads·M·d) resident state.
    pub fn forward_chunk(
        &self,
        tokens: &[u8],
        pos_offset: usize,
        states: &mut [Vec<StreamState>],
    ) -> Result<Mat> {
        let NativeAttention::Favor(fm) = &self.attention else {
            bail!("streaming requires FAVOR attention");
        };
        if self.direction != Direction::Unidirectional {
            bail!("streaming requires a unidirectional (causal) model");
        }
        if states.len() != self.layers.len()
            || states.iter().any(|s| s.len() != self.n_heads)
        {
            bail!(
                "stream state shape mismatch: expected {} layers x {} heads",
                self.layers.len(),
                self.n_heads
            );
        }
        let l = tokens.len();
        let d = self.d_model;
        let h = self.n_heads;
        let dh = d / h;
        let scale = (d as f32).sqrt();

        let mut x = Mat::from_fn(l, d, |i, j| self.embed.at(tokens[i] as usize, j) * scale);
        x.add_assign(&positions_from(pos_offset, l, d));

        for (layer, lstates) in self.layers.iter().zip(states.iter_mut()) {
            // attention block, streaming per head
            let normed = layer.ln1.apply(&x);
            let qkv = layer.qkv.apply(&normed); // (chunk, 3d)
            let mut head_outs = Mat::zeros(l, d);
            for (head, st) in lstates.iter_mut().enumerate() {
                let slice = |which: usize| -> Mat {
                    Mat::from_fn(l, dh, |i, j| qkv.at(i, which * d + head * dh + j))
                };
                let (q, k, v) = (slice(0), slice(1), slice(2));
                let qp = fm.apply(&q);
                let kp = fm.apply(&k);
                let out = st.advance(&qp, &kp, &v);
                for i in 0..l {
                    for j in 0..dh {
                        *head_outs.at_mut(i, head * dh + j) = out.at(i, j);
                    }
                }
            }
            x.add_assign(&layer.proj.apply(&head_outs));

            // MLP block
            let normed = layer.ln2.apply(&x);
            let mut hmid = layer.ff1.apply(&normed);
            for v in &mut hmid.data {
                *v = gelu(*v);
            }
            x.add_assign(&layer.ff2.apply(&hmid));
        }

        let xf = self.lnf.apply(&x);
        Ok(xf.matmul(&self.embed.t()))
    }

    /// Randomly initialized model for streaming tests, benches and
    /// artifact-free demos (no checkpoint required).
    pub fn synthetic(cfg: &SyntheticConfig, rng: &mut Pcg64) -> NativeModel {
        assert!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "d_model % n_heads != 0");
        let dh = cfg.d_model / cfg.n_heads;
        let dense = |din: usize, dout: usize, rng: &mut Pcg64| -> Dense {
            let scale = 1.0 / (din as f32).sqrt();
            Dense {
                w: Mat::from_vec(
                    din,
                    dout,
                    rng.gaussian_vec(din * dout).iter().map(|v| v * scale).collect(),
                ),
                b: vec![0.0; dout],
            }
        };
        let ln = |d: usize| LayerNorm { g: vec![1.0; d], b: vec![0.0; d] };
        let layers = (0..cfg.n_layers)
            .map(|_| Layer {
                ln1: ln(cfg.d_model),
                qkv: dense(cfg.d_model, 3 * cfg.d_model, rng),
                proj: dense(cfg.d_model, cfg.d_model, rng),
                ln2: ln(cfg.d_model),
                ff1: dense(cfg.d_model, cfg.d_ff, rng),
                ff2: dense(cfg.d_ff, cfg.d_model, rng),
            })
            .collect();
        let embed = Mat::from_vec(
            cfg.vocab_size,
            cfg.d_model,
            rng.gaussian_vec(cfg.vocab_size * cfg.d_model).iter().map(|v| v * 0.1).collect(),
        );
        let fm = FeatureMap::sample(cfg.kind, cfg.n_features, dh, OrfMechanism::Regular, rng);
        NativeModel {
            d_model: cfg.d_model,
            n_heads: cfg.n_heads,
            vocab_size: cfg.vocab_size,
            direction: cfg.direction,
            embed,
            lnf: ln(cfg.d_model),
            layers,
            attention: NativeAttention::Favor(fm),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positions_match_reference_values() {
        let p = positions(4, 8);
        assert!((p.at(0, 0) - 0.0).abs() < 1e-6); // sin(0)
        assert!((p.at(0, 1) - 1.0).abs() < 1e-6); // cos(0)
        assert!((p.at(1, 0) - 1f32.sin()).abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }
}
