//! Native forward pass of the Performer/Transformer model, operating on
//! checkpoint weights with the `tensor` substrate.
//!
//! Two purposes:
//!   * analysis — Figs. 7–10 need per-layer, per-head *attention
//!     matrices* from a trained model, which the AOT artifacts (logits
//!     only) don't expose; this replays the model natively and captures
//!     them via the Appendix C.4 one-hot probe equivalents;
//!   * cross-validation — `rust/tests/native_vs_hlo.rs` checks this
//!     implementation's logits against the AOT (Pallas-kerneled) HLO,
//!     pinning both implementations to the same math.

use anyhow::{anyhow, bail, Result};

use crate::favor::linear::{favor_bidirectional, favor_unidirectional};
use crate::favor::{
    attention_matrix_exact, attention_matrix_favor, exact_attention, AttentionKernel, Direction,
    FeatureKind, FeatureMap, KernelConfig,
};
use crate::linalg::OrfMechanism;
use crate::obs::trace;
use crate::rng::Pcg64;
use crate::runtime::{ArtifactMeta, Role};
use crate::stream::{advance_vjp, StatePrecision, StreamState};
use crate::tensor::{matmul_at_b, Batch, Mat};

/// A dense layer (w: in×out, b: out).
struct Dense {
    w: Mat,
    b: Vec<f32>,
}

impl Dense {
    fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.matmul(&self.w);
        for i in 0..out.rows {
            for (v, b) in out.row_mut(i).iter_mut().zip(&self.b) {
                *v += b;
            }
        }
        out
    }
}

struct LayerNorm {
    g: Vec<f32>,
    b: Vec<f32>,
}

impl LayerNorm {
    fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for i in 0..out.rows {
            let row = out.row_mut(i);
            let n = row.len() as f32;
            let mu = row.iter().sum::<f32>() / n;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (j, v) in row.iter_mut().enumerate() {
                *v = self.g[j] * (*v - mu) * inv + self.b[j];
            }
        }
        out
    }
}

struct Layer {
    ln1: LayerNorm,
    qkv: Dense,
    proj: Dense,
    ln2: LayerNorm,
    ff1: Dense,
    ff2: Dense,
}

/// Which attention the native model runs (matches the artifact config).
pub enum NativeAttention {
    /// exact softmax attention (the quadratic baseline)
    Exact,
    /// Kernelized FAVOR attention: one [`AttentionKernel`] handle per
    /// layer, so hybrid stacks (different kinds/M/redraw schedules per
    /// layer) are a configuration, not a fork of the forward path.
    Favor(Vec<AttentionKernel>),
    /// pass-through attention (ablation/debug stack)
    Identity,
}

impl NativeAttention {
    /// The same kernel replicated across every layer — the uniform
    /// (non-hybrid) configuration.
    pub fn favor_uniform(kernel: AttentionKernel, n_layers: usize) -> NativeAttention {
        NativeAttention::Favor((0..n_layers).map(|_| kernel.clone()).collect())
    }
}

/// One head's view into the fused QKV matrix: rows `[row_lo,
/// row_lo+len)` of the (B·stride)×3d stack, with the head's q/k/v
/// column blocks addressed in place. `phi_q`/`phi_k` featurize a block
/// without materializing it (`FeatureMap::apply_block` — the fused phi
/// path); `q`/`k`/`v` copy a block out for consumers that need a dense
/// `Mat` (exact attention, the value columns of the FAVOR recurrence).
pub struct HeadView<'a> {
    qkv: &'a Mat,
    row_lo: usize,
    len: usize,
    d: usize,
    dh: usize,
    head: usize,
}

impl HeadView<'_> {
    /// Copy this head's query block out as a dense matrix.
    pub fn q(&self) -> Mat {
        slice_head(self.qkv, self.row_lo, self.len, self.head * self.dh, self.dh)
    }

    /// Copy this head's key block out as a dense matrix.
    pub fn k(&self) -> Mat {
        slice_head(self.qkv, self.row_lo, self.len, self.d + self.head * self.dh, self.dh)
    }

    /// Copy this head's value block out as a dense matrix.
    pub fn v(&self) -> Mat {
        slice_head(self.qkv, self.row_lo, self.len, 2 * self.d + self.head * self.dh, self.dh)
    }

    /// phi(q-block) computed in place on the stacked QKV rows.
    pub fn phi_q(&self, fm: &FeatureMap) -> Mat {
        fm.apply_block(self.qkv, self.row_lo, self.row_lo + self.len, self.head * self.dh)
    }

    /// phi(k-block) computed in place on the stacked QKV rows.
    pub fn phi_k(&self, fm: &FeatureMap) -> Mat {
        fm.apply_block(self.qkv, self.row_lo, self.row_lo + self.len, self.d + self.head * self.dh)
    }
}

/// The assembled native model.
pub struct NativeModel {
    /// model width
    pub d_model: usize,
    /// attention heads per layer
    pub n_heads: usize,
    /// vocabulary size (logit width)
    pub vocab_size: usize,
    /// attention direction (Eq. 1 vs Eq. 2)
    pub direction: Direction,
    embed: Mat,
    lnf: LayerNorm,
    layers: Vec<Layer>,
    /// which attention mechanism the stack runs
    pub attention: NativeAttention,
    /// lazily computed cache for [`Self::weights_digest`]
    digest: std::sync::OnceLock<u64>,
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())
}

/// Copy one head's q/k/v block out of the fused QKV matrix: rows
/// `[row_lo, row_lo+len)`, columns `[col_lo, col_lo+dh)` — a row-wise
/// memcpy instead of the former per-element `Mat::from_fn`.
fn slice_head(qkv: &Mat, row_lo: usize, len: usize, col_lo: usize, dh: usize) -> Mat {
    let mut out = Mat::zeros(len, dh);
    for i in 0..len {
        out.row_mut(i).copy_from_slice(&qkv.row(row_lo + i)[col_lo..col_lo + dh]);
    }
    out
}

/// Position encodings for rows [offset, offset+l) of a longer stream —
/// row r here equals row offset+r of `positions(offset + l, d)`, so
/// chunked forwards see exactly the single-shot encodings. The per-column
/// inverse frequency is hoisted out of the row loop (it is the same
/// `powf` for every position — recomputing it per element dominated the
/// embedding cost of the naive version).
fn positions_from(offset: usize, l: usize, d: usize) -> Mat {
    let freq: Vec<f64> =
        (0..d).map(|i| 10000f64.powf((2 * (i / 2)) as f64 / d as f64)).collect();
    Mat::from_fn(l, d, |pos, i| {
        let angle = (offset + pos) as f64 / freq[i];
        if i % 2 == 0 { angle.sin() as f32 } else { angle.cos() as f32 }
    })
}

/// Shape of a synthetically initialized [`NativeModel`] — used by the
/// streaming tests/benches and the `stream` CLI demo, which need a
/// Performer stack without compiled artifacts on disk.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// model width
    pub d_model: usize,
    /// attention heads per layer
    pub n_heads: usize,
    /// number of transformer layers
    pub n_layers: usize,
    /// feed-forward hidden width
    pub d_ff: usize,
    /// vocabulary size
    pub vocab_size: usize,
    /// number of random features M (every layer, unless overridden
    /// per-layer via [`Self::layer_features`])
    pub n_features: usize,
    /// attention-kernel feature kind (every layer, unless overridden
    /// per-layer via [`Self::layer_kinds`])
    pub kind: FeatureKind,
    /// attention direction (causal streams need `Unidirectional`)
    pub direction: Direction,
    /// ORF mechanism for the kernel draws
    pub mech: OrfMechanism,
    /// base seed of the deterministic kernel-draw schedule; layer `l`
    /// draws from `kernel_seed + l·φ` so layers get independent draws
    pub kernel_seed: u64,
    /// tokens per redraw epoch (0 = never); causal models only
    pub redraw_every: u64,
    /// per-layer feature-kind overrides (hybrid stacks); empty = `kind`
    /// on every layer, otherwise the length must equal `n_layers`
    pub layer_kinds: Vec<FeatureKind>,
    /// per-layer feature-count overrides, mirroring `layer_kinds`:
    /// empty = `n_features` on every layer, otherwise the length must
    /// equal `n_layers`. Snapshots, budgets and fingerprints already
    /// carry per-layer M, so a hybrid-M stack is pure configuration
    pub layer_features: Vec<usize>,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            vocab_size: crate::protein::vocab::VOCAB_SIZE,
            n_features: 32,
            kind: FeatureKind::Relu,
            direction: Direction::Unidirectional,
            mech: OrfMechanism::Regular,
            kernel_seed: 0x5eed,
            redraw_every: 0,
            layer_kinds: Vec::new(),
            layer_features: Vec::new(),
        }
    }
}

impl SyntheticConfig {
    /// The per-layer [`KernelConfig`]s this config describes.
    pub fn layer_kernels(&self) -> Vec<KernelConfig> {
        assert!(
            self.layer_kinds.is_empty() || self.layer_kinds.len() == self.n_layers,
            "layer_kinds must be empty or name all {} layers",
            self.n_layers
        );
        assert!(
            self.layer_features.is_empty() || self.layer_features.len() == self.n_layers,
            "layer_features must be empty or size all {} layers",
            self.n_layers
        );
        (0..self.n_layers)
            .map(|li| KernelConfig {
                kind: self.layer_kinds.get(li).copied().unwrap_or(self.kind),
                m: self.layer_features.get(li).copied().unwrap_or(self.n_features),
                mech: self.mech,
                // golden-ratio stride: distinct, well-separated per-layer
                // seeds from one base seed
                seed: self
                    .kernel_seed
                    .wrapping_add((li as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                redraw_every: self.redraw_every,
            })
            .collect()
    }
}

impl NativeModel {
    /// Build from an artifact's metadata + a name->(shape, data) weight
    /// lookup (init.bin or a checkpoint read as TensorFile entries).
    pub fn from_weights(
        meta: &ArtifactMeta,
        lookup: &dyn Fn(&str) -> Option<Vec<f32>>,
    ) -> Result<NativeModel> {
        let cfg = &meta.config;
        let d = cfg.d_model;
        let shapes: std::collections::HashMap<&str, &[usize]> = meta
            .inputs
            .iter()
            .filter(|s| s.role == Role::Param || s.role == Role::Feature)
            .map(|s| (s.name.as_str(), s.shape.as_slice()))
            .collect();
        let fetch_mat = |name: &str| -> Result<Mat> {
            let data = lookup(name).ok_or_else(|| anyhow!("missing weight {name}"))?;
            let shape = shapes.get(name).ok_or_else(|| anyhow!("no shape for {name}"))?;
            match shape.len() {
                2 => Ok(Mat::from_vec(shape[0], shape[1], data)),
                1 => Ok(Mat::from_vec(1, shape[0], data)),
                n => bail!("{name}: unsupported rank {n}"),
            }
        };
        let fetch_vec = |name: &str| -> Result<Vec<f32>> {
            lookup(name).ok_or_else(|| anyhow!("missing weight {name}"))
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            layers.push(Layer {
                ln1: LayerNorm { g: fetch_vec(&p("ln1/g"))?, b: fetch_vec(&p("ln1/b"))? },
                qkv: Dense { w: fetch_mat(&p("qkv/w"))?, b: fetch_vec(&p("qkv/b"))? },
                proj: Dense { w: fetch_mat(&p("proj/w"))?, b: fetch_vec(&p("proj/b"))? },
                ln2: LayerNorm { g: fetch_vec(&p("ln2/g"))?, b: fetch_vec(&p("ln2/b"))? },
                ff1: Dense { w: fetch_mat(&p("ff1/w"))?, b: fetch_vec(&p("ff1/b"))? },
                ff2: Dense { w: fetch_mat(&p("ff2/w"))?, b: fetch_vec(&p("ff2/b"))? },
            });
        }

        let attention = if cfg.attention.starts_with("favor-") {
            let kind = FeatureKind::parse_or_err(cfg.attention.trim_start_matches("favor-"))
                .map_err(|e| anyhow!("artifact attention '{}': {e}", cfg.attention))?;
            let w_shape = shapes.get("w").copied().unwrap_or(&[0, 0]);
            let w = Mat::from_vec(w_shape[0], w_shape[1], fetch_vec("w")?);
            let b = fetch_vec("b").unwrap_or_else(|_| vec![0.0; w_shape[0]]);
            let kernel_eps = match kind {
                FeatureKind::Softmax => 0.0,
                FeatureKind::Positive => 1e-6,
                _ => 1e-3,
            };
            // checkpoint-loaded features are the kernel's eternal epoch 0:
            // a trained draw cannot be redrawn from a schedule
            let kcfg = KernelConfig {
                kind,
                m: w_shape[0],
                mech: OrfMechanism::Regular,
                seed: 0,
                redraw_every: 0,
            };
            NativeAttention::favor_uniform(
                AttentionKernel::from_feature_map(
                    FeatureMap::from_parts(kind, w, b, kernel_eps),
                    kcfg,
                ),
                cfg.n_layers,
            )
        } else if cfg.attention == "exact" {
            NativeAttention::Exact
        } else if cfg.attention == "identity" {
            NativeAttention::Identity
        } else {
            bail!("native model does not support attention '{}'", cfg.attention);
        };

        let embed = fetch_mat("embed")?;
        Ok(NativeModel {
            d_model: d,
            n_heads: cfg.n_heads,
            vocab_size: embed.rows,
            direction: if cfg.unidirectional {
                Direction::Unidirectional
            } else {
                Direction::Bidirectional
            },
            embed,
            lnf: LayerNorm { g: fetch_vec("lnf/g")?, b: fetch_vec("lnf/b")? },
            layers,
            attention,
            digest: std::sync::OnceLock::new(),
        })
    }

    /// Stateless full-sequence attention for one head of layer `li`.
    /// The FAVOR path featurizes the QKV block in place (fused phi) with
    /// the layer kernel's epoch-0 draw.
    fn head_attention(&self, li: usize, hv: &HeadView) -> Mat {
        match &self.attention {
            NativeAttention::Exact => exact_attention(&hv.q(), &hv.k(), &hv.v(), self.direction),
            NativeAttention::Favor(kernels) => {
                let fm = kernels[li].map_for_epoch(0);
                let qp = hv.phi_q(&fm);
                let kp = hv.phi_k(&fm);
                match self.direction {
                    Direction::Bidirectional => favor_bidirectional(&qp, &kp, &hv.v()),
                    Direction::Unidirectional => favor_unidirectional(&qp, &kp, &hv.v()),
                }
            }
            NativeAttention::Identity => hv.v(),
        }
    }

    /// The attention matrix a head *would* apply (for visualization).
    fn head_attention_matrix(&self, li: usize, q: &Mat, k: &Mat) -> Mat {
        match &self.attention {
            NativeAttention::Exact | NativeAttention::Identity => {
                attention_matrix_exact(q, k, self.direction)
            }
            NativeAttention::Favor(kernels) => {
                attention_matrix_favor(&kernels[li], q, k, self.direction)
            }
        }
    }

    /// Whether any layer kernel has a live redraw schedule.
    fn has_redraw(&self) -> bool {
        matches!(&self.attention, NativeAttention::Favor(kernels)
            if kernels.iter().any(|k| k.config().redraw_every > 0))
    }

    /// The next stream position (> `pos`) at which any layer's kernel
    /// redraws. Chunks are split there so no fused segment crosses an
    /// epoch boundary — the alignment rule that keeps chunked ==
    /// single-shot exact under redrawing.
    fn next_redraw_boundary(&self, pos: u64) -> Option<u64> {
        let NativeAttention::Favor(kernels) = &self.attention else {
            return None;
        };
        crate::favor::kernel::stack_next_boundary(kernels, pos)
    }

    /// The per-layer attention kernels (None for exact/identity models).
    pub fn kernels(&self) -> Option<&[AttentionKernel]> {
        match &self.attention {
            NativeAttention::Favor(kernels) => Some(kernels),
            _ => None,
        }
    }

    /// Forward pass for one sequence. Returns logits (L×vocab) and, if
    /// `capture_attention`, the per-layer per-head attention matrices.
    /// Thin wrapper over [`Self::forward_batch`] with B = 1.
    pub fn forward(
        &self,
        tokens: &[u8],
        capture_attention: bool,
    ) -> (Mat, Vec<Vec<Mat>>) {
        let (mut logits, mut maps) = self.forward_batch(&[tokens], capture_attention);
        (logits.pop().expect("B=1 forward"), maps.pop().unwrap_or_default())
    }

    /// Batched forward pass: B sequences (possibly ragged) fused into one
    /// [`Batch`], so every dense per-token operation — embedding,
    /// LayerNorm, QKV, output projection, FFN, final logits — runs once
    /// over the (B·stride)×d stack instead of B times over small
    /// matrices; attention is dispatched per (sequence, head) on real
    /// rows only. Returns per-sequence logits and, when
    /// `capture_attention`, maps indexed `[seq][layer][head]`.
    pub fn forward_batch(
        &self,
        seqs: &[&[u8]],
        capture_attention: bool,
    ) -> (Vec<Mat>, Vec<Vec<Vec<Mat>>>) {
        // a redraw-scheduled causal model must score a full sequence
        // exactly as the streamed path would (chunked == single-shot is
        // the invariant), so it routes through the epoch-aware chunk
        // forward with fresh state. Attention capture keeps the
        // stateless epoch-0 path: the L×L matrices are an analysis view.
        if !capture_attention && self.has_redraw() && self.is_streamable() {
            let mut states: Vec<Vec<Vec<StreamState>>> =
                seqs.iter().map(|_| self.make_stream_states().expect("streamable")).collect();
            let mut refs: Vec<&mut [Vec<StreamState>]> =
                states.iter_mut().map(|s| s.as_mut_slice()).collect();
            let offsets = vec![0usize; seqs.len()];
            let logits = self
                .forward_chunk_batch(seqs, &offsets, &mut refs)
                .expect("fresh-state chunk forward over a streamable model");
            return (logits, Vec::new());
        }
        let offsets = vec![0usize; seqs.len()];
        self.forward_batch_inner(seqs, &offsets, capture_attention, |li, _, _, hv| {
            self.head_attention(li, hv)
        })
    }

    /// The shared batched layer stack behind every forward path.
    /// `attend(layer, seq, head, head_view)` supplies the per-head
    /// attention outputs — stateless full-sequence attention for
    /// [`Self::forward_batch`], the carried FAVOR prefix-sum recurrence
    /// for [`Self::forward_chunk_batch`]. The [`HeadView`] addresses the
    /// head's q/k/v blocks inside the fused QKV stack in place, so the
    /// FAVOR paths featurize without per-head `slice_head` memcpys.
    fn forward_batch_inner(
        &self,
        seqs: &[&[u8]],
        offsets: &[usize],
        capture_attention: bool,
        mut attend: impl FnMut(usize, usize, usize, &HeadView) -> Mat,
    ) -> (Vec<Mat>, Vec<Vec<Vec<Mat>>>) {
        debug_assert_eq!(seqs.len(), offsets.len());
        let bsz = seqs.len();
        let d = self.d_model;
        let h = self.n_heads;
        let dh = d / h;
        let scale = (d as f32).sqrt();
        let lens: Vec<usize> = seqs.iter().map(|s| s.len()).collect();

        // fused input: embeddings + positions per sequence; padding rows
        // (ragged batches) stay zero and are never read back
        let mut batch = Batch::zeros(&lens, d);
        let stride = batch.stride;
        for (s, tokens) in seqs.iter().enumerate() {
            let pos = positions_from(offsets[s], tokens.len(), d);
            let (lo, _) = batch.seq_rows(s);
            for (i, &tok) in tokens.iter().enumerate() {
                let row = batch.data.row_mut(lo + i);
                let erow = self.embed.row(tok as usize);
                let prow = pos.row(i);
                for j in 0..d {
                    row[j] = erow[j] * scale + prow[j];
                }
            }
        }
        let mut x = batch.data;

        let mut attn_maps: Vec<Vec<Vec<Mat>>> =
            if capture_attention { (0..bsz).map(|_| Vec::new()).collect() } else { Vec::new() };
        for (li, layer) in self.layers.iter().enumerate() {
            let _layer_span = trace::span_n("layer", li as u64);
            // attention block: one fused LayerNorm + QKV over the stack,
            // then per-(sequence, head) attention on real rows
            let normed = layer.ln1.apply(&x);
            let qkv = layer.qkv.apply(&normed); // (B*stride, 3d)
            let mut head_outs = Mat::zeros(x.rows, d);
            for s in 0..bsz {
                let row_lo = s * stride;
                let l = lens[s];
                let mut layer_maps = Vec::new();
                for head in 0..h {
                    let hv = HeadView { qkv: &qkv, row_lo, len: l, d, dh, head };
                    let out = attend(li, s, head, &hv);
                    for i in 0..l {
                        head_outs.row_mut(row_lo + i)[head * dh..(head + 1) * dh]
                            .copy_from_slice(out.row(i));
                    }
                    if capture_attention {
                        layer_maps.push(self.head_attention_matrix(li, &hv.q(), &hv.k()));
                    }
                }
                if capture_attention {
                    attn_maps[s].push(layer_maps);
                }
            }
            x.add_assign(&layer.proj.apply(&head_outs));

            // MLP block, fused over the whole stack
            let normed = layer.ln2.apply(&x);
            let mut hmid = layer.ff1.apply(&normed);
            for v in &mut hmid.data {
                *v = gelu(*v);
            }
            x.add_assign(&layer.ff2.apply(&hmid));
        }

        let xf = self.lnf.apply(&x);
        // the logits inherit the batch's row layout: rewrap them so the
        // per-sequence views come from the same seq_rows arithmetic
        let logits_all = Batch { data: xf.matmul(&self.embed.t()), stride, lens };
        let logits = (0..bsz).map(|s| logits_all.seq_mat(s)).collect();
        (logits, attn_maps)
    }

    /// Swap the attention mechanism (e.g. exact -> FAVOR on the same
    /// weights — the Fig. 11 error-propagation experiment).
    pub fn with_attention(mut self, attention: NativeAttention) -> Self {
        // same invariant `synthetic` enforces: a redraw schedule only
        // means something on the causal (streamable) direction — a
        // bidirectional model would silently never redraw while its
        // kernel signature advertises the schedule
        if let NativeAttention::Favor(kernels) = &attention {
            assert!(
                self.direction == Direction::Unidirectional
                    || kernels.iter().all(|k| k.config().redraw_every == 0),
                "a redraw schedule needs the causal direction (epochs are stream positions)"
            );
        }
        self.attention = attention;
        // the digest covers the feature map: swapping attention
        // invalidates any cached value
        self.digest = std::sync::OnceLock::new();
        self
    }

    /// FNV-1a digest over every parameter byte — embeddings, all layer
    /// weights, the final norm and (for FAVOR) the sampled feature map.
    /// Two models with identical geometry but different weights or
    /// resampled random features get different digests, so carried
    /// stream state can never silently cross models
    /// (`persist::ModelFingerprint` folds this into every snapshot).
    /// Computed once per model and cached.
    pub fn weights_digest(&self) -> u64 {
        *self.digest.get_or_init(|| {
            fn eat(h: &mut u64, data: &[f32]) {
                for v in data {
                    *h = crate::rng::fnv1a64_extend(*h, &v.to_le_bytes());
                }
            }
            let mut h = crate::rng::FNV1A64_SEED;
            eat(&mut h, &self.embed.data);
            for layer in &self.layers {
                for ln in [&layer.ln1, &layer.ln2] {
                    eat(&mut h, &ln.g);
                    eat(&mut h, &ln.b);
                }
                for dense in [&layer.qkv, &layer.proj, &layer.ff1, &layer.ff2] {
                    eat(&mut h, &dense.w.data);
                    eat(&mut h, &dense.b);
                }
            }
            eat(&mut h, &self.lnf.g);
            eat(&mut h, &self.lnf.b);
            if let NativeAttention::Favor(kernels) = &self.attention {
                // each kernel folds in its full identity: config
                // signature (kind/M/mech/seed/redraw schedule) plus the
                // epoch-0 draw bytes
                for kernel in kernels {
                    kernel.digest_into(&mut h);
                }
            }
            h
        })
    }

    /// Number of transformer layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Whether this model can be driven chunk-by-chunk: streaming needs
    /// the causal direction (prefix-sum recurrence) and FAVOR attention
    /// (exact attention has no constant-size carried state).
    pub fn is_streamable(&self) -> bool {
        self.direction == Direction::Unidirectional
            && matches!(self.attention, NativeAttention::Favor(_))
    }

    /// Fresh per-layer, per-head streaming attention states for
    /// [`NativeModel::forward_chunk`].
    pub fn make_stream_states(&self) -> Result<Vec<Vec<StreamState>>> {
        self.make_stream_states_with(StatePrecision::F32)
    }

    /// [`Self::make_stream_states`] with an explicit storage precision
    /// for the carried prefix sums (the SLiM trainer exposes this so
    /// chunked training can run on bf16 boundary checkpoints).
    pub fn make_stream_states_with(
        &self,
        precision: StatePrecision,
    ) -> Result<Vec<Vec<StreamState>>> {
        let NativeAttention::Favor(kernels) = &self.attention else {
            bail!("streaming requires FAVOR attention (exact has no constant-size state)");
        };
        if self.direction != Direction::Unidirectional {
            bail!("streaming requires a unidirectional (causal) model");
        }
        let dh = self.d_model / self.n_heads;
        Ok(kernels
            .iter()
            .map(|k| {
                (0..self.n_heads)
                    .map(|_| StreamState::with_precision(k.m(), dh, precision))
                    .collect()
            })
            .collect())
    }

    /// Streaming forward: run one chunk of a longer token stream through
    /// the whole stack, carrying the per-layer per-head FAVOR prefix-sum
    /// states across calls. `pos_offset` is the global index of
    /// `tokens[0]` in the stream. Feeding a stream chunk by chunk (any
    /// chunking) produces the same logits as a single [`Self::forward`]
    /// over the concatenation, in O(layers·heads·M·d) resident state.
    /// Thin wrapper over [`Self::forward_chunk_batch`] with B = 1.
    pub fn forward_chunk(
        &self,
        tokens: &[u8],
        pos_offset: usize,
        states: &mut [Vec<StreamState>],
    ) -> Result<Mat> {
        let mut refs = [states];
        Ok(self
            .forward_chunk_batch(&[tokens], &[pos_offset], &mut refs)?
            .pop()
            .expect("B=1 forward_chunk"))
    }

    /// Batched streaming forward: advance B independent sessions through
    /// the whole stack in one fused call. `seqs[s]` is session `s`'s next
    /// chunk, `offsets[s]` the global stream index of its first token,
    /// and `states[s]` its carried per-layer per-head FAVOR prefix sums.
    /// Dense work (LayerNorm/QKV/proj/FFN/logits) runs once over the
    /// fused (B·stride)×d stack; each session's attention recurrence
    /// advances on its own rows only, so chunk lengths may differ and
    /// every session produces exactly the logits a sequential
    /// [`Self::forward_chunk`] would.
    pub fn forward_chunk_batch(
        &self,
        seqs: &[&[u8]],
        offsets: &[usize],
        states: &mut [&mut [Vec<StreamState>]],
    ) -> Result<Vec<Mat>> {
        let _span = trace::span_n("forward_chunk_batch", seqs.len() as u64);
        let NativeAttention::Favor(kernels) = &self.attention else {
            bail!("streaming requires FAVOR attention");
        };
        if self.direction != Direction::Unidirectional {
            bail!("streaming requires a unidirectional (causal) model");
        }
        if seqs.len() != offsets.len() || seqs.len() != states.len() {
            bail!(
                "batch arity mismatch: {} seqs, {} offsets, {} states",
                seqs.len(),
                offsets.len(),
                states.len()
            );
        }
        for st in states.iter() {
            if st.len() != self.layers.len() || st.iter().any(|l| l.len() != self.n_heads) {
                bail!(
                    "stream state shape mismatch: expected {} layers x {} heads",
                    self.layers.len(),
                    self.n_heads
                );
            }
            for (li, layer) in st.iter().enumerate() {
                if layer.iter().any(|h| h.m() != kernels[li].m()) {
                    bail!(
                        "stream state layer {li} carries M={}, its kernel expects M={}",
                        layer.first().map_or(0, StreamState::m),
                        kernels[li].m()
                    );
                }
            }
        }

        // Fast path — no kernel redraws (the only configuration
        // artifact-backed models can have): every state is pinned to
        // epoch 0 and no chunk needs splitting, so the logits flow
        // straight out of the fused forward without the per-segment
        // accumulation copy below.
        if !self.has_redraw() {
            let (logits, _) =
                self.forward_batch_inner(seqs, offsets, false, |li, s, head, hv| {
                    let fm = kernels[li].map_for_epoch(0);
                    let qp = hv.phi_q(&fm);
                    let kp = hv.phi_k(&fm);
                    states[s][li][head].advance(&qp, &kp, &hv.v())
                });
            return Ok(logits);
        }

        let bsz = seqs.len();
        let vocab = self.vocab_size;
        // Chunks are consumed in *epoch-aligned segments*: each round
        // takes every session's tokens up to its next redraw boundary,
        // so no fused segment ever crosses an epoch boundary for any
        // layer.
        let mut outs: Vec<Vec<f32>> =
            seqs.iter().map(|s| Vec::with_capacity(s.len() * vocab)).collect();
        let mut done = vec![0usize; bsz];
        loop {
            let mut idxs: Vec<usize> = Vec::new();
            let mut segs: Vec<&[u8]> = Vec::new();
            let mut segoffs: Vec<usize> = Vec::new();
            for s in 0..bsz {
                if done[s] >= seqs[s].len() {
                    continue;
                }
                let pos = offsets[s] + done[s];
                let seg_end = match self.next_redraw_boundary(pos as u64) {
                    Some(boundary) => {
                        (done[s] + (boundary - pos as u64) as usize).min(seqs[s].len())
                    }
                    None => seqs[s].len(),
                };
                idxs.push(s);
                segs.push(&seqs[s][done[s]..seg_end]);
                segoffs.push(pos);
            }
            if idxs.is_empty() {
                break;
            }
            // entering a new epoch resets the carried prefix sums: they
            // live in the previous draw's feature space and cannot be
            // mixed with the new draw's queries
            for (&s, &off) in idxs.iter().zip(&segoffs) {
                for (li, kernel) in kernels.iter().enumerate() {
                    let epoch = kernel.epoch_of(off as u64);
                    for st in states[s][li].iter_mut() {
                        if st.epoch() > epoch {
                            bail!(
                                "stream state of layer {li} is at redraw epoch {} but the \
                                 chunk starts in epoch {epoch}: state and offset disagree",
                                st.epoch()
                            );
                        }
                        if st.epoch() < epoch {
                            st.reset_for_epoch(epoch);
                        }
                    }
                }
            }
            let (logits, _) =
                self.forward_batch_inner(&segs, &segoffs, false, |li, j, head, hv| {
                    let kernel = &kernels[li];
                    let fm = kernel.map_for_epoch(kernel.epoch_of(segoffs[j] as u64));
                    let qp = hv.phi_q(&fm);
                    let kp = hv.phi_k(&fm);
                    states[idxs[j]][li][head].advance(&qp, &kp, &hv.v())
                });
            for (j, logit) in logits.into_iter().enumerate() {
                let s = idxs[j];
                done[s] += segs[j].len();
                outs[s].extend(logit.data);
            }
        }
        Ok(outs
            .into_iter()
            .zip(seqs)
            .map(|(data, seq)| Mat::from_vec(seq.len(), vocab, data))
            .collect())
    }

    /// Randomly initialized model for streaming tests, benches and
    /// artifact-free demos (no checkpoint required).
    pub fn synthetic(cfg: &SyntheticConfig, rng: &mut Pcg64) -> NativeModel {
        assert!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "d_model % n_heads != 0");
        assert!(
            cfg.redraw_every == 0 || cfg.direction == Direction::Unidirectional,
            "a redraw schedule needs the causal direction (epochs are stream positions)"
        );
        let dh = cfg.d_model / cfg.n_heads;
        let dense = |din: usize, dout: usize, rng: &mut Pcg64| -> Dense {
            let scale = 1.0 / (din as f32).sqrt();
            Dense {
                w: Mat::from_vec(
                    din,
                    dout,
                    rng.gaussian_vec(din * dout).iter().map(|v| v * scale).collect(),
                ),
                b: vec![0.0; dout],
            }
        };
        let ln = |d: usize| LayerNorm { g: vec![1.0; d], b: vec![0.0; d] };
        let layers = (0..cfg.n_layers)
            .map(|_| Layer {
                ln1: ln(cfg.d_model),
                qkv: dense(cfg.d_model, 3 * cfg.d_model, rng),
                proj: dense(cfg.d_model, cfg.d_model, rng),
                ln2: ln(cfg.d_model),
                ff1: dense(cfg.d_model, cfg.d_ff, rng),
                ff2: dense(cfg.d_ff, cfg.d_model, rng),
            })
            .collect();
        let embed = Mat::from_vec(
            cfg.vocab_size,
            cfg.d_model,
            rng.gaussian_vec(cfg.vocab_size * cfg.d_model).iter().map(|v| v * 0.1).collect(),
        );
        // kernels draw from the deterministic per-layer schedule, not
        // the model rng: the same KernelConfig always reproduces the
        // same features, which is what redraw epochs and snapshot
        // compatibility are built on
        let kernels: Vec<AttentionKernel> =
            cfg.layer_kernels().into_iter().map(|kc| AttentionKernel::new(kc, dh)).collect();
        NativeModel {
            d_model: cfg.d_model,
            n_heads: cfg.n_heads,
            vocab_size: cfg.vocab_size,
            direction: cfg.direction,
            embed,
            lnf: ln(cfg.d_model),
            layers,
            attention: NativeAttention::Favor(kernels),
            digest: std::sync::OnceLock::new(),
        }
    }
}

/// Gradient of [`gelu`]: d/dx [0.5·x·(1 + tanh(u(x)))] with
/// u = 0.7978845608·(x + 0.044715·x³).
fn gelu_prime(x: f32) -> f32 {
    let u = 0.7978845608 * (x + 0.044715 * x * x * x);
    let th = u.tanh();
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * 0.7978845608 * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Accumulate the column sums of `dy` into `acc` (the bias gradient of
/// a dense layer: b broadcasts over rows, so db = Σ_rows dy).
fn colsum_into(dy: &Mat, acc: &mut [f32]) {
    for i in 0..dy.rows {
        for (a, v) in acc.iter_mut().zip(dy.row(i)) {
            *a += *v;
        }
    }
}

/// Reverse-mode LayerNorm: recompute mu/var/inv from the saved input
/// (bitwise the same expressions as [`LayerNorm::apply`]), accumulate
/// dg/db, return dx.
fn layernorm_vjp(ln: &LayerNorm, x: &Mat, dy: &Mat, dg: &mut [f32], db: &mut [f32]) -> Mat {
    let mut dx = Mat::zeros(x.rows, x.cols);
    let n = x.cols as f32;
    for i in 0..x.rows {
        let xr = x.row(i);
        let dyr = dy.row(i);
        let mu = xr.iter().sum::<f32>() / n;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..x.cols {
            let xhat = (xr[j] - mu) * inv;
            dg[j] += dyr[j] * xhat;
            db[j] += dyr[j];
            let dxhat = dyr[j] * ln.g[j];
            m1 += dxhat;
            m2 += dxhat * xhat;
        }
        m1 /= n;
        m2 /= n;
        let dxr = dx.row_mut(i);
        for j in 0..xr.len() {
            let xhat = (xr[j] - mu) * inv;
            dxr[j] = inv * (dyr[j] * ln.g[j] - m1 - xhat * m2);
        }
    }
    dx
}

/// Gradient slots for one transformer layer, mirroring [`Layer`].
struct LayerGrads {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    qkv_w: Mat,
    qkv_b: Vec<f32>,
    proj_w: Mat,
    proj_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    ff1_w: Mat,
    ff1_b: Vec<f32>,
    ff2_w: Mat,
    ff2_b: Vec<f32>,
}

/// Parameter-gradient buffers mirroring a [`NativeModel`]'s trainable
/// parameters (embeddings, every layer, the final norm). The FAVOR
/// feature maps are kernel draws, not parameters — they have no slot.
///
/// [`Self::slots`]/[`Self::slots_mut`] expose the buffers as
/// `(artifact name, flat data)` pairs in the same canonical order as
/// [`NativeModel::param_slots`], so an optimizer (or a checkpoint
/// writer) can zip the two without knowing the layout.
pub struct ParamGrads {
    embed: Mat,
    layers: Vec<LayerGrads>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

impl ParamGrads {
    /// Zero-initialized gradient buffers shaped like `model`'s
    /// parameters.
    pub fn zeros_like(model: &NativeModel) -> ParamGrads {
        ParamGrads {
            embed: Mat::zeros(model.embed.rows, model.embed.cols),
            layers: model
                .layers
                .iter()
                .map(|l| LayerGrads {
                    ln1_g: vec![0.0; l.ln1.g.len()],
                    ln1_b: vec![0.0; l.ln1.b.len()],
                    qkv_w: Mat::zeros(l.qkv.w.rows, l.qkv.w.cols),
                    qkv_b: vec![0.0; l.qkv.b.len()],
                    proj_w: Mat::zeros(l.proj.w.rows, l.proj.w.cols),
                    proj_b: vec![0.0; l.proj.b.len()],
                    ln2_g: vec![0.0; l.ln2.g.len()],
                    ln2_b: vec![0.0; l.ln2.b.len()],
                    ff1_w: Mat::zeros(l.ff1.w.rows, l.ff1.w.cols),
                    ff1_b: vec![0.0; l.ff1.b.len()],
                    ff2_w: Mat::zeros(l.ff2.w.rows, l.ff2.w.cols),
                    ff2_b: vec![0.0; l.ff2.b.len()],
                })
                .collect(),
            lnf_g: vec![0.0; model.lnf.g.len()],
            lnf_b: vec![0.0; model.lnf.b.len()],
        }
    }

    /// Reset every slot to zero (start of a fresh accumulation).
    pub fn zero(&mut self) {
        for (_, slot) in self.slots_mut() {
            slot.fill(0.0);
        }
    }

    /// Multiply every slot by `c` (e.g. loss-normalization folded in
    /// after accumulation).
    pub fn scale(&mut self, c: f32) {
        for (_, slot) in self.slots_mut() {
            for v in slot.iter_mut() {
                *v *= c;
            }
        }
    }

    /// Largest absolute entry across every slot (diagnostics / tests).
    pub fn max_abs(&self) -> f32 {
        self.slots()
            .iter()
            .flat_map(|(_, s)| s.iter())
            .fold(0.0f32, |a, v| a.max(v.abs()))
    }

    /// `(artifact name, flat gradient data)` pairs in canonical order.
    pub fn slots(&self) -> Vec<(String, &[f32])> {
        let mut out: Vec<(String, &[f32])> = vec![("embed".to_string(), &self.embed.data)];
        for (i, l) in self.layers.iter().enumerate() {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            out.push((p("ln1/g"), &l.ln1_g));
            out.push((p("ln1/b"), &l.ln1_b));
            out.push((p("qkv/w"), &l.qkv_w.data));
            out.push((p("qkv/b"), &l.qkv_b));
            out.push((p("proj/w"), &l.proj_w.data));
            out.push((p("proj/b"), &l.proj_b));
            out.push((p("ln2/g"), &l.ln2_g));
            out.push((p("ln2/b"), &l.ln2_b));
            out.push((p("ff1/w"), &l.ff1_w.data));
            out.push((p("ff1/b"), &l.ff1_b));
            out.push((p("ff2/w"), &l.ff2_w.data));
            out.push((p("ff2/b"), &l.ff2_b));
        }
        out.push(("lnf/g".to_string(), &self.lnf_g));
        out.push(("lnf/b".to_string(), &self.lnf_b));
        out
    }

    /// Mutable [`Self::slots`], same names, same order.
    pub fn slots_mut(&mut self) -> Vec<(String, &mut [f32])> {
        let mut out: Vec<(String, &mut [f32])> =
            vec![("embed".to_string(), &mut self.embed.data)];
        for (i, l) in self.layers.iter_mut().enumerate() {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            out.push((p("ln1/g"), &mut l.ln1_g));
            out.push((p("ln1/b"), &mut l.ln1_b));
            out.push((p("qkv/w"), &mut l.qkv_w.data));
            out.push((p("qkv/b"), &mut l.qkv_b));
            out.push((p("proj/w"), &mut l.proj_w.data));
            out.push((p("proj/b"), &mut l.proj_b));
            out.push((p("ln2/g"), &mut l.ln2_g));
            out.push((p("ln2/b"), &mut l.ln2_b));
            out.push((p("ff1/w"), &mut l.ff1_w.data));
            out.push((p("ff1/b"), &mut l.ff1_b));
            out.push((p("ff2/w"), &mut l.ff2_w.data));
            out.push((p("ff2/b"), &mut l.ff2_b));
        }
        out.push(("lnf/g".to_string(), &mut self.lnf_g));
        out.push(("lnf/b".to_string(), &mut self.lnf_b));
        out
    }
}

/// One transformer layer's saved forward intermediates (see
/// [`ChunkTape`]).
struct LayerTape {
    normed1: Mat,
    qkv: Mat,
    head_outs: Mat,
    x_mid: Mat,
    normed2: Mat,
    hmid_pre: Mat,
}

/// Saved activations for ONE epoch-aligned chunk of a streamed forward
/// ([`NativeModel::forward_chunk_tape`]) — everything the reverse sweep
/// ([`NativeModel::backward_chunk`]) needs, and nothing longer than the
/// chunk: O(L_chunk · layers · (d + d_ff)) floats plus the M×(d+1)
/// entry state per (sequence, layer, head). Feature projections
/// (phi_q/phi_k/v) and the attention recurrence internals are
/// *recomputed* in the backward from the saved QKV stack, so they never
/// rest on the tape.
pub struct ChunkTape {
    lens: Vec<usize>,
    stride: usize,
    offset: usize,
    /// per-layer redraw epoch the chunk ran under
    epochs: Vec<u64>,
    tokens: Vec<Vec<u8>>,
    /// residual-stream stacks: entry to each layer, then the final x
    xs: Vec<Mat>,
    layers: Vec<LayerTape>,
    /// dense f32 image of each head's prefix-sum state at chunk entry
    states_in: Vec<Vec<Vec<Mat>>>,
}

impl ChunkTape {
    /// Resident bytes of the saved activations (the quantity the SLiM
    /// memory bench series tracks): every taped matrix plus the entry
    /// states and token bytes.
    pub fn bytes(&self) -> usize {
        let mat = |m: &Mat| m.data.len() * std::mem::size_of::<f32>();
        let mut total: usize = self.xs.iter().map(mat).sum();
        for lt in &self.layers {
            total += mat(&lt.normed1)
                + mat(&lt.qkv)
                + mat(&lt.head_outs)
                + mat(&lt.x_mid)
                + mat(&lt.normed2)
                + mat(&lt.hmid_pre);
        }
        for seq in &self.states_in {
            for layer in seq {
                total += layer.iter().map(mat).sum::<usize>();
            }
        }
        total + self.tokens.iter().map(Vec::len).sum::<usize>()
    }

    /// Global stream position of the chunk's first token.
    pub fn offset(&self) -> usize {
        self.offset
    }
}

impl NativeModel {
    /// Trainable parameters as `(artifact name, flat data)` pairs —
    /// same names and order as [`ParamGrads::slots`], and the same
    /// names `from_weights`/checkpoints use.
    pub fn param_slots(&self) -> Vec<(String, &[f32])> {
        let mut out: Vec<(String, &[f32])> = vec![("embed".to_string(), &self.embed.data)];
        for (i, l) in self.layers.iter().enumerate() {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            out.push((p("ln1/g"), &l.ln1.g));
            out.push((p("ln1/b"), &l.ln1.b));
            out.push((p("qkv/w"), &l.qkv.w.data));
            out.push((p("qkv/b"), &l.qkv.b));
            out.push((p("proj/w"), &l.proj.w.data));
            out.push((p("proj/b"), &l.proj.b));
            out.push((p("ln2/g"), &l.ln2.g));
            out.push((p("ln2/b"), &l.ln2.b));
            out.push((p("ff1/w"), &l.ff1.w.data));
            out.push((p("ff1/b"), &l.ff1.b));
            out.push((p("ff2/w"), &l.ff2.w.data));
            out.push((p("ff2/b"), &l.ff2.b));
        }
        out.push(("lnf/g".to_string(), &self.lnf.g));
        out.push(("lnf/b".to_string(), &self.lnf.b));
        out
    }

    /// Mutable [`Self::param_slots`] (the optimizer's write path).
    /// Invalidates the cached [`Self::weights_digest`] — mutated
    /// weights are a different model.
    pub fn param_slots_mut(&mut self) -> Vec<(String, &mut [f32])> {
        self.digest = std::sync::OnceLock::new();
        let mut out: Vec<(String, &mut [f32])> =
            vec![("embed".to_string(), &mut self.embed.data)];
        for (i, l) in self.layers.iter_mut().enumerate() {
            let p = |leaf: &str| format!("layers/{i}/{leaf}");
            out.push((p("ln1/g"), &mut l.ln1.g));
            out.push((p("ln1/b"), &mut l.ln1.b));
            out.push((p("qkv/w"), &mut l.qkv.w.data));
            out.push((p("qkv/b"), &mut l.qkv.b));
            out.push((p("proj/w"), &mut l.proj.w.data));
            out.push((p("proj/b"), &mut l.proj.b));
            out.push((p("ln2/g"), &mut l.ln2.g));
            out.push((p("ln2/b"), &mut l.ln2.b));
            out.push((p("ff1/w"), &mut l.ff1.w.data));
            out.push((p("ff1/b"), &mut l.ff1.b));
            out.push((p("ff2/w"), &mut l.ff2.w.data));
            out.push((p("ff2/b"), &mut l.ff2.b));
        }
        out.push(("lnf/g".to_string(), &mut self.lnf.g));
        out.push(("lnf/b".to_string(), &mut self.lnf.b));
        out
    }

    /// Streamed forward over ONE epoch-aligned segment that also
    /// records a [`ChunkTape`] for [`Self::backward_chunk`]. Produces
    /// logits bitwise-identical to [`Self::forward_chunk_batch`] over
    /// the same segment (op-for-op the same arithmetic), advancing
    /// `states` in place exactly as the streaming path does.
    ///
    /// `offset` is the global stream position of every sequence's first
    /// token (training batches advance in lockstep). The segment must
    /// not cross any kernel's redraw boundary, and every carried state
    /// must already sit in the segment's epoch — the caller (the SLiM
    /// segment planner) splits at [`crate::favor::epoch_aligned_segments`]
    /// and applies `reset_for_epoch` first, exactly like the streaming
    /// path's per-segment loop.
    pub fn forward_chunk_tape(
        &self,
        seqs: &[&[u8]],
        offset: usize,
        states: &mut [&mut [Vec<StreamState>]],
    ) -> Result<(Vec<Mat>, ChunkTape)> {
        let NativeAttention::Favor(kernels) = &self.attention else {
            bail!("chunked training requires FAVOR attention");
        };
        if self.direction != Direction::Unidirectional {
            bail!("chunked training requires a unidirectional (causal) model");
        }
        if seqs.len() != states.len() {
            bail!("batch arity mismatch: {} seqs, {} states", seqs.len(), states.len());
        }
        let max_len = seqs.iter().map(|s| s.len()).max().unwrap_or(0);
        if let Some(b) = crate::favor::kernel::stack_next_boundary(kernels, offset as u64) {
            if (offset + max_len) as u64 > b {
                bail!(
                    "tape segment [{offset}, {}) crosses the redraw boundary at {b}: \
                     split at epoch_aligned_segments first",
                    offset + max_len
                );
            }
        }
        let epochs: Vec<u64> = kernels.iter().map(|k| k.epoch_of(offset as u64)).collect();
        for (s, st) in states.iter().enumerate() {
            if st.len() != self.layers.len() || st.iter().any(|l| l.len() != self.n_heads) {
                bail!(
                    "stream state shape mismatch: expected {} layers x {} heads",
                    self.layers.len(),
                    self.n_heads
                );
            }
            for (li, layer) in st.iter().enumerate() {
                for hs in layer {
                    if hs.epoch() != epochs[li] {
                        bail!(
                            "seq {s} layer {li}: state epoch {} != segment epoch {}: \
                             reset_for_epoch before taping",
                            hs.epoch(),
                            epochs[li]
                        );
                    }
                }
            }
        }

        // mirror of forward_batch_inner, capturing what the reverse
        // sweep replays
        let bsz = seqs.len();
        let d = self.d_model;
        let h = self.n_heads;
        let dh = d / h;
        let scale = (d as f32).sqrt();
        let lens: Vec<usize> = seqs.iter().map(|s| s.len()).collect();
        let mut batch = Batch::zeros(&lens, d);
        let stride = batch.stride;
        for (s, tokens) in seqs.iter().enumerate() {
            let pos = positions_from(offset, tokens.len(), d);
            let (lo, _) = batch.seq_rows(s);
            for (i, &tok) in tokens.iter().enumerate() {
                let row = batch.data.row_mut(lo + i);
                let erow = self.embed.row(tok as usize);
                let prow = pos.row(i);
                for j in 0..d {
                    row[j] = erow[j] * scale + prow[j];
                }
            }
        }
        let mut x = batch.data;

        let nl = self.layers.len();
        let mut states_in: Vec<Vec<Vec<Mat>>> =
            (0..bsz).map(|_| (0..nl).map(|_| Vec::with_capacity(h)).collect()).collect();
        let mut xs: Vec<Mat> = Vec::with_capacity(nl + 1);
        let mut ltapes: Vec<LayerTape> = Vec::with_capacity(nl);
        for (li, layer) in self.layers.iter().enumerate() {
            xs.push(x.clone());
            let normed1 = layer.ln1.apply(&x);
            let qkv = layer.qkv.apply(&normed1);
            let mut head_outs = Mat::zeros(x.rows, d);
            let fm = kernels[li].map_for_epoch(epochs[li]);
            for s in 0..bsz {
                let row_lo = s * stride;
                let l = lens[s];
                for head in 0..h {
                    let hv = HeadView { qkv: &qkv, row_lo, len: l, d, dh, head };
                    let st = &mut states[s][li][head];
                    states_in[s][li].push(st.dense());
                    let qp = hv.phi_q(&fm);
                    let kp = hv.phi_k(&fm);
                    let out = st.advance(&qp, &kp, &hv.v());
                    for i in 0..l {
                        head_outs.row_mut(row_lo + i)[head * dh..(head + 1) * dh]
                            .copy_from_slice(out.row(i));
                    }
                }
            }
            x.add_assign(&layer.proj.apply(&head_outs));
            let x_mid = x.clone();
            let normed2 = layer.ln2.apply(&x);
            let hmid_pre = layer.ff1.apply(&normed2);
            let mut hmid = hmid_pre.clone();
            for v in &mut hmid.data {
                *v = gelu(*v);
            }
            x.add_assign(&layer.ff2.apply(&hmid));
            ltapes.push(LayerTape { normed1, qkv, head_outs, x_mid, normed2, hmid_pre });
        }
        xs.push(x.clone());
        let xf = self.lnf.apply(&x);
        let logits_all = Batch { data: xf.matmul(&self.embed.t()), stride, lens: lens.clone() };
        let logits = (0..bsz).map(|s| logits_all.seq_mat(s)).collect();
        let tape = ChunkTape {
            lens,
            stride,
            offset,
            epochs,
            tokens: seqs.iter().map(|s| s.to_vec()).collect(),
            xs,
            layers: ltapes,
            states_in,
        };
        Ok((logits, tape))
    }

    /// Reverse sweep over one taped chunk: accumulate parameter
    /// gradients into `grads` given the logit cotangents `dlogits`
    /// (per sequence, len×vocab) and the cotangents `dstates` of each
    /// head's *end-of-chunk* prefix-sum state. On return, `dstates`
    /// holds the cotangents of each head's *entry* state — the d-state
    /// in / d-state out mirror of the forward's state in / state out —
    /// which the caller chains into the preceding chunk's backward
    /// (zeroing it across a redraw-epoch reset, where the forward
    /// discarded the carried sums).
    pub fn backward_chunk(
        &self,
        tape: &ChunkTape,
        dlogits: &[Mat],
        dstates: &mut [Vec<Vec<Mat>>],
        grads: &mut ParamGrads,
    ) -> Result<()> {
        let NativeAttention::Favor(kernels) = &self.attention else {
            bail!("chunked training requires FAVOR attention");
        };
        let bsz = tape.lens.len();
        if dlogits.len() != bsz || dstates.len() != bsz {
            bail!(
                "batch arity mismatch: tape has {bsz} seqs, {} dlogits, {} dstates",
                dlogits.len(),
                dstates.len()
            );
        }
        let d = self.d_model;
        let h = self.n_heads;
        let dh = d / h;
        let stride = tape.stride;
        let vocab = self.vocab_size;
        let rows = stride * bsz;

        // stack the per-sequence logit cotangents into the fused batch
        // layout; padding rows stay zero and contribute zero gradient
        let mut dlog = Mat::zeros(rows, vocab);
        for s in 0..bsz {
            if dlogits[s].rows != tape.lens[s] || dlogits[s].cols != vocab {
                bail!(
                    "seq {s}: dlogits is {}x{}, expected {}x{vocab}",
                    dlogits[s].rows,
                    dlogits[s].cols,
                    tape.lens[s]
                );
            }
            for i in 0..tape.lens[s] {
                dlog.row_mut(s * stride + i).copy_from_slice(dlogits[s].row(i));
            }
        }

        // logits = lnf(x_last)·embedᵀ — the tied embedding gets both
        // the logit-side and (below) the input-side gradient
        let x_last = tape.xs.last().expect("tape has layer entries");
        let xf = self.lnf.apply(x_last);
        grads.embed.add_assign(&matmul_at_b(&dlog, &xf));
        let dxf = dlog.matmul(&self.embed);
        let mut dx = layernorm_vjp(&self.lnf, x_last, &dxf, &mut grads.lnf_g, &mut grads.lnf_b);

        for (li, layer) in self.layers.iter().enumerate().rev() {
            let lt = &tape.layers[li];
            let lg = &mut grads.layers[li];

            // MLP block: x_out = x_mid + ff2(gelu(ff1(ln2(x_mid))))
            let mut hpost = lt.hmid_pre.clone();
            for v in &mut hpost.data {
                *v = gelu(*v);
            }
            lg.ff2_w.add_assign(&matmul_at_b(&hpost, &dx));
            colsum_into(&dx, &mut lg.ff2_b);
            let mut dhmid = dx.matmul(&layer.ff2.w.t());
            for (g, z) in dhmid.data.iter_mut().zip(&lt.hmid_pre.data) {
                *g *= gelu_prime(*z);
            }
            lg.ff1_w.add_assign(&matmul_at_b(&lt.normed2, &dhmid));
            colsum_into(&dhmid, &mut lg.ff1_b);
            let dnormed2 = dhmid.matmul(&layer.ff1.w.t());
            let mut dx_mid =
                layernorm_vjp(&layer.ln2, &lt.x_mid, &dnormed2, &mut lg.ln2_g, &mut lg.ln2_b);
            dx_mid.add_assign(&dx); // residual skip

            // attention block: x_mid = x_in + proj(head_outs)
            lg.proj_w.add_assign(&matmul_at_b(&lt.head_outs, &dx_mid));
            colsum_into(&dx_mid, &mut lg.proj_b);
            let dhead_outs = dx_mid.matmul(&layer.proj.w.t());
            let mut d_qkv = Mat::zeros(rows, 3 * d);
            let fm = kernels[li].map_for_epoch(tape.epochs[li]);
            for s in 0..bsz {
                let row_lo = s * stride;
                let l = tape.lens[s];
                for head in 0..h {
                    // recompute phi_q/phi_k/v from the taped QKV stack
                    // (bitwise the forward's own featurization)
                    let hv = HeadView { qkv: &lt.qkv, row_lo, len: l, d, dh, head };
                    let qp = hv.phi_q(&fm);
                    let kp = hv.phi_k(&fm);
                    let v = hv.v();
                    let dout = slice_head(&dhead_outs, row_lo, l, head * dh, dh);
                    let g = advance_vjp(
                        &tape.states_in[s][li][head],
                        &qp,
                        &kp,
                        &v,
                        &dout,
                        &dstates[s][li][head],
                    );
                    dstates[s][li][head] = g.dstate_in;
                    fm.vjp_block(&lt.qkv, row_lo, row_lo + l, head * dh, &g.dqp, &mut d_qkv);
                    fm.vjp_block(&lt.qkv, row_lo, row_lo + l, d + head * dh, &g.dkp, &mut d_qkv);
                    for i in 0..l {
                        let col = 2 * d + head * dh;
                        let dst = &mut d_qkv.row_mut(row_lo + i)[col..col + dh];
                        for (a, b) in dst.iter_mut().zip(g.dv.row(i)) {
                            *a += *b;
                        }
                    }
                }
            }
            lg.qkv_w.add_assign(&matmul_at_b(&lt.normed1, &d_qkv));
            colsum_into(&d_qkv, &mut lg.qkv_b);
            let dnormed1 = d_qkv.matmul(&layer.qkv.w.t());
            let mut dx0 =
                layernorm_vjp(&layer.ln1, &tape.xs[li], &dnormed1, &mut lg.ln1_g, &mut lg.ln1_b);
            dx0.add_assign(&dx_mid); // residual skip
            dx = dx0;
        }

        // input rows: x0 = embed[tok]·√d + positions (positions carry
        // no parameters)
        let scale = (d as f32).sqrt();
        for (s, toks) in tape.tokens.iter().enumerate() {
            for (i, &tok) in toks.iter().enumerate() {
                let row = dx.row(s * stride + i);
                let erow = grads.embed.row_mut(tok as usize);
                for j in 0..d {
                    erow[j] += scale * row[j];
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positions_match_reference_values() {
        let p = positions_from(0, 4, 8);
        assert!((p.at(0, 0) - 0.0).abs() < 1e-6); // sin(0)
        assert!((p.at(0, 1) - 1.0).abs() < 1e-6); // cos(0)
        assert!((p.at(1, 0) - 1f32.sin()).abs() < 1e-6);
    }

    #[test]
    fn chunk_offset_positions_match_single_shot_rows() {
        let full = positions_from(0, 24, 8);
        let tail = positions_from(16, 8, 8);
        assert!(tail.max_abs_diff(&full.rows_slice(16, 24)) < 1e-7);
    }

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
    }

    #[test]
    fn slice_head_extracts_block() {
        let m = Mat::from_fn(6, 8, |i, j| (i * 8 + j) as f32);
        let s = slice_head(&m, 2, 3, 5, 2);
        assert_eq!((s.rows, s.cols), (3, 2));
        assert_eq!(s.data, vec![21.0, 22.0, 29.0, 30.0, 37.0, 38.0]);
    }

    #[test]
    fn forward_batch_matches_independent_forwards_ragged() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(17);
        let model = NativeModel::synthetic(&SyntheticConfig::default(), &mut rng);
        let mk = |rng: &mut Pcg64, n: usize| -> Vec<u8> {
            (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
        };
        // ragged on purpose: padding rows must not perturb real rows
        let seqs: Vec<Vec<u8>> = vec![mk(&mut rng, 19), mk(&mut rng, 7), mk(&mut rng, 12)];
        let refs: Vec<&[u8]> = seqs.iter().map(|s| s.as_slice()).collect();
        let (batched, _) = model.forward_batch(&refs, false);
        for (s, seq) in seqs.iter().enumerate() {
            let (single, _) = model.forward(seq, false);
            let diff = batched[s].max_abs_diff(&single);
            assert!(diff < 1e-5, "seq {s}: batched forward diverges by {diff}");
        }
    }

    #[test]
    fn per_layer_feature_counts_forward_and_stream() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(53);
        let cfg = SyntheticConfig {
            layer_features: vec![48, 16],
            ..Default::default()
        };
        let model = NativeModel::synthetic(&cfg, &mut rng);
        let ms: Vec<usize> = model.kernels().unwrap().iter().map(AttentionKernel::m).collect();
        assert_eq!(ms, cfg.layer_features);

        let toks: Vec<u8> = (0..40).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();
        let (single, _) = model.forward(&toks, false);
        assert!(single.data.iter().all(|v| v.is_finite()));

        // per-layer M streams chunked == single-shot (states are shaped
        // per layer: 48×(d_h+1) then 16×(d_h+1))
        let mut states = model.make_stream_states().unwrap();
        assert_eq!(states[0][0].m(), 48);
        assert_eq!(states[1][0].m(), 16);
        let mut streamed = Vec::new();
        for (lo, hi) in [(0usize, 11usize), (11, 25), (25, 40)] {
            streamed.extend(model.forward_chunk(&toks[lo..hi], lo, &mut states).unwrap().data);
        }
        let streamed = Mat::from_vec(40, model.vocab_size, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-4, "per-layer-M chunked forward diverges by {diff}");
    }

    #[test]
    #[should_panic(expected = "layer_features")]
    fn mismatched_layer_features_length_panics() {
        let cfg = SyntheticConfig { layer_features: vec![8], ..Default::default() };
        let _ = cfg.layer_kernels(); // 1 count for 2 layers must refuse
    }

    #[test]
    fn hybrid_per_layer_kernels_forward_and_stream() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(31);
        let cfg = SyntheticConfig {
            layer_kinds: vec![FeatureKind::Relu, FeatureKind::Positive],
            ..Default::default()
        };
        let model = NativeModel::synthetic(&cfg, &mut rng);
        let kinds: Vec<FeatureKind> =
            model.kernels().unwrap().iter().map(AttentionKernel::kind).collect();
        assert_eq!(kinds, cfg.layer_kinds);

        let toks: Vec<u8> = (0..48).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();
        let (single, _) = model.forward(&toks, false);
        assert!(single.data.iter().all(|v| v.is_finite()));

        // the hybrid stack still streams chunked == single-shot
        let mut states = model.make_stream_states().unwrap();
        let mut streamed = Vec::new();
        for (lo, hi) in [(0usize, 13usize), (13, 30), (30, 48)] {
            streamed.extend(model.forward_chunk(&toks[lo..hi], lo, &mut states).unwrap().data);
        }
        let streamed = Mat::from_vec(48, model.vocab_size, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-4, "hybrid chunked forward diverges by {diff}");
    }

    #[test]
    fn redraw_epoch_resets_are_chunk_invariant() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(37);
        let cfg = SyntheticConfig { redraw_every: 20, ..Default::default() };
        let model = NativeModel::synthetic(&cfg, &mut rng);
        let toks: Vec<u8> = (0..64).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();

        // single-shot routes through the epoch-aware path internally
        let (single, _) = model.forward(&toks, false);

        // a chunking that crosses the epoch boundaries at 20/40/60
        // mid-chunk must reproduce it
        let mut states = model.make_stream_states().unwrap();
        let mut streamed = Vec::new();
        for (lo, hi) in [(0usize, 7usize), (7, 33), (33, 64)] {
            streamed.extend(model.forward_chunk(&toks[lo..hi], lo, &mut states).unwrap().data);
        }
        let streamed = Mat::from_vec(64, model.vocab_size, streamed);
        let diff = streamed.max_abs_diff(&single);
        assert!(diff < 1e-4, "redraw chunked forward diverges by {diff}");
        // and the carried states ended in epoch 3 (position 63)
        for layer in &states {
            for st in layer {
                assert_eq!(st.epoch(), 3, "state should track the final epoch");
            }
        }
        // sanity: the redraw model genuinely differs from a never-redraw
        // twin past the first boundary
        let frozen =
            NativeModel::synthetic(&SyntheticConfig { redraw_every: 0, ..cfg }, &mut Pcg64::new(37));
        let (frozen_logits, _) = frozen.forward(&toks, false);
        assert!(
            single.rows_slice(20, 64).max_abs_diff(&frozen_logits.rows_slice(20, 64)) > 1e-6,
            "epochs past the first boundary must use a redrawn kernel"
        );
    }

    #[test]
    fn stale_state_epoch_is_rejected() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(41);
        let model =
            NativeModel::synthetic(&SyntheticConfig { redraw_every: 16, ..Default::default() }, &mut rng);
        let toks: Vec<u8> = (0..32).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();
        let mut states = model.make_stream_states().unwrap();
        model.forward_chunk(&toks, 0, &mut states).unwrap();
        // states are now in epoch 1; replaying an epoch-0 offset must
        // fail loudly instead of mixing feature spaces
        let err = model.forward_chunk(&toks[..8], 0, &mut states).unwrap_err();
        assert!(format!("{err:#}").contains("epoch"), "{err:#}");
    }

    #[test]
    fn param_slots_and_grad_slots_agree() {
        let mut rng = Pcg64::new(61);
        let model = NativeModel::synthetic(&SyntheticConfig::default(), &mut rng);
        let grads = ParamGrads::zeros_like(&model);
        let ps = model.param_slots();
        let gs = grads.slots();
        assert_eq!(ps.len(), gs.len());
        for ((pn, pd), (gn, gd)) in ps.iter().zip(gs.iter()) {
            assert_eq!(pn, gn, "slot order diverged");
            assert_eq!(pd.len(), gd.len(), "slot {pn} shape diverged");
        }
        // the artifact names from_weights expects are all present
        let names: Vec<&str> = ps.iter().map(|(n, _)| n.as_str()).collect();
        for want in ["embed", "layers/0/qkv/w", "layers/1/ff2/b", "lnf/g", "lnf/b"] {
            assert!(names.contains(&want), "missing canonical slot {want}");
        }
    }

    #[test]
    fn param_slots_mut_invalidates_digest() {
        let mut rng = Pcg64::new(62);
        let mut model = NativeModel::synthetic(&SyntheticConfig::default(), &mut rng);
        let before = model.weights_digest();
        model.param_slots_mut()[0].1[0] += 1.0;
        assert_ne!(before, model.weights_digest(), "mutated weights must re-digest");
    }

    #[test]
    fn forward_chunk_tape_matches_streamed_forward_bitwise() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(63);
        let cfg = SyntheticConfig { redraw_every: 16, ..Default::default() };
        let model = NativeModel::synthetic(&cfg, &mut rng);
        let toks: Vec<u8> = (0..32).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();

        let mut streamed = model.make_stream_states().unwrap();
        let mut taped = model.make_stream_states().unwrap();

        // epoch 0 segment [0, 16), then epoch 1 segment [16, 32)
        for (lo, hi) in [(0usize, 16usize), (16, 32)] {
            let expect = model.forward_chunk(&toks[lo..hi], lo, &mut streamed).unwrap();
            for layer in taped.iter_mut() {
                for st in layer.iter_mut() {
                    let epoch = (lo / 16) as u64;
                    if st.epoch() < epoch {
                        st.reset_for_epoch(epoch);
                    }
                }
            }
            let mut refs = [taped.as_mut_slice()];
            let (logits, tape) =
                model.forward_chunk_tape(&[&toks[lo..hi]], lo, &mut refs).unwrap();
            assert_eq!(logits[0].data, expect.data, "tape forward diverged at [{lo},{hi})");
            assert!(tape.bytes() > 0);
            assert_eq!(tape.offset(), lo);
        }

        // crossing a redraw boundary must refuse
        let mut fresh = model.make_stream_states().unwrap();
        let mut refs = [fresh.as_mut_slice()];
        let err = model.forward_chunk_tape(&[&toks[..20]], 0, &mut refs).unwrap_err();
        assert!(format!("{err:#}").contains("boundary"), "{err:#}");
    }

    /// Directional finite-difference check of the whole chunk backward:
    /// perturb every parameter along a random direction and compare the
    /// probe-loss slope against the accumulated analytic gradients.
    /// Sigmoid features keep every op smooth, so the central difference
    /// is trustworthy.
    #[test]
    fn backward_chunk_matches_directional_finite_difference() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let cfg = SyntheticConfig {
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 12,
            n_features: 8,
            kind: FeatureKind::Sigmoid,
            ..Default::default()
        };
        let mut rng = Pcg64::new(7);
        let model = NativeModel::synthetic(&cfg, &mut rng);
        let l = 9usize;
        let toks: Vec<u8> = (0..l).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();
        let w = Mat::from_vec(
            l,
            model.vocab_size,
            rng.gaussian_vec(l * model.vocab_size).iter().map(|v| v * 0.05).collect(),
        );

        // analytic gradients through tape + backward (zero end-state
        // cotangent: the probe loss reads logits only)
        let mut grads = ParamGrads::zeros_like(&model);
        let mut states = model.make_stream_states().unwrap();
        let mut refs = [states.as_mut_slice()];
        let (logits, tape) = model.forward_chunk_tape(&[toks.as_slice()], 0, &mut refs).unwrap();
        let dh = model.d_model / model.n_heads;
        let mut dstates = vec![model
            .kernels()
            .unwrap()
            .iter()
            .map(|k| (0..model.n_heads).map(|_| Mat::zeros(k.m(), dh + 1)).collect())
            .collect::<Vec<Vec<Mat>>>()];
        model.backward_chunk(&tape, &[w.clone()], &mut dstates, &mut grads).unwrap();
        let base: f64 =
            logits[0].data.iter().zip(&w.data).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!(base.is_finite());

        let n_params: usize = model.param_slots().iter().map(|(_, s)| s.len()).sum();
        let dir = Pcg64::new(99).gaussian_vec(n_params);
        let an: f64 = {
            let mut k = 0usize;
            let mut acc = 0.0f64;
            for (_, slot) in grads.slots() {
                for v in slot {
                    acc += *v as f64 * dir[k] as f64;
                    k += 1;
                }
            }
            acc
        };

        let eps = 1e-3f32;
        let probe = |delta: f32| -> f64 {
            let mut m2 = NativeModel::synthetic(&cfg, &mut Pcg64::new(7));
            let mut k = 0usize;
            for (_, slot) in m2.param_slots_mut() {
                for v in slot.iter_mut() {
                    *v += delta * dir[k];
                    k += 1;
                }
            }
            let mut st = m2.make_stream_states().unwrap();
            let out = m2.forward_chunk(&toks, 0, &mut st).unwrap();
            out.data.iter().zip(&w.data).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
        let tol = 2e-3 + 2e-2 * fd.abs().max(an.abs());
        assert!(
            (fd - an).abs() <= tol,
            "directional derivative: fd {fd} vs analytic {an} (base loss {base})"
        );
    }

    #[test]
    fn forward_batch_captures_attention_per_seq() {
        use crate::protein::vocab::{AA_BASE, N_AA};
        let mut rng = Pcg64::new(23);
        let model = NativeModel::synthetic(&SyntheticConfig::default(), &mut rng);
        let toks: Vec<u8> = (0..9).map(|_| AA_BASE + rng.below(N_AA) as u8).collect();
        let (_, maps) = model.forward_batch(&[toks.as_slice(), toks.as_slice()], true);
        assert_eq!(maps.len(), 2);
        for seq_maps in &maps {
            assert_eq!(seq_maps.len(), model.n_layers());
            assert_eq!(seq_maps[0].len(), model.n_heads);
            assert_eq!((seq_maps[0][0].rows, seq_maps[0][0].cols), (9, 9));
        }
    }
}
