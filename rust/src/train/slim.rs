//! Sub-linear-memory chunked training (SLiM).
//!
//! "Sub-Linear Memory: How to Make Performers SLiM" observes that the
//! causal-FAVOR prefix-sum decomposition — the same one the streaming
//! scorer exploits for inference (`stream::StreamState`,
//! `NativeModel::forward_chunk_batch`) — admits a chunked
//! forward+backward: run the forward in fixed-size chunks carrying only
//! the M×(d+1) prefix sums across boundaries, checkpoint the boundary
//! states (not the activations), then sweep the chunks in reverse,
//! recomputing each chunk's activations right before its backward and
//! chaining the attention-state cotangent (d-state in / d-state out)
//! across boundaries. Peak activation memory is O(L_chunk), independent
//! of sequence length; the O(L/L_chunk) boundary checkpoints are
//! constant-size states, orders of magnitude smaller.
//!
//! Segments are **epoch-aligned**: chunk cuts are the union of the
//! fixed chunk grid and every layer kernel's redraw boundaries
//! ([`crate::favor::epoch_aligned_segments`]), the exact alignment rule
//! the streaming forward uses, so chunked training sees bit-for-bit the
//! forward the full-sequence (single-segment) path computes. Where the
//! forward reset a layer's carried sums at an epoch boundary, the
//! backward zeroes that layer's state cotangent across the same
//! boundary — gradients cannot flow through a reset.
//!
//! The full-sequence gradient oracle is this same code with
//! `chunk_len = 0` (one segment covering the sequence), which is what
//! `rust/tests/prop_train.rs` pins chunked runs against.

use std::path::Path;

use anyhow::{bail, Result};

use crate::favor::kernel::epoch_aligned_segments;
use crate::protein::Batch;
use crate::runtime::TensorFile;
use crate::stream::{StatePrecision, StreamState};
use crate::tensor::Mat;

use super::native_model::{NativeModel, ParamGrads};

/// What the backward sweep does about chunk activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecomputePolicy {
    /// recompute each chunk's activations from its boundary state
    /// during the reverse sweep (O(L_chunk) peak activation memory —
    /// the SLiM scheme)
    Recompute,
    /// keep every chunk's tape from the forward pass (O(L) activation
    /// memory, one forward — the speed/memory trade's other corner,
    /// and bitwise identical to `Recompute` since the recomputed
    /// forward replays the same arithmetic)
    Retain,
}

/// Configuration for chunked (SLiM) training.
#[derive(Clone, Copy, Debug)]
pub struct ChunkedTrainConfig {
    /// chunk length L_c (0 = one segment over the whole sequence — the
    /// full-sequence oracle; redraw boundaries still split segments)
    pub chunk_len: usize,
    /// recompute vs retain chunk activations in the backward sweep
    pub policy: RecomputePolicy,
    /// storage precision of the carried/checkpointed prefix sums
    pub precision: StatePrecision,
}

impl Default for ChunkedTrainConfig {
    fn default() -> Self {
        ChunkedTrainConfig {
            chunk_len: 0,
            policy: RecomputePolicy::Recompute,
            precision: StatePrecision::F32,
        }
    }
}

/// Activation-memory accounting for one chunked loss+grad call —
/// analytic byte counts of what the sweep keeps resident, the series
/// `benches/train_memory.rs` records.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemStats {
    /// peak resident activation bytes: chunk tape(s) + the segment's
    /// logit cotangents + the carried state cotangents
    pub peak_activation_bytes: usize,
    /// total bytes of cloned boundary states (the O(L/L_c) checkpoint
    /// term; zero under [`RecomputePolicy::Retain`])
    pub boundary_state_bytes: usize,
    /// bytes of the per-(seq, layer, head) state cotangents
    pub dstate_bytes: usize,
    /// epoch-aligned segments the sequence was split into
    pub segments: usize,
}

/// Result of one chunked loss+gradient evaluation.
#[derive(Clone, Copy, Debug)]
pub struct ChunkedOutcome {
    /// weighted mean cross-entropy over the batch
    pub loss: f32,
    /// weighted token accuracy over the batch
    pub acc: f32,
    /// total loss weight of the batch
    pub w_total: f32,
    /// memory accounting for this call
    pub mem: MemStats,
}

/// The epoch-aligned segment plan for sequences of length `l` starting
/// at stream position 0: cut at every multiple of `chunk_len` (0 =
/// no fixed grid) **and** at every kernel redraw boundary. Returns
/// `(start, end)` position pairs tiling `[0, l)`.
pub fn plan_segments(model: &NativeModel, l: usize, chunk_len: usize) -> Result<Vec<(usize, usize)>> {
    let Some(kernels) = model.kernels() else {
        bail!("chunked training requires FAVOR attention");
    };
    let mut segs = Vec::new();
    for (a, b) in epoch_aligned_segments(kernels, 0, l) {
        let mut cur = a;
        while cur < b {
            let end = if chunk_len == 0 { b } else { ((cur / chunk_len + 1) * chunk_len).min(b) };
            segs.push((cur, end));
            cur = end;
        }
    }
    Ok(segs)
}

/// Weighted cross-entropy + accuracy + logit cotangents for the rows
/// `[lo, hi)` of sequence `s` of the batch. Returns the weighted loss
/// and accuracy *sums* (caller divides by `w_total`); `dlogits` rows
/// are already scaled by `w_i / w_total` so the chunk backward can
/// consume them directly.
fn loss_and_dlogits(
    logits: &Mat,
    batch: &Batch,
    s: usize,
    lo: usize,
    w_total: f32,
) -> (f64, f64, Mat) {
    let vocab = logits.cols;
    let mut dl = Mat::zeros(logits.rows, vocab);
    let mut loss = 0.0f64;
    let mut acc = 0.0f64;
    for i in 0..logits.rows {
        let idx = s * batch.l + lo + i;
        let w = batch.weights[idx];
        if w == 0.0 {
            continue;
        }
        let y = batch.targets[idx] as usize;
        let row = logits.row(i);
        // numerically stable logsumexp in f64 (association-stable
        // across chunkings: per-row, not per-segment)
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - mx) as f64).exp();
        }
        let lse = mx as f64 + sum.ln();
        loss += w as f64 * (lse - row[y] as f64);
        let top = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if top == y {
            acc += w as f64;
        }
        let scale = w / w_total;
        let dr = dl.row_mut(i);
        for (j, g) in dr.iter_mut().enumerate() {
            let p = (((row[j] - mx) as f64).exp() / sum) as f32;
            *g = scale * (p - if j == y { 1.0 } else { 0.0 });
        }
    }
    (loss, acc, dl)
}

fn batch_rows(batch: &Batch) -> Result<Vec<Vec<u8>>> {
    let mut rows = Vec::with_capacity(batch.b);
    for s in 0..batch.b {
        let mut row = Vec::with_capacity(batch.l);
        for i in 0..batch.l {
            let t = batch.tokens[s * batch.l + i];
            if !(0..=255).contains(&t) {
                bail!("token id {t} out of the native vocab range");
            }
            row.push(t as u8);
        }
        rows.push(row);
    }
    Ok(rows)
}

fn zero_dstates(model: &NativeModel, bsz: usize) -> Vec<Vec<Vec<Mat>>> {
    let dh = model.d_model / model.n_heads;
    let kernels = model.kernels().expect("FAVOR model");
    (0..bsz)
        .map(|_| {
            kernels
                .iter()
                .map(|k| (0..model.n_heads).map(|_| Mat::zeros(k.m(), dh + 1)).collect())
                .collect()
        })
        .collect()
}

/// Evaluation-only chunked forward: weighted (loss, acc) of one batch
/// at O(L_chunk) activation memory, no tapes, no gradients.
pub fn chunked_loss(
    model: &NativeModel,
    batch: &Batch,
    cfg: &ChunkedTrainConfig,
) -> Result<(f32, f32)> {
    let seqs = batch_rows(batch)?;
    let segments = plan_segments(model, batch.l, cfg.chunk_len)?;
    let w_total: f32 = batch.weights.iter().map(|&w| w as f64).sum::<f64>() as f32;
    if w_total <= 0.0 {
        bail!("batch has zero loss weight");
    }
    let mut states: Vec<Vec<Vec<StreamState>>> =
        (0..batch.b).map(|_| model.make_stream_states_with(cfg.precision)).collect::<Result<_>>()?;
    let mut loss = 0.0f64;
    let mut acc = 0.0f64;
    for &(lo, hi) in &segments {
        align_states_to(model, &mut states, lo)?;
        let segs: Vec<&[u8]> = seqs.iter().map(|r| &r[lo..hi]).collect();
        let offsets = vec![lo; batch.b];
        let mut refs: Vec<&mut [Vec<StreamState>]> =
            states.iter_mut().map(|s| s.as_mut_slice()).collect();
        let logits = model.forward_chunk_batch(&segs, &offsets, &mut refs)?;
        for (s, lg) in logits.iter().enumerate() {
            let (l, a, _) = loss_and_dlogits(lg, batch, s, lo, w_total);
            loss += l;
            acc += a;
        }
    }
    Ok(((loss / w_total as f64) as f32, (acc / w_total as f64) as f32))
}

/// Advance every carried state into the epoch of stream position `pos`
/// — the same reset rule `forward_chunk_batch` applies per segment.
fn align_states_to(
    model: &NativeModel,
    states: &mut [Vec<Vec<StreamState>>],
    pos: usize,
) -> Result<()> {
    let kernels = model.kernels().expect("FAVOR model");
    for st in states.iter_mut() {
        for (li, kernel) in kernels.iter().enumerate() {
            let epoch = kernel.epoch_of(pos as u64);
            for hs in st[li].iter_mut() {
                if hs.epoch() > epoch {
                    bail!(
                        "layer {li} state is at epoch {} past segment epoch {epoch}",
                        hs.epoch()
                    );
                }
                if hs.epoch() < epoch {
                    hs.reset_for_epoch(epoch);
                }
            }
        }
    }
    Ok(())
}

/// One chunked loss + gradient evaluation over a batch: SLiM forward
/// (boundary-state checkpoints), reverse recompute-and-backward sweep,
/// gradients accumulated into `grads` (zeroed first). With
/// `chunk_len = 0` this runs one segment per redraw epoch — the
/// full-sequence oracle the property tests compare against.
pub fn chunked_loss_and_grad(
    model: &NativeModel,
    batch: &Batch,
    cfg: &ChunkedTrainConfig,
    grads: &mut ParamGrads,
) -> Result<ChunkedOutcome> {
    grads.zero();
    let seqs = batch_rows(batch)?;
    let segments = plan_segments(model, batch.l, cfg.chunk_len)?;
    let kernels = model.kernels().expect("FAVOR model");
    let w_total: f32 = batch.weights.iter().map(|&w| w as f64).sum::<f64>() as f32;
    if w_total <= 0.0 {
        bail!("batch has zero loss weight");
    }

    // ---- pass 1: forward over the segments, carrying prefix sums ----
    // Under Recompute we checkpoint each segment's entry states and run
    // the tape-free streaming forward; under Retain we keep the tapes
    // (and the logit cotangents) so the reverse sweep replays nothing.
    let mut states: Vec<Vec<Vec<StreamState>>> =
        (0..batch.b).map(|_| model.make_stream_states_with(cfg.precision)).collect::<Result<_>>()?;
    let mut loss = 0.0f64;
    let mut acc = 0.0f64;
    let mut checkpoints: Vec<Vec<Vec<Vec<StreamState>>>> = Vec::new(); // [segment][seq]
    let mut retained: Vec<(super::native_model::ChunkTape, Vec<Mat>)> = Vec::new();
    let mut boundary_state_bytes = 0usize;
    let mut seg_tape_bytes: Vec<usize> = Vec::with_capacity(segments.len());
    for &(lo, hi) in &segments {
        align_states_to(model, &mut states, lo)?;
        let segs: Vec<&[u8]> = seqs.iter().map(|r| &r[lo..hi]).collect();
        let mut refs: Vec<&mut [Vec<StreamState>]> =
            states.iter_mut().map(|s| s.as_mut_slice()).collect();
        match cfg.policy {
            RecomputePolicy::Recompute => {
                // boundary checkpoint: clone the (possibly quantized)
                // entry states — restoring replays the forward exactly
                let snap: Vec<Vec<Vec<StreamState>>> =
                    (0..batch.b).map(|s| refs[s].to_vec()).collect();
                boundary_state_bytes += snap
                    .iter()
                    .flat_map(|s| s.iter())
                    .flat_map(|l| l.iter())
                    .map(StreamState::state_bytes)
                    .sum::<usize>();
                checkpoints.push(snap);
                let offsets = vec![lo; batch.b];
                let logits = model.forward_chunk_batch(&segs, &offsets, &mut refs)?;
                let mut tape_bytes = 0usize;
                for (s, lg) in logits.iter().enumerate() {
                    let (l, a, _) = loss_and_dlogits(lg, batch, s, lo, w_total);
                    loss += l;
                    acc += a;
                    tape_bytes += lg.data.len() * std::mem::size_of::<f32>();
                }
                seg_tape_bytes.push(tape_bytes);
            }
            RecomputePolicy::Retain => {
                let (logits, tape) = model.forward_chunk_tape(&segs, lo, &mut refs)?;
                let mut dls = Vec::with_capacity(batch.b);
                for (s, lg) in logits.iter().enumerate() {
                    let (l, a, dl) = loss_and_dlogits(lg, batch, s, lo, w_total);
                    loss += l;
                    acc += a;
                    dls.push(dl);
                }
                seg_tape_bytes.push(
                    tape.bytes()
                        + dls.iter().map(|d| d.data.len() * 4).sum::<usize>(),
                );
                retained.push((tape, dls));
            }
        }
    }

    // ---- pass 2: reverse sweep, chaining the state cotangents ----
    let mut dstates = zero_dstates(model, batch.b);
    let dstate_bytes: usize = dstates
        .iter()
        .flat_map(|s| s.iter())
        .flat_map(|l| l.iter())
        .map(|m| m.data.len() * std::mem::size_of::<f32>())
        .sum();
    let mut peak = 0usize;
    for (t, &(lo, hi)) in segments.iter().enumerate().rev() {
        let (tape, dls, resident) = match cfg.policy {
            RecomputePolicy::Recompute => {
                // restore the boundary checkpoint and replay the chunk
                // with a tape — bitwise the pass-1 forward
                let mut snap = std::mem::take(&mut checkpoints[t]);
                let segs: Vec<&[u8]> = seqs.iter().map(|r| &r[lo..hi]).collect();
                let mut refs: Vec<&mut [Vec<StreamState>]> =
                    snap.iter_mut().map(|s| s.as_mut_slice()).collect();
                let (logits, tape) = model.forward_chunk_tape(&segs, lo, &mut refs)?;
                let mut dls = Vec::with_capacity(batch.b);
                for (s, lg) in logits.iter().enumerate() {
                    let (_, _, dl) = loss_and_dlogits(lg, batch, s, lo, w_total);
                    dls.push(dl);
                }
                let resident = tape.bytes()
                    + dls.iter().map(|d| d.data.len() * 4).sum::<usize>()
                    + dstate_bytes;
                (tape, dls, resident)
            }
            RecomputePolicy::Retain => {
                let (tape, dls) = retained.pop().expect("one retained tape per segment");
                // everything retained is resident at once
                let resident = seg_tape_bytes.iter().sum::<usize>() + dstate_bytes;
                (tape, dls, resident)
            }
        };
        peak = peak.max(resident);
        model.backward_chunk(&tape, &dls, &mut dstates, grads)?;
        // where the forward reset a layer's carried sums entering this
        // segment, no gradient flows into the previous epoch's state
        if t > 0 {
            let prev = segments[t - 1].0;
            for (li, kernel) in kernels.iter().enumerate() {
                if kernel.epoch_of(lo as u64) != kernel.epoch_of(prev as u64) {
                    for ds in dstates.iter_mut() {
                        for m in ds[li].iter_mut() {
                            m.data.fill(0.0);
                        }
                    }
                }
            }
        }
    }

    Ok(ChunkedOutcome {
        loss: (loss / w_total as f64) as f32,
        acc: (acc / w_total as f64) as f32,
        w_total,
        mem: MemStats {
            peak_activation_bytes: peak,
            boundary_state_bytes,
            dstate_bytes,
            segments: segments.len(),
        },
    })
}

/// A fully native trainer over a [`NativeModel`]: SLiM chunked
/// loss+grad plus a host Adam step, with checkpoints in the exact
/// `PFRMTENS` layout `TrainState::save_checkpoint` writes
/// (`param:{name}` / `opt_m:{name}` / `opt_v:{name}` / `step`), so
/// chunked runs restore through the same tooling. FAVOR feature draws
/// are deterministic kernel schedules, not parameters — they are not
/// checkpointed.
pub struct NativeTrainer {
    model: NativeModel,
    cfg: ChunkedTrainConfig,
    grads: ParamGrads,
    opt_m: ParamGrads,
    opt_v: ParamGrads,
    step: f32,
    lr: f32,
    tag: String,
    last_mem: Option<MemStats>,
}

impl NativeTrainer {
    /// Wrap a streamable model for chunked training.
    pub fn new(model: NativeModel, cfg: ChunkedTrainConfig, lr: f32, tag: &str) -> Result<Self> {
        if !model.is_streamable() {
            bail!("chunked training requires a causal FAVOR model");
        }
        let grads = ParamGrads::zeros_like(&model);
        let opt_m = ParamGrads::zeros_like(&model);
        let opt_v = ParamGrads::zeros_like(&model);
        Ok(NativeTrainer {
            model,
            cfg,
            grads,
            opt_m,
            opt_v,
            step: 0.0,
            lr,
            tag: tag.to_string(),
            last_mem: None,
        })
    }

    /// The wrapped model.
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Mutable model access (weight sync from a `TrainState`).
    pub fn model_mut(&mut self) -> &mut NativeModel {
        &mut self.model
    }

    /// The chunking configuration.
    pub fn config(&self) -> &ChunkedTrainConfig {
        &self.cfg
    }

    /// Optimizer step counter.
    pub fn step(&self) -> f32 {
        self.step
    }

    /// Overwrite the optimizer step counter (checkpoint sync).
    pub fn set_step(&mut self, step: f32) {
        self.step = step;
    }

    /// Memory accounting of the most recent `train_step`.
    pub fn last_mem(&self) -> Option<&MemStats> {
        self.last_mem.as_ref()
    }

    /// Adam first/second moments as named slots (checkpoint sync).
    pub fn opt_slots(&self) -> (Vec<(String, &[f32])>, Vec<(String, &[f32])>) {
        (self.opt_m.slots(), self.opt_v.slots())
    }

    /// Mutable [`Self::opt_slots`].
    pub fn opt_slots_mut(
        &mut self,
    ) -> (Vec<(String, &mut [f32])>, Vec<(String, &mut [f32])>) {
        (self.opt_m.slots_mut(), self.opt_v.slots_mut())
    }

    /// One SLiM train step: chunked loss+grad, then a bias-corrected
    /// Adam update (β₁ 0.9, β₂ 0.999, ε 1e-8). Returns (loss, acc).
    pub fn train_step(&mut self, batch: &Batch) -> Result<(f32, f32)> {
        let outcome = chunked_loss_and_grad(&self.model, batch, &self.cfg, &mut self.grads)?;
        if !outcome.loss.is_finite() {
            bail!("{}: non-finite chunked loss at step {}", self.tag, self.step);
        }
        self.last_mem = Some(outcome.mem);
        self.step += 1.0;
        let t = self.step;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let c1 = 1.0 - b1.powf(t);
        let c2 = 1.0 - b2.powf(t);
        let lr = self.lr;
        for (((pn, p), (gn, g)), ((mn, m), (vn, v))) in self
            .model
            .param_slots_mut()
            .into_iter()
            .zip(self.grads.slots())
            .zip(self.opt_m.slots_mut().into_iter().zip(self.opt_v.slots_mut()))
        {
            debug_assert!(pn == gn && pn == mn && pn == vn, "slot order diverged");
            for k in 0..p.len() {
                let gk = g[k];
                m[k] = b1 * m[k] + (1.0 - b1) * gk;
                v[k] = b2 * v[k] + (1.0 - b2) * gk * gk;
                let mhat = m[k] / c1;
                let vhat = v[k] / c2;
                p[k] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        Ok((outcome.loss, outcome.acc))
    }

    /// (loss, acc) of one batch without updating anything.
    pub fn eval_step(&self, batch: &Batch) -> Result<(f32, f32)> {
        chunked_loss(&self.model, batch, &self.cfg)
    }

    /// Save params + Adam moments + step in `TrainState`'s checkpoint
    /// layout.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tf = TensorFile::default();
        for (name, data) in self.model.param_slots() {
            tf.entries.push((format!("param:{name}"), vec![data.len()], data.to_vec()));
        }
        for (name, data) in self.opt_m.slots() {
            tf.entries.push((format!("opt_m:{name}"), vec![data.len()], data.to_vec()));
        }
        for (name, data) in self.opt_v.slots() {
            tf.entries.push((format!("opt_v:{name}"), vec![data.len()], data.to_vec()));
        }
        tf.entries.push(("step".into(), vec![], vec![self.step]));
        tf.write(path)
    }

    /// Restore a checkpoint written by [`Self::save_checkpoint`] (or by
    /// `TrainState::save_checkpoint` for a matching architecture).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let tf = TensorFile::read(path)?;
        for (name, slot) in self.model.param_slots_mut() {
            if let Some((_, data)) = tf.get(&format!("param:{name}")) {
                if data.len() != slot.len() {
                    bail!("checkpoint param {name}: {} values, expected {}", data.len(), slot.len());
                }
                slot.copy_from_slice(data);
            }
        }
        for (name, slot) in self.opt_m.slots_mut() {
            if let Some((_, data)) = tf.get(&format!("opt_m:{name}")) {
                if data.len() == slot.len() {
                    slot.copy_from_slice(data);
                }
            }
        }
        for (name, slot) in self.opt_v.slots_mut() {
            if let Some((_, data)) = tf.get(&format!("opt_v:{name}")) {
                if data.len() == slot.len() {
                    slot.copy_from_slice(data);
                }
            }
        }
        if let Some((_, s)) = tf.get("step") {
            self.step = s[0];
        }
        Ok(())
    }

    /// Tag used in logs and error messages.
    pub fn tag(&self) -> &str {
        &self.tag
    }
}
