//! Training orchestration: the AOT train-step driver, data streaming,
//! curve recording, checkpoints and weight transplant (for the Fig. 3
//! backward-compatibility experiment).

pub mod curve;
pub mod native_model;
pub mod driver;

pub use curve::{Curve, Point};
pub use native_model::{NativeAttention, NativeModel, SyntheticConfig};
pub use driver::{run_training, DataGen, LoopOptions, Split, TrainState};
