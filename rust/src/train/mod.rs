//! Training orchestration: the AOT train-step driver, data streaming,
//! curve recording, checkpoints and weight transplant (for the Fig. 3
//! backward-compatibility experiment) — plus the fully native SLiM
//! chunked trainer (`slim`), which runs forward and backward in
//! fixed-size chunks over the streaming prefix-sum states for
//! sub-linear-in-length activation memory.

pub mod curve;
pub mod native_model;
pub mod driver;
pub mod slim;

pub use curve::{Curve, Point};
pub use native_model::{ChunkTape, NativeAttention, NativeModel, ParamGrads, SyntheticConfig};
pub use driver::{run_training, DataGen, LoopOptions, Split, TrainState, TrainStep};
pub use slim::{
    chunked_loss, chunked_loss_and_grad, plan_segments, ChunkedOutcome, ChunkedTrainConfig,
    MemStats, NativeTrainer, RecomputePolicy,
};
