//! Streaming long-context inference: stateful, chunked FAVOR sessions.
//!
//! The unidirectional FAVOR recurrence (PAPER.md Sec. 2.5.1/2.6) carries
//! only an M×(d+1) prefix-sum per head, so a sequence can be consumed
//! chunk by chunk in memory independent of its total length. This
//! subsystem turns that observation into a serving capability:
//!
//! * [`state`] — [`StreamState`], the incremental prefix-sum core (the
//!   single source of truth for causal FAVOR; `favor::linear`'s
//!   single-shot path wraps it), plus [`FavorStream`] for raw q/k/v
//!   streams;
//! * [`scorer`] — [`ChunkScorer`], the full Performer stack run
//!   layer-by-layer over chunks, yielding per-token MLM scores for
//!   genome-scale inputs;
//! * [`session`] — [`SessionManager`], many concurrent keyed streams
//!   under a global memory budget with LRU eviction — backed by the
//!   asynchronous write-back spill tier (`persist::SpillTier`), full and
//!   delta checkpoint exports, and redraw-churn accounting.
//!
//! The serving-side request path lives in `coordinator::streamer`; the
//! `performer stream` CLI, `xp stream` report and the
//! `benches/stream_scaling.rs` sweep drive it end to end.

pub mod scorer;
pub mod session;
pub mod state;
pub mod sweep;

pub use scorer::{ChunkScorer, ChunkScores};
pub use session::{DeltaStats, SessionConfig, SessionManager, SessionStats};
pub use state::{advance_vjp, AdvanceGrads, FavorStream, StatePrecision, StreamState};
pub use sweep::{
    chunked_latency_point, fused_throughput_point, sweep_totals, FusedPoint, SweepPoint,
};
