//! Chunked native-model scorer: drives the `train::NativeModel`
//! Performer stack chunk by chunk through its streaming forward,
//! producing causal per-token scores (log-likelihoods + greedy
//! predictions) for sequences far longer than any compiled artifact
//! length. Resident state is the per-layer per-head FAVOR prefix sums —
//! constant in the streamed length.
//!
//! Redraw awareness: a kernel with a live redraw schedule changes its
//! feature draw at epoch boundaries (`favor::kernel`). The model
//! forward splits chunks at those boundaries internally and resets the
//! per-head sums (the context restarts there), while this scorer's
//! carried `prev_row` survives the crossing — so per-token scores stay
//! causal and chunk-boundary-invariant across redraws, and snapshots
//! capture each state's epoch alongside its sums.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::protein::vocab::{AA_BASE, N_AA};
use crate::stream::{StatePrecision, StreamState};
use crate::tensor::Mat;
use crate::train::NativeModel;

/// Per-token scores for one consumed chunk. Scoring is properly causal:
/// position p is scored from the logits of position p−1 (carried across
/// chunk boundaries), i.e. log P(token_p | tokens_<p) — same-position
/// logits would let the model see the token it is scoring. The stream's
/// very first token has no context and is scored against the uniform
/// prior over the vocabulary.
#[derive(Clone, Debug)]
pub struct ChunkScores {
    /// global stream position of the chunk's first token
    pub offset: usize,
    /// log P(observed token | causal context before it), per position
    pub logprob: Vec<f32>,
    /// greedy amino-acid prediction for each position (from the context
    /// before it)
    pub argmax: Vec<u8>,
    /// probability of that prediction
    pub argmax_prob: Vec<f32>,
}

impl ChunkScores {
    /// Number of scored positions (the chunk length).
    pub fn len(&self) -> usize {
        self.logprob.len()
    }

    /// Whether the chunk scored no positions.
    pub fn is_empty(&self) -> bool {
        self.logprob.is_empty()
    }

    /// Mean negative log-likelihood over the chunk (perplexity = exp).
    pub fn mean_nll(&self) -> f64 {
        if self.logprob.is_empty() {
            return 0.0;
        }
        -self.logprob.iter().map(|&v| v as f64).sum::<f64>() / self.logprob.len() as f64
    }
}

/// A stateful scorer over one token stream: owns the model handle and
/// the carried attention states, tracks the global position.
pub struct ChunkScorer {
    model: Arc<NativeModel>,
    states: Vec<Vec<StreamState>>,
    /// logits of the previous chunk's last position — the causal context
    /// for the next chunk's first token
    prev_row: Option<Vec<f32>>,
    pos: usize,
}

impl ChunkScorer {
    /// Start an f32 stream over the given model. Errors unless the model
    /// is streamable (unidirectional + FAVOR).
    pub fn new(model: Arc<NativeModel>) -> Result<ChunkScorer> {
        ChunkScorer::new_with_precision(model, StatePrecision::F32)
    }

    /// Start a stream whose carried prefix sums use the given storage
    /// precision ([`StatePrecision::Bf16`] halves the resident state).
    pub fn new_with_precision(
        model: Arc<NativeModel>,
        precision: StatePrecision,
    ) -> Result<ChunkScorer> {
        let mut states = model.make_stream_states()?;
        if precision != StatePrecision::F32 {
            for layer in &mut states {
                for st in layer.iter_mut() {
                    *st = StreamState::with_precision(st.m(), st.d(), precision);
                }
            }
        }
        Ok(ChunkScorer { model, states, prev_row: None, pos: 0 })
    }

    /// Storage precision of the carried states (they are uniform — mixed
    /// precisions are rejected at construction).
    pub fn precision(&self) -> StatePrecision {
        self.states
            .first()
            .and_then(|layer| layer.first())
            .map(StreamState::precision)
            .unwrap_or_default()
    }

    /// The shared model this stream scores against.
    pub fn model(&self) -> &Arc<NativeModel> {
        &self.model
    }

    /// Sum of the carried states' redraw epochs. The serving layer
    /// samples this before and after an advance: the difference is the
    /// number of state resets the chunk caused (each state's epoch
    /// increments once per boundary it crossed), and any increase marks
    /// an epoch crossing for the session — the redraw-churn signal
    /// `coordinator::PersistMetrics` surfaces.
    pub fn epoch_sum(&self) -> u64 {
        self.states
            .iter()
            .flat_map(|layer| layer.iter())
            .map(StreamState::epoch)
            .sum()
    }

    /// The carried per-layer per-head attention states — read-only view
    /// for snapshot serialization (`persist/snapshot.rs`).
    pub fn states(&self) -> &[Vec<StreamState>] {
        &self.states
    }

    /// The carried cross-chunk context row (previous chunk's last logits;
    /// `None` before the first chunk) — read-only view for snapshots.
    pub fn prev_row(&self) -> Option<&[f32]> {
        self.prev_row.as_deref()
    }

    /// Rebuild a scorer from snapshot parts. Validates every shape
    /// against the model (layer/head counts, feature count M, head dim,
    /// context-row length) so a snapshot can never be rehydrated into a
    /// model it was not captured from; the restored scorer continues the
    /// stream bit-for-bit where the captured one stopped.
    pub fn from_parts(
        model: Arc<NativeModel>,
        states: Vec<Vec<StreamState>>,
        prev_row: Option<Vec<f32>>,
        pos: usize,
    ) -> Result<ChunkScorer> {
        // make_stream_states re-checks streamability and gives the
        // reference geometry to validate the snapshot against
        let reference = model.make_stream_states()?;
        if states.len() != reference.len() {
            bail!("snapshot has {} layers, model has {}", states.len(), reference.len());
        }
        for (li, (got, want)) in states.iter().zip(&reference).enumerate() {
            if got.len() != want.len() {
                bail!("snapshot layer {li} has {} heads, model has {}", got.len(), want.len());
            }
            for (hi, (g, w)) in got.iter().zip(want).enumerate() {
                if g.m() != w.m() || g.d() != w.d() {
                    bail!(
                        "snapshot state ({li},{hi}) is {}x({}+1), model needs {}x({}+1)",
                        g.m(),
                        g.d(),
                        w.m(),
                        w.d()
                    );
                }
            }
        }
        // the states must share one storage precision: a stream is
        // either f32 or bf16, never a mixture
        let precisions: Vec<StatePrecision> = states
            .iter()
            .flat_map(|layer| layer.iter())
            .map(StreamState::precision)
            .collect();
        if let Some(&first) = precisions.first() {
            if let Some(odd) = precisions.iter().find(|&&p| p != first) {
                bail!(
                    "snapshot mixes state precisions ({} and {})",
                    first.name(),
                    odd.name()
                );
            }
        }
        if let Some(row) = &prev_row {
            if row.len() != model.vocab_size {
                bail!(
                    "snapshot context row has {} logits, model vocab is {}",
                    row.len(),
                    model.vocab_size
                );
            }
        }
        if prev_row.is_none() && pos > 0 {
            bail!("snapshot at position {pos} is missing its carried context row");
        }
        Ok(ChunkScorer { model, states, prev_row, pos })
    }

    /// Tokens consumed so far.
    pub fn tokens_seen(&self) -> usize {
        self.pos
    }

    /// Resident bytes of the carried attention state — constant in the
    /// streamed length (layers × heads × M × (d_h + 1) entries, 4 bytes
    /// each under f32, 2 under bf16).
    pub fn state_bytes(&self) -> usize {
        self.states
            .iter()
            .flat_map(|layer| layer.iter())
            .map(StreamState::state_bytes)
            .sum()
    }

    /// Total resident bytes this stream actually carries: the attention
    /// prefix sums plus the cross-chunk context row (`prev_row`, one
    /// vocab-sized logit vector once the first chunk has been consumed).
    pub fn resident_bytes(&self) -> usize {
        self.state_bytes()
            + self.prev_row.as_ref().map_or(0, |r| r.len() * std::mem::size_of::<f32>())
    }

    /// Steady-state resident bytes (as [`Self::resident_bytes`] reports
    /// after the first chunk) — what a budget should charge per session,
    /// since every live session reaches it immediately.
    pub fn steady_state_bytes(&self) -> usize {
        self.state_bytes() + self.model.vocab_size * std::mem::size_of::<f32>()
    }

    /// Restart the stream without reallocating.
    pub fn reset(&mut self) {
        for layer in &mut self.states {
            for st in layer {
                st.reset();
            }
        }
        self.prev_row = None;
        self.pos = 0;
    }

    /// Consume the next chunk of the stream and score every position
    /// causally (position p from the logits at p−1, carried across
    /// chunk boundaries). Thin wrapper over [`Self::advance_batch`].
    pub fn advance(&mut self, tokens: &[u8]) -> Result<ChunkScores> {
        Self::advance_batch(std::slice::from_mut(self), &[tokens])?
            .pop()
            .ok_or_else(|| anyhow::anyhow!("B=1 advance produced no scores"))
    }

    /// Advance B independent streams in one fused forward: every scorer
    /// must share the same model handle; chunk `i` feeds scorer `i`.
    /// The dense per-token work of the whole batch runs as single fused
    /// matrix operations ([`NativeModel::forward_chunk_batch`]), while
    /// each stream's carried state, position and scoring context advance
    /// exactly as B sequential [`Self::advance`] calls would.
    pub fn advance_batch(
        scorers: &mut [ChunkScorer],
        chunks: &[&[u8]],
    ) -> Result<Vec<ChunkScores>> {
        if scorers.len() != chunks.len() {
            bail!("{} scorers fed {} chunks", scorers.len(), chunks.len());
        }
        if scorers.is_empty() {
            return Ok(Vec::new());
        }
        let model = scorers[0].model.clone();
        for s in scorers.iter().skip(1) {
            if !Arc::ptr_eq(&model, &s.model) {
                bail!("fused scorers must share one model");
            }
        }
        for tokens in chunks {
            if tokens.is_empty() {
                bail!("empty chunk");
            }
            if let Some(&t) = tokens.iter().find(|&&t| t as usize >= model.vocab_size) {
                bail!("token {t} outside vocab (size {})", model.vocab_size);
            }
        }
        let offsets: Vec<usize> = scorers.iter().map(|s| s.pos).collect();
        let logits = {
            let mut state_refs: Vec<&mut [Vec<StreamState>]> =
                scorers.iter_mut().map(|s| s.states.as_mut_slice()).collect();
            model.forward_chunk_batch(chunks, &offsets, &mut state_refs)?
        };
        Ok(scorers
            .iter_mut()
            .zip(chunks.iter().zip(logits))
            .map(|(scorer, (tokens, logits))| scorer.score_chunk(tokens, logits))
            .collect())
    }

    /// Score one consumed chunk from its logits, updating the stream
    /// position and the carried cross-chunk context row.
    fn score_chunk(&mut self, tokens: &[u8], logits: Mat) -> ChunkScores {
        let offset = self.pos;
        self.pos += tokens.len();

        let vocab = logits.cols;
        let aa_lo = AA_BASE as usize;
        let aa_hi = (aa_lo + N_AA).min(vocab);
        let uniform = -(vocab as f32).ln();
        let mut logprob = Vec::with_capacity(tokens.len());
        let mut argmax = Vec::with_capacity(tokens.len());
        let mut argmax_prob = Vec::with_capacity(tokens.len());
        for (i, &tok) in tokens.iter().enumerate() {
            // context row: previous position's logits (cross-chunk for i=0)
            let ctx: Option<&[f32]> = if i == 0 {
                self.prev_row.as_deref()
            } else {
                Some(logits.row(i - 1))
            };
            let Some(row) = ctx else {
                // the stream's first token: no context, uniform prior
                logprob.push(uniform);
                argmax.push(AA_BASE);
                argmax_prob.push(1.0 / vocab as f32);
                continue;
            };
            // stable log-softmax
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
            logprob.push(row[tok as usize] - lse);
            let (best, best_logit) = row[aa_lo..aa_hi]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, &v)| (aa_lo + j, v))
                .unwrap();
            argmax.push(best as u8);
            argmax_prob.push((best_logit - lse).exp());
        }
        self.prev_row = Some(logits.row(tokens.len() - 1).to_vec());
        ChunkScores { offset, logprob, argmax, argmax_prob }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::train::{NativeModel, SyntheticConfig};

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(7);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    #[test]
    fn chunked_matches_single_shot_forward() {
        let m = model();
        let toks = tokens(96, 1);
        let (full_logits, _) = m.forward(&toks, false);

        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        let mut states = m.make_stream_states().unwrap();
        let mut streamed = Vec::new();
        let mut pos = 0;
        for chunk in toks.chunks(25) {
            let logits = m.forward_chunk(chunk, pos, &mut states).unwrap();
            streamed.extend(logits.data);
            pos += chunk.len();
            scorer.advance(chunk).unwrap();
        }
        let max_diff = full_logits
            .data
            .iter()
            .zip(&streamed)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "chunked logits diverge by {max_diff}");
        assert_eq!(scorer.tokens_seen(), toks.len());
    }

    #[test]
    fn chunked_scoring_matches_single_shot_scoring() {
        // the carried prev_row must make scores independent of chunking
        let m = model();
        let toks = tokens(60, 9);
        let mut one = ChunkScorer::new(m.clone()).unwrap();
        let whole = one.advance(&toks).unwrap();

        let mut many = ChunkScorer::new(m).unwrap();
        let mut got = Vec::new();
        for chunk in toks.chunks(20) {
            got.extend(many.advance(chunk).unwrap().logprob);
        }
        assert_eq!(whole.logprob.len(), got.len());
        let max_diff = whole
            .logprob
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "scores depend on chunk boundaries (diff {max_diff})");
    }

    #[test]
    fn scores_are_finite_probabilities() {
        let mut scorer = ChunkScorer::new(model()).unwrap();
        let s = scorer.advance(&tokens(40, 2)).unwrap();
        assert_eq!(s.len(), 40);
        assert!(s.logprob.iter().all(|v| v.is_finite() && *v <= 0.0));
        assert!(s.argmax_prob.iter().all(|&p| p > 0.0 && p <= 1.0));
        assert!(s.argmax.iter().all(|&t| t >= AA_BASE && (t as usize) < AA_BASE as usize + N_AA));
        assert!(s.mean_nll() > 0.0);
    }

    #[test]
    fn state_bytes_constant_as_stream_grows() {
        let mut scorer = ChunkScorer::new(model()).unwrap();
        let b0 = scorer.state_bytes();
        assert!(b0 > 0);
        for seed in 0..8 {
            scorer.advance(&tokens(64, 100 + seed)).unwrap();
            assert_eq!(scorer.state_bytes(), b0);
        }
        assert_eq!(scorer.tokens_seen(), 8 * 64);
    }

    #[test]
    fn bf16_scorer_halves_state_and_tracks_f32_scores() {
        let m = model();
        let toks = tokens(80, 21);
        let mut exact = ChunkScorer::new(m.clone()).unwrap();
        let mut quant = ChunkScorer::new_with_precision(m, StatePrecision::Bf16).unwrap();
        assert_eq!(exact.precision(), StatePrecision::F32);
        assert_eq!(quant.precision(), StatePrecision::Bf16);
        assert_eq!(quant.state_bytes() * 2, exact.state_bytes());

        let mut worst = 0.0f32;
        for chunk in toks.chunks(17) {
            let se = exact.advance(chunk).unwrap();
            let sq = quant.advance(chunk).unwrap();
            for (a, b) in se.logprob.iter().zip(&sq.logprob) {
                worst = worst.max((a - b).abs());
            }
        }
        // documented envelope: per-token logprobs within 0.5 nats,
        // typically far closer (see tests/prop_quant.rs for the
        // cross-chunking/redraw/spill sweep)
        assert!(worst < 0.5, "bf16 logprobs drifted {worst} nats from f32");
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut scorer = ChunkScorer::new(model()).unwrap();
        assert!(scorer.advance(&[]).is_err());
        assert!(scorer.advance(&[200]).is_err());
    }
}
