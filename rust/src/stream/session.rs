//! Session management for concurrent long-context streams: many users
//! hold open streams against one model; each session carries only the
//! constant-size FAVOR prefix-sum state, and a global memory budget with
//! LRU eviction keeps residency bounded no matter how many streams are
//! opened and abandoned.
//!
//! With a spill directory configured, eviction becomes *asynchronous
//! demotion*: the LRU session's state is captured and enqueued to a
//! background writer thread (`persist::SpillTier`) instead of being
//! written — or destroyed — on the serving thread. Until the write
//! commits, the demoted session stays resident-readable (write-back),
//! so `advance`/`advance_batch` never block on a spill write, and its
//! next chunk transparently rehydrates it — from RAM if the write is
//! still in flight, from disk after it commits — with scores bitwise
//! identical to a never-evicted stream. The same machinery backs
//! [`SessionManager::checkpoint_all`] / [`SessionManager::restore_from`]
//! (warm-replica migration) and [`SessionManager::checkpoint_delta`]
//! (incremental hot exports that re-snapshot only dirty sessions).

use std::collections::{BTreeSet, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::trace;
use crate::persist::{Checkpointer, SpillTier};
use crate::train::NativeModel;

use super::scorer::{ChunkScorer, ChunkScores};
use super::state::StatePrecision;

/// Budget knobs for a [`SessionManager`].
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// total bytes of carried attention state across all sessions; when
    /// exceeded, least-recently-used sessions are evicted (the active
    /// one is always preserved)
    pub max_state_bytes: usize,
    /// hard cap on simultaneously resident sessions (0 = no cap)
    pub max_sessions: usize,
    /// when set, budget eviction demotes cold sessions to snapshots in
    /// this directory instead of destroying their context; their next
    /// chunk rehydrates them transparently. Writes run on a background
    /// thread — eviction enqueues instead of blocking the serving path
    pub spill_dir: Option<PathBuf>,
    /// high-water mark, in bytes, on encoded snapshots parked awaiting
    /// their background spill write (0 = unbounded). When an eviction
    /// would push the staging footprint past this, the spill is *shed*:
    /// the tier refuses the enqueue (counting it in `spill_sheds`) and
    /// the eviction degrades to the loud context-destroying kind — the
    /// bounded-memory contract a slow disk must not be able to break
    pub spill_pending_limit: usize,
    /// storage precision of every session's carried prefix sums;
    /// [`StatePrecision::Bf16`] halves per-session residency (so ~2×
    /// the sessions fit one byte budget) at a documented per-token
    /// score tolerance. Snapshots embed the mode: a manager refuses to
    /// adopt sessions captured under the other precision
    pub precision: StatePrecision,
}

impl Default for SessionConfig {
    fn default() -> Self {
        // 64 MiB of stream state, no session-count cap, no spill tier,
        // unbounded write-back staging, full-precision f32 state
        SessionConfig {
            max_state_bytes: 64 << 20,
            max_sessions: 0,
            spill_dir: None,
            spill_pending_limit: 0,
            precision: StatePrecision::F32,
        }
    }
}

/// Chunks are fused into one wave only when the longest is at most this
/// multiple of the shortest — past that, the padding rows the fused
/// `Batch` carries for the short chunks outweigh the fusion win.
const COMPAT_LEN_RATIO: usize = 2;

/// Aggregate counters, cheap to copy out for metrics/logging.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    /// sessions currently resident in RAM
    pub active: usize,
    /// total resident carried-state bytes
    pub resident_bytes: usize,
    /// steady-state resident bytes one session costs under the
    /// configured [`SessionConfig::precision`] (bf16 halves the
    /// attention-state share) — the budget's per-session charge
    pub per_session_bytes: usize,
    /// sessions opened since startup
    pub opened: u64,
    /// sessions explicitly closed
    pub closed: u64,
    /// sessions whose context was destroyed under memory pressure
    pub evicted: u64,
    /// chunks served
    pub chunks: u64,
    /// tokens consumed
    pub tokens: u64,
    /// sessions currently demoted to the spill tier (pending + on disk)
    pub spilled: usize,
    /// cumulative demote-to-spill events (enqueues)
    pub spills: u64,
    /// cumulative spill-to-RAM promotions (from the pending map or disk)
    pub rehydrations: u64,
    /// cumulative snapshot bytes written (spills + checkpoint exports)
    pub checkpoint_bytes: u64,
    /// cumulative wall time spent rehydrating, nanoseconds
    pub rehydrate_nanos: u64,
    /// spills parked awaiting their background write (gauge)
    pub pending_spills: usize,
    /// bytes of encoded snapshots parked awaiting their background
    /// write (gauge) — bounded by `SessionConfig::spill_pending_limit`
    pub spill_pending_bytes: u64,
    /// spills refused at the pending-byte high-water mark, each
    /// degraded to a loud eviction
    pub spill_sheds: u64,
    /// background spill writes committed to the spill manifest
    pub spill_commits: u64,
    /// queued spill writes canceled (taken back by a rehydration or a
    /// close before the write committed)
    pub spill_cancels: u64,
    /// background spill writes that failed — each is converted to a
    /// loud eviction at the manager's next batch, so the byte budget
    /// stays enforceable behind a failing disk
    pub spill_write_failures: u64,
    /// serving-thread nanoseconds spent *enqueueing* spills — the cost
    /// eviction now pays instead of a full fsynced write
    pub spill_enqueue_nanos: u64,
    /// writer-thread nanoseconds spent writing + committing spills
    pub spill_write_nanos: u64,
    /// advances that crossed ≥1 kernel-redraw epoch boundary (the
    /// session's attention context restarted there)
    pub epoch_crossings: u64,
    /// per-(layer, head) state resets caused by redraw crossings (one
    /// per state per boundary crossed)
    pub state_resets: u64,
    /// snapshot records written by delta exports
    pub delta_written: u64,
    /// clean records retained (not re-snapshotted) by delta exports
    pub delta_retained: u64,
}

/// What one [`SessionManager::checkpoint_delta`] export did.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    /// sessions re-snapshotted (dirty since the previous export)
    pub written: usize,
    /// clean records carried forward without any snapshot IO
    pub retained: usize,
    /// stale records dropped (sessions closed since the previous export)
    pub removed: usize,
    /// manifest generation the export committed
    pub generation: u64,
}

struct Session {
    scorer: ChunkScorer,
    last_used: u64,
    /// monotone per-manager generation stamped at the session's last
    /// state change — the delta-export dirty marker
    dirty_gen: u64,
}

/// Process-unique identity token for a manager's exports: a record in a
/// checkpoint directory is provably clean only if it carries this
/// manager's token *and* the session's current dirty generation.
fn exporter_token() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut h = crate::rng::fnv1a64(&nanos.to_le_bytes());
    h = crate::rng::fnv1a64_extend(h, &u64::from(std::process::id()).to_le_bytes());
    h = crate::rng::fnv1a64_extend(h, &COUNTER.fetch_add(1, Ordering::Relaxed).to_le_bytes());
    h.max(1) // 0 is reserved for "unknown/foreign"
}

/// Keyed store of open streams over one model, with budgeted residency.
pub struct SessionManager {
    model: Arc<NativeModel>,
    cfg: SessionConfig,
    sessions: HashMap<String, Session>,
    /// asynchronous spill tier: demoted-but-live sessions, parked in RAM
    /// until their background write commits, then on disk (None when no
    /// spill directory is configured — eviction then destroys context)
    spill: Option<SpillTier>,
    /// ids dropped under memory pressure: a later chunk for one of these
    /// must fail loudly (the causal context is gone) rather than
    /// silently reopen at offset 0 with context-free scores
    evicted_ids: HashSet<String>,
    /// logical clock for LRU ordering
    clock: u64,
    /// monotone counter behind each session's `dirty_gen`
    dirty_clock: u64,
    /// this manager's identity token in export dirty markers
    exporter: u64,
    /// bytes of carried state per session (uniform: one model)
    per_session_bytes: usize,
    opened: u64,
    closed: u64,
    evicted: u64,
    chunks: u64,
    tokens: u64,
    spills: u64,
    rehydrations: u64,
    checkpoint_bytes: u64,
    rehydrate_nanos: u64,
    epoch_crossings: u64,
    state_resets: u64,
    delta_written: u64,
    delta_retained: u64,
}

impl SessionManager {
    /// Build over a streamable model. Errors if the model cannot stream
    /// (bidirectional or non-FAVOR attention) or if the configured spill
    /// directory cannot be opened.
    pub fn new(model: Arc<NativeModel>, cfg: SessionConfig) -> Result<SessionManager> {
        // probe streamability once up front so `advance` can't half-open;
        // budget the *steady-state* residency (prefix sums + the carried
        // vocab-sized context row), which every live session reaches
        // after its first chunk — charging only the attention state
        // undercounted by vocab×4 bytes per session. The probe uses the
        // configured precision, so bf16 halves the per-session charge
        // and the same byte budget admits ~2× the sessions
        let probe = ChunkScorer::new_with_precision(model.clone(), cfg.precision)?;
        let per_session_bytes = probe.steady_state_bytes();
        let spill = match &cfg.spill_dir {
            Some(dir) => {
                let tier = SpillTier::create(dir)?;
                tier.set_pending_limit(cfg.spill_pending_limit);
                Some(tier)
            }
            None => None,
        };
        Ok(SessionManager {
            model,
            cfg,
            sessions: HashMap::new(),
            spill,
            evicted_ids: HashSet::new(),
            clock: 0,
            dirty_clock: 0,
            exporter: exporter_token(),
            per_session_bytes,
            opened: 0,
            closed: 0,
            evicted: 0,
            chunks: 0,
            tokens: 0,
            spills: 0,
            rehydrations: 0,
            checkpoint_bytes: 0,
            rehydrate_nanos: 0,
            epoch_crossings: 0,
            state_resets: 0,
            delta_written: 0,
            delta_retained: 0,
        })
    }

    /// Carried-state bytes for one session (constant for a given model).
    pub fn per_session_bytes(&self) -> usize {
        self.per_session_bytes
    }

    /// Number of resident sessions.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// Whether no sessions are resident.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Whether a session is resident in RAM.
    pub fn contains(&self, id: &str) -> bool {
        self.sessions.contains_key(id)
    }

    /// Total resident carried-state bytes.
    pub fn resident_bytes(&self) -> usize {
        self.sessions.len() * self.per_session_bytes
    }

    /// Whether a session is currently demoted to the spill tier — its
    /// write still in flight (resident-readable) or committed on disk.
    /// Either way its next chunk will rehydrate it.
    pub fn is_spilled(&self, id: &str) -> bool {
        self.spill.as_ref().is_some_and(|tier| tier.contains(id))
    }

    /// Aggregate counters for metrics/logging.
    pub fn stats(&self) -> SessionStats {
        let spill = self.spill.as_ref().map(SpillTier::counters).unwrap_or_default();
        let spilled = self
            .spill
            .as_ref()
            .map_or(0, |t| t.pending_count() + t.committed_count());
        SessionStats {
            active: self.sessions.len(),
            resident_bytes: self.resident_bytes(),
            per_session_bytes: self.per_session_bytes,
            opened: self.opened,
            closed: self.closed,
            evicted: self.evicted,
            chunks: self.chunks,
            tokens: self.tokens,
            spilled,
            spills: self.spills,
            rehydrations: self.rehydrations,
            checkpoint_bytes: self.checkpoint_bytes,
            rehydrate_nanos: self.rehydrate_nanos,
            pending_spills: spill.pending as usize,
            spill_pending_bytes: spill.pending_bytes,
            spill_sheds: spill.sheds,
            spill_commits: spill.commits,
            spill_cancels: spill.cancels,
            spill_write_failures: spill.write_failures,
            spill_enqueue_nanos: spill.enqueue_nanos,
            spill_write_nanos: spill.write_nanos,
            epoch_crossings: self.epoch_crossings,
            state_resets: self.state_resets,
            delta_written: self.delta_written,
            delta_retained: self.delta_retained,
        }
    }

    /// Tokens consumed so far by a resident session.
    pub fn tokens_seen(&self, id: &str) -> Option<usize> {
        self.sessions.get(id).map(|s| s.scorer.tokens_seen())
    }

    /// Block until every spill enqueued so far has committed (or been
    /// canceled) — the test/shutdown barrier. A manager without a spill
    /// tier returns immediately. Dropping the manager drains implicitly.
    pub fn sync_spills(&self) -> Result<()> {
        match &self.spill {
            Some(tier) => tier.flush(),
            None => Ok(()),
        }
    }

    /// Test/ops hook: hold (or release) the background spill writer, so
    /// in-flight spills stay observably pending. Used by tests that pin
    /// the write-back protocol; a no-op without a spill tier.
    pub fn set_spill_hold(&self, on: bool) {
        if let Some(tier) = &self.spill {
            tier.hold_writes(on);
        }
    }

    /// Feed the next chunk of stream `id` (opening it on first use) and
    /// return the chunk's scores. May evict other idle sessions to stay
    /// within budget; the session being advanced is never evicted. A
    /// session that *was* evicted fails loudly here — its causal context
    /// is gone, so silently restarting it would return wrong scores;
    /// `close` it (acknowledging the loss) to reuse the id.
    /// Thin wrapper over [`Self::advance_batch`] with B = 1.
    pub fn advance(&mut self, id: &str, chunk: &[u8]) -> Result<ChunkScores> {
        self.advance_batch(&[id], &[chunk]).pop().expect("B=1 advance")
    }

    /// Feed the next chunk of several streams in one fused forward
    /// ([`ChunkScorer::advance_batch`] →
    /// [`crate::train::NativeModel::forward_chunk_batch`]): the dense
    /// per-token work of the whole batch runs as single matrix
    /// operations while each session's carried state advances exactly as
    /// B sequential [`Self::advance`] calls would. Results line up with
    /// `ids`; each request succeeds or fails independently (bad chunk,
    /// evicted id). The batch is served as one or more fused *waves*: a
    /// wave holds each session at most once (a repeated id advances in
    /// submission order across successive waves, so callers may drain a
    /// queue without deduplicating) and only chunks within
    /// [`COMPAT_LEN_RATIO`]× of each other in length (beyond that, the
    /// padding rows the fused `Batch` would carry outweigh the fusion
    /// win). None of the batch's sessions is evicted while serving any
    /// part of it, and evictions triggered here only *enqueue* spill
    /// writes — the serving path never waits on the disk.
    pub fn advance_batch(&mut self, ids: &[&str], chunks: &[&[u8]]) -> Vec<Result<ChunkScores>> {
        assert_eq!(ids.len(), chunks.len(), "{} ids fed {} chunks", ids.len(), chunks.len());
        let _span = trace::span_n("advance_batch", ids.len() as u64);
        self.reap_failed_spills();
        let mut results: Vec<Option<Result<ChunkScores>>> =
            (0..ids.len()).map(|_| None).collect();

        // per-request validation and open-on-first-use, before fusing
        let mut admitted: Vec<usize> = Vec::new();
        for (i, (&id, &chunk)) in ids.iter().zip(chunks).enumerate() {
            if chunk.is_empty() {
                results[i] = Some(Err(anyhow!("empty chunk")));
                continue;
            }
            if let Some(&t) = chunk.iter().find(|&&t| t as usize >= self.model.vocab_size) {
                results[i] = Some(Err(anyhow!(
                    "token {t} outside vocab (size {})",
                    self.model.vocab_size
                )));
                continue;
            }
            if !self.sessions.contains_key(id) {
                if self.is_spilled(id) {
                    // demoted under byte pressure: promote it back before
                    // scoring — the caller never learns it was gone
                    if let Err(e) = self.rehydrate(id) {
                        results[i] = Some(Err(e));
                        continue;
                    }
                } else if self.evicted_ids.contains(id) {
                    results[i] = Some(Err(anyhow!(
                        "session '{id}' was evicted under memory pressure; \
                         close it and start a new session"
                    )));
                    continue;
                } else {
                    match ChunkScorer::new_with_precision(self.model.clone(), self.cfg.precision)
                    {
                        Ok(scorer) => {
                            self.sessions.insert(
                                id.to_string(),
                                Session { scorer, last_used: self.clock, dirty_gen: 0 },
                            );
                            self.opened += 1;
                        }
                        Err(e) => {
                            results[i] = Some(Err(e));
                            continue;
                        }
                    }
                }
            }
            admitted.push(i);
        }
        let keep: HashSet<&str> = admitted.iter().map(|&i| ids[i]).collect();
        self.enforce_budget(&keep);

        // fused waves: a wave holds each session at most once (so a
        // duplicated id advances sequentially in submission order) and
        // only length-compatible chunks. An id deferred for length is
        // blocked for the rest of the wave — a later chunk of the same
        // session must not jump ahead of it.
        let mut remaining = admitted;
        while !remaining.is_empty() {
            let mut wave: Vec<usize> = Vec::new();
            let mut in_wave: HashSet<&str> = HashSet::new();
            let mut blocked: HashSet<&str> = HashSet::new();
            let mut next: Vec<usize> = Vec::new();
            let (mut wlo, mut whi) = (0usize, 0usize); // wave's length window
            for i in remaining {
                let id = ids[i];
                if in_wave.contains(id) || blocked.contains(id) {
                    next.push(i);
                    continue;
                }
                let len = chunks[i].len();
                let (nlo, nhi) = if wave.is_empty() {
                    (len, len)
                } else {
                    (wlo.min(len), whi.max(len))
                };
                if nhi > COMPAT_LEN_RATIO * nlo {
                    blocked.insert(id);
                    next.push(i);
                    continue;
                }
                (wlo, whi) = (nlo, nhi);
                in_wave.insert(id);
                wave.push(i);
            }
            // pull the wave's scorers out of the map so they advance as
            // one contiguous mutable slice, then reinsert (each with its
            // own clock tick, in submission order, so LRU ordering stays
            // a deterministic total order exactly as sequential advances
            // would produce)
            let mut old_dirty: Vec<u64> = Vec::with_capacity(wave.len());
            let mut scorers: Vec<ChunkScorer> = wave
                .iter()
                .map(|&i| {
                    let sess =
                        self.sessions.remove(ids[i]).expect("admitted session resident");
                    old_dirty.push(sess.dirty_gen);
                    sess.scorer
                })
                .collect();
            // redraw accounting: epoch sums before/after the advance
            let epochs_before: Vec<u64> = scorers.iter().map(ChunkScorer::epoch_sum).collect();
            let wave_chunks: Vec<&[u8]> = wave.iter().map(|&i| chunks[i]).collect();
            let _wave_span = trace::span_n("wave", wave.len() as u64);
            match ChunkScorer::advance_batch(&mut scorers, &wave_chunks) {
                Ok(scores) => {
                    for (j, ((&i, scorer), sc)) in
                        wave.iter().zip(scorers).zip(scores).enumerate()
                    {
                        let resets = scorer.epoch_sum().saturating_sub(epochs_before[j]);
                        if resets > 0 {
                            self.epoch_crossings += 1;
                            self.state_resets += resets;
                        }
                        self.chunks += 1;
                        self.tokens += chunks[i].len() as u64;
                        self.clock += 1;
                        self.dirty_clock += 1;
                        self.sessions.insert(
                            ids[i].to_string(),
                            Session {
                                scorer,
                                last_used: self.clock,
                                dirty_gen: self.dirty_clock,
                            },
                        );
                        results[i] = Some(Ok(sc));
                    }
                }
                Err(e) => {
                    // advance_batch validates before touching any state,
                    // so the scorers are unmodified: keep them resident
                    let msg = format!("{e:#}");
                    for ((&i, scorer), dirty_gen) in
                        wave.iter().zip(scorers).zip(old_dirty)
                    {
                        self.clock += 1;
                        self.sessions.insert(
                            ids[i].to_string(),
                            Session { scorer, last_used: self.clock, dirty_gen },
                        );
                        results[i] = Some(Err(anyhow!("{msg}")));
                    }
                }
            }
            remaining = next;
        }
        results.into_iter().map(|r| r.expect("every request answered")).collect()
    }

    /// Explicitly end a stream, releasing its state immediately —
    /// resident, spill-pending or spilled — (and acknowledging a prior
    /// eviction, freeing the id for reuse). Returns whether the session
    /// existed.
    pub fn close(&mut self, id: &str) -> bool {
        self.evicted_ids.remove(id);
        let mut existed = self.sessions.remove(id).is_some();
        if let Some(tier) = &self.spill {
            match tier.remove(id) {
                Ok(removed) => existed |= removed,
                Err(e) => eprintln!("[session] dropping spilled '{id}' failed: {e:#}"),
            }
        }
        if existed {
            self.closed += 1;
        }
        existed
    }

    /// Convert spills whose background write failed into loud evictions
    /// — the degradation a failed *synchronous* spill always had. Runs
    /// at the top of every batch, so parked scorers never accumulate
    /// unboundedly behind a failing disk; a session that was already
    /// rehydrated (write-back take-back) lost nothing and is skipped.
    fn reap_failed_spills(&mut self) {
        let Some(tier) = &self.spill else { return };
        for (id, seq) in tier.take_failed() {
            if tier.drop_failed_pending(&id, seq) {
                eprintln!("[session] spill write for '{id}' failed; dropping its context");
                self.evicted_ids.insert(id);
                self.evicted += 1;
            }
        }
    }

    /// Promote a demoted session back into residency. A spill whose
    /// background write is still in flight short-circuits to the parked
    /// resident copy (canceling the queued write — no disk touched at
    /// all); a committed spill is loaded and its snapshot consumed (the
    /// resident copy owns the stream from here on). Either way the
    /// session's dirty generation survives, so an untouched rehydrated
    /// session stays "clean" for delta exports.
    fn rehydrate(&mut self, id: &str) -> Result<()> {
        let _span = trace::span("rehydrate");
        let t0 = Instant::now();
        let tier = self.spill.as_ref().expect("rehydrate requires a spill tier");
        let (scorer, dirty_gen) = match tier.take_pending(id) {
            Some(hot) => hot,
            None => tier
                .load_committed(id, &self.model)
                .with_context(|| format!("rehydrating session '{id}'"))?,
        };
        if scorer.precision() != self.cfg.precision {
            bail!(
                "spilled session '{id}' was captured with {} state, manager runs {}",
                scorer.precision().name(),
                self.cfg.precision.name()
            );
        }
        self.clock += 1;
        self.sessions.insert(
            id.to_string(),
            Session { scorer, last_used: self.clock, dirty_gen },
        );
        self.rehydrations += 1;
        self.rehydrate_nanos += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// Refuse export targets that alias the live spill directory —
    /// clearing or rewriting it would destroy the spilled sessions' only
    /// durable copies.
    fn guard_export_target(&self, dir: &Path) -> Result<()> {
        if let Some(spill_dir) = self.cfg.spill_dir.as_deref() {
            // resolve aliases (relative paths, symlinks) before
            // comparing; a target that does not exist yet cannot alias
            // the (existing) spill dir, so the textual fallback only has
            // to cover equal spellings
            let same = match (std::fs::canonicalize(spill_dir), std::fs::canonicalize(dir)) {
                (Ok(a), Ok(b)) => a == b,
                _ => spill_dir == dir,
            };
            if same {
                bail!("checkpoint target must differ from the spill directory");
            }
        }
        Ok(())
    }

    /// Snapshot every live session — resident, spill-pending and
    /// spilled — into `dir` (which must not be the spill directory
    /// itself), leaving the manager untouched. The target is cleared
    /// first: the export describes exactly the sessions live *now*, so a
    /// reused directory can never resurrect ones that have since closed.
    /// Already-committed spill snapshots are hard-linked (or copied)
    /// into the export instead of being decoded and re-encoded. Returns
    /// the number of sessions written; this is the coordinator's
    /// migration export. For hot repeated exports, prefer
    /// [`Self::checkpoint_delta`].
    pub fn checkpoint_all(&mut self, dir: &Path) -> Result<usize> {
        let _span = trace::span("checkpoint_all");
        self.guard_export_target(dir)?;
        let mut ck = Checkpointer::create(dir).context("opening checkpoint directory")?;
        ck.clear().context("clearing previous export")?;
        let exporter = self.exporter;
        let mut written = 0usize;
        let mut ids: Vec<&String> = self.sessions.keys().collect();
        ids.sort();
        for id in ids {
            let sess = &self.sessions[id];
            let rec = ck.stage_marked(id, &sess.scorer, exporter, sess.dirty_gen)?;
            self.checkpoint_bytes += rec.bytes;
            written += 1;
        }
        if let Some(tier) = &self.spill {
            // in-flight spills are live sessions too: export their
            // parked resident copies
            let mut extra_bytes = 0u64;
            let mut pending_exported: BTreeSet<String> = BTreeSet::new();
            tier.for_each_pending(|id, bytes, pos, dirty_gen| {
                let rec = ck.stage_encoded(id, bytes, pos, exporter, dirty_gen)?;
                extra_bytes += rec.bytes;
                pending_exported.insert(id.to_string());
                Ok(())
            })?;
            written += pending_exported.len();
            // committed spills migrate by linking their verified bytes
            for id in tier.committed_ids() {
                if self.sessions.contains_key(&id) || pending_exported.contains(&id) {
                    continue;
                }
                let rec = tier
                    .committed_record(&id)
                    .ok_or_else(|| anyhow!("spill record for '{id}' vanished mid-export"))?;
                let staged =
                    ck.stage_linked(&tier.dir().join(&rec.file), &rec, exporter, rec.dirty_gen)?;
                extra_bytes += staged.bytes;
                written += 1;
            }
            self.checkpoint_bytes += extra_bytes;
        }
        // one manifest write publishes the whole export
        ck.commit_new_generation()?;
        Ok(written)
    }

    /// Evacuate the manager: snapshot every live session into `dir`
    /// ([`Self::checkpoint_all`]) and then close them all, leaving the
    /// manager empty. This is the migration hand-off — after a
    /// successful drain the sessions live *only* in the export, so the
    /// peer that adopts it (`restore_from`) becomes their sole owner
    /// and no stale copy can keep serving here. Returns the number of
    /// sessions exported.
    pub fn drain_to(&mut self, dir: &Path) -> Result<usize> {
        let _span = trace::span("drain_to");
        let written = self.checkpoint_all(dir)?;
        // the export is durable; release everything it captured
        // (resident, spill-pending and committed-spill sessions alike)
        let mut ids: BTreeSet<String> = self.sessions.keys().cloned().collect();
        if let Some(tier) = &self.spill {
            ids.extend(tier.pending_ids());
            ids.extend(tier.committed_ids());
        }
        for id in ids {
            self.close(&id);
        }
        Ok(written)
    }

    /// Incremental export: bring `dir` (a previous [`Self::checkpoint_all`]
    /// or `checkpoint_delta` target, or an empty directory) up to date
    /// with the sessions live now, re-snapshotting **only the dirty
    /// ones**. A record is provably clean — and retained with zero
    /// snapshot IO — when it carries this manager's exporter token and
    /// the session's current dirty generation; anything else (advanced
    /// sessions, foreign records, v1 manifests) is re-written. Records
    /// for sessions that have since closed are dropped. The new record
    /// set is published as one atomically-committed manifest generation;
    /// restoring from any chain of full + delta exports is bitwise
    /// identical to restoring from a single full export.
    pub fn checkpoint_delta(&mut self, dir: &Path) -> Result<DeltaStats> {
        let _span = trace::span("checkpoint_delta");
        self.guard_export_target(dir)?;
        let mut ck = Checkpointer::create(dir).context("opening checkpoint directory")?;
        let exporter = self.exporter;
        let mut stats = DeltaStats::default();

        // the live set: resident ∪ spill-pending ∪ spill-committed
        let mut live: BTreeSet<String> = self.sessions.keys().cloned().collect();
        if let Some(tier) = &self.spill {
            live.extend(tier.pending_ids());
            live.extend(tier.committed_ids());
        }
        // drop records of sessions that closed since the last export
        for id in ck.ids() {
            if !live.contains(&id) {
                ck.unstage(&id)?;
                stats.removed += 1;
            }
        }
        let clean = |ck: &Checkpointer, id: &str, dirty_gen: u64| -> bool {
            ck.record(id)
                .is_some_and(|r| r.exporter == exporter && r.dirty_gen == dirty_gen)
        };
        // resident sessions
        let mut ids: Vec<&String> = self.sessions.keys().collect();
        ids.sort();
        for id in ids {
            let sess = &self.sessions[id];
            if clean(&ck, id, sess.dirty_gen) {
                stats.retained += 1;
            } else {
                let rec = ck.stage_marked(id, &sess.scorer, exporter, sess.dirty_gen)?;
                self.checkpoint_bytes += rec.bytes;
                stats.written += 1;
            }
        }
        if let Some(tier) = &self.spill {
            // in-flight spills: retain if clean, else export the parked copy
            let mut extra_bytes = 0u64;
            let mut written = 0usize;
            let mut retained = 0usize;
            let mut pending_seen: BTreeSet<String> = BTreeSet::new();
            tier.for_each_pending(|id, bytes, pos, dirty_gen| {
                pending_seen.insert(id.to_string());
                if clean(&ck, id, dirty_gen) {
                    retained += 1;
                } else {
                    let rec = ck.stage_encoded(id, bytes, pos, exporter, dirty_gen)?;
                    extra_bytes += rec.bytes;
                    written += 1;
                }
                Ok(())
            })?;
            // committed spills: retain if clean, else link their bytes
            for id in tier.committed_ids() {
                if self.sessions.contains_key(&id) || pending_seen.contains(&id) {
                    continue;
                }
                let rec = tier
                    .committed_record(&id)
                    .ok_or_else(|| anyhow!("spill record for '{id}' vanished mid-export"))?;
                if clean(&ck, &id, rec.dirty_gen) {
                    retained += 1;
                } else {
                    let staged = ck.stage_linked(
                        &tier.dir().join(&rec.file),
                        &rec,
                        exporter,
                        rec.dirty_gen,
                    )?;
                    extra_bytes += staged.bytes;
                    written += 1;
                }
            }
            self.checkpoint_bytes += extra_bytes;
            stats.written += written;
            stats.retained += retained;
        }
        ck.commit_new_generation()?;
        stats.generation = ck.generation();
        self.delta_written += stats.written as u64;
        self.delta_retained += stats.retained as u64;
        Ok(stats)
    }

    /// Adopt every session checkpointed in `dir` (a `checkpoint_all` /
    /// `checkpoint_delta` export from this or another coordinator).
    /// All-or-nothing: every snapshot is decoded and verified before any
    /// session becomes visible; an id collision with a live session is
    /// an error (silently overwriting an advancing stream would corrupt
    /// it); and without a spill tier, an export that cannot fit in the
    /// budget is refused up front — adopting it would immediately
    /// destroy the overflow's context while reporting success. Returns
    /// the number of sessions adopted; the source directory is left
    /// intact.
    pub fn restore_from(&mut self, dir: &Path) -> Result<usize> {
        let _span = trace::span("restore_from");
        let ck = Checkpointer::open(dir)?;
        let ids = ck.ids();
        for id in &ids {
            if self.sessions.contains_key(id) || self.is_spilled(id) {
                bail!("cannot restore '{id}': a session with that id is already live");
            }
        }
        if self.spill.is_none() {
            // with a spill tier the budget demotes (recoverably); without
            // one it destroys, so the adoption must fit outright
            let resident = self.sessions.len() + ids.len();
            let over_bytes = resident * self.per_session_bytes > self.cfg.max_state_bytes;
            let over_count = self.cfg.max_sessions > 0 && resident > self.cfg.max_sessions;
            if over_bytes || over_count {
                bail!(
                    "restoring {} session(s) onto {} resident would exceed the budget \
                     and no spill tier is configured; raise max_state_bytes/max_sessions \
                     or set spill_dir",
                    ids.len(),
                    self.sessions.len()
                );
            }
        }
        let mut adopted = Vec::with_capacity(ids.len());
        for id in &ids {
            let scorer = ck.load(id, &self.model)?;
            if scorer.precision() != self.cfg.precision {
                // f32 and bf16 snapshots refuse each other: an adopted
                // stream must carry exactly the state representation the
                // manager budgets and spills
                bail!(
                    "cannot restore '{id}': snapshot carries {} state, manager runs {}",
                    scorer.precision().name(),
                    self.cfg.precision.name()
                );
            }
            adopted.push((id.clone(), scorer));
        }
        let n = adopted.len();
        for (id, scorer) in adopted {
            self.clock += 1;
            self.dirty_clock += 1;
            self.evicted_ids.remove(&id);
            self.sessions.insert(
                id,
                Session { scorer, last_used: self.clock, dirty_gen: self.dirty_clock },
            );
            self.opened += 1;
        }
        // adopted sessions count against the budget like any others
        // (with a spill tier this can only demote, never destroy)
        self.enforce_budget(&HashSet::new());
        Ok(n)
    }

    /// Evict least-recently-used sessions (never one in `keep`) until
    /// both the byte budget and the session cap hold. With a spill tier
    /// the victim's snapshot is *enqueued* to the background writer —
    /// the serving thread pays a capture + encode (memcpy-scale), never
    /// an fsync — and the victim stays transparently resumable; without
    /// one (or if the capture fails) its context is destroyed and later
    /// chunks for the id fail loudly.
    fn enforce_budget(&mut self, keep: &HashSet<&str>) {
        loop {
            let over_bytes = self.resident_bytes() > self.cfg.max_state_bytes;
            let over_count =
                self.cfg.max_sessions > 0 && self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                return;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(k, _)| !keep.contains(k.as_str()))
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let sess = self.sessions.remove(&k).expect("victim is resident");
                    match &mut self.spill {
                        Some(tier) => {
                            let _span = trace::span("spill_enqueue");
                            match tier.enqueue(&k, sess.scorer, sess.dirty_gen, self.exporter)
                            {
                                Ok(bytes) => {
                                    self.spills += 1;
                                    self.checkpoint_bytes += bytes;
                                }
                                Err(e) => {
                                    eprintln!(
                                        "[session] spilling '{k}' failed ({e:#}); \
                                         dropping its context"
                                    );
                                    self.evicted_ids.insert(k);
                                    self.evicted += 1;
                                }
                            }
                        }
                        None => {
                            self.evicted_ids.insert(k);
                            self.evicted += 1;
                        }
                    }
                }
                // only actively-served sessions are left; let them
                // exceed the budget rather than refusing to serve them
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::{NativeModel, SyntheticConfig};

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(11);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn chunk(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    #[test]
    fn sessions_are_independent_streams() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let c = chunk(32, 0);
        let a1 = mgr.advance("a", &c).unwrap();
        let _ = mgr.advance("b", &chunk(32, 1)).unwrap();
        // a fresh session fed the same chunk reproduces session a's start
        let a2 = mgr.advance("c", &c).unwrap();
        assert_eq!(a1.logprob, a2.logprob);
        assert_eq!(mgr.tokens_seen("a"), Some(32));
        assert_eq!(mgr.len(), 3);
    }

    #[test]
    fn offsets_accumulate_within_a_session() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let s0 = mgr.advance("s", &chunk(20, 2)).unwrap();
        let s1 = mgr.advance("s", &chunk(20, 3)).unwrap();
        assert_eq!(s0.offset, 0);
        assert_eq!(s1.offset, 20);
        assert_eq!(mgr.tokens_seen("s"), Some(40));
    }

    #[test]
    fn budget_evicts_lru_and_preserves_active() {
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly two sessions
        let cfg = SessionConfig { max_state_bytes: 2 * per, ..Default::default() };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("old", &chunk(16, 4)).unwrap();
        mgr.advance("mid", &chunk(16, 5)).unwrap();
        // opening a third must evict the least-recently-used ("old")
        mgr.advance("new", &chunk(16, 6)).unwrap();
        assert!(!mgr.contains("old"), "LRU session should be evicted");
        assert!(mgr.contains("mid"), "recently used session survives");
        assert!(mgr.contains("new"), "active session is never evicted");
        assert_eq!(mgr.stats().evicted, 1);
        assert!(mgr.resident_bytes() <= 2 * per);

        // the evicted stream must fail loudly, not silently restart…
        assert!(mgr.advance("old", &chunk(16, 7)).is_err());
        // …until the client acknowledges the loss by closing the id
        mgr.close("old");
        assert!(mgr.advance("old", &chunk(16, 8)).is_ok());
    }

    #[test]
    fn session_cap_is_enforced() {
        let cfg = SessionConfig {
            max_state_bytes: usize::MAX,
            max_sessions: 2,
            spill_dir: None,
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        for (i, id) in ["a", "b", "c", "d"].iter().enumerate() {
            mgr.advance(id, &chunk(8, 10 + i as u64)).unwrap();
        }
        assert_eq!(mgr.len(), 2);
        assert!(mgr.contains("d"));
    }

    #[test]
    fn close_releases_state() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        mgr.advance("x", &chunk(8, 20)).unwrap();
        assert!(mgr.resident_bytes() > 0);
        assert!(mgr.close("x"));
        assert!(!mgr.close("x"));
        assert_eq!(mgr.resident_bytes(), 0);
        assert!(mgr.is_empty());
        let st = mgr.stats();
        assert_eq!((st.opened, st.closed), (1, 1));
    }

    #[test]
    fn budget_charges_true_resident_bytes() {
        let m = model();
        let mgr = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        // the estimate must equal the Σ_layers heads × M_layer × (d_h+1)
        // prefix sums plus the carried vocab-sized context row
        let kernels = m.kernels().expect("synthetic model must be FAVOR");
        let dh = m.d_model / m.n_heads;
        let f32s = std::mem::size_of::<f32>();
        let expect = kernels.iter().map(|k| m.n_heads * k.m() * (dh + 1) * f32s).sum::<usize>()
            + m.vocab_size * f32s;
        assert_eq!(mgr.per_session_bytes(), expect);

        // ...and match what a live session actually carries at steady
        // state (after its first chunk)
        let mut scorer = ChunkScorer::new(m).unwrap();
        assert!(scorer.resident_bytes() < mgr.per_session_bytes(), "no context row yet");
        scorer.advance(&chunk(16, 40)).unwrap();
        assert_eq!(scorer.resident_bytes(), mgr.per_session_bytes());
        assert_eq!(scorer.steady_state_bytes(), mgr.per_session_bytes());
    }

    #[test]
    fn batched_advance_matches_sequential_and_orders_duplicates() {
        let m = model();
        let mut seq = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        let mut bat = SessionManager::new(m, SessionConfig::default()).unwrap();
        let c0 = chunk(24, 50);
        let c1 = chunk(16, 51);
        let c2 = chunk(24, 52);
        // "a" appears twice: its second chunk must see the first's state
        let ids = ["a", "b", "a"];
        let chunks: Vec<&[u8]> = vec![&c0, &c1, &c2];
        let fused = bat.advance_batch(&ids, &chunks);
        for (i, (id, c)) in ids.iter().zip(&chunks).enumerate() {
            let want = seq.advance(id, c).unwrap();
            let got = fused[i].as_ref().expect("batched advance succeeds");
            assert_eq!(got.offset, want.offset, "request {i}");
            let diff = got
                .logprob
                .iter()
                .zip(&want.logprob)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-5, "request {i}: fused diverges by {diff}");
        }
        assert_eq!(bat.stats().chunks, 3);
        assert_eq!(bat.stats().tokens, (c0.len() + c1.len() + c2.len()) as u64);
    }

    #[test]
    fn batch_members_survive_budget_pressure_across_waves() {
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly two sessions
        let cfg = SessionConfig { max_state_bytes: 2 * per, ..Default::default() };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("live", &chunk(16, 70)).unwrap();
        mgr.advance("idle", &chunk(16, 71)).unwrap();
        // one window: a new session plus "live", with incompatible
        // lengths (100 > 2×8) so they land in separate fused waves.
        // Budget pressure must evict the idle session, never a batch
        // member — even one whose wave runs after the eviction.
        let short = chunk(8, 72);
        let long = chunk(100, 73);
        let res = mgr.advance_batch(&["new", "live"], &[&short, &long]);
        assert!(res[0].is_ok(), "new session must be served");
        assert!(
            res[1].is_ok(),
            "batch member in a later wave must not be evicted by an earlier wave: {:?}",
            res[1].as_ref().err()
        );
        assert!(mgr.contains("live") && mgr.contains("new"));
        assert!(!mgr.contains("idle"), "the idle session is the only valid victim");
    }

    #[test]
    fn batched_advance_isolates_per_request_failures() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let good = chunk(12, 60);
        let empty: &[u8] = &[];
        let bad = vec![200u8; 4]; // outside vocab
        let res = mgr.advance_batch(&["ok", "e", "v"], &[&good, empty, &bad]);
        assert!(res[0].is_ok(), "valid request must survive bad neighbors");
        assert!(res[1].is_err());
        assert!(res[2].is_err());
        assert_eq!(mgr.stats().chunks, 1);
        // failed requests must not leave half-open sessions resident
        assert!(mgr.contains("ok"));
        assert!(!mgr.contains("e") && !mgr.contains("v"));
    }

    #[test]
    fn single_oversized_session_still_served() {
        let cfg = SessionConfig { max_state_bytes: 1, ..Default::default() };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        // budget smaller than one session: the active stream still works
        let s = mgr.advance("only", &chunk(8, 30)).unwrap();
        assert_eq!(s.len(), 8);
        assert!(mgr.contains("only"));
    }

    fn tempdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("pfrm_session_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn bits(scores: &ChunkScores) -> Vec<u32> {
        scores.logprob.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn spill_then_rehydrate_is_bitwise_transparent() {
        let dir = tempdir("spill");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly one resident session, spill tier enabled
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m.clone(), cfg).unwrap();
        let mut ref_mgr = SessionManager::new(m, SessionConfig::default()).unwrap();

        let (c0, c1) = (chunk(24, 80), chunk(24, 81));
        assert_eq!(
            bits(&mgr.advance("a", &c0).unwrap()),
            bits(&ref_mgr.advance("a", &c0).unwrap())
        );
        // opening "b" demotes "a" to the spill tier instead of
        // destroying it — the eviction only *enqueues* the write
        mgr.advance("b", &chunk(24, 82)).unwrap();
        assert!(!mgr.contains("a") && mgr.is_spilled("a"));
        assert_eq!(mgr.stats().spills, 1);
        assert!(mgr.stats().checkpoint_bytes > 0);

        // the next chunk for "a" rehydrates transparently, scores
        // bitwise identical to the never-evicted reference stream
        assert_eq!(
            bits(&mgr.advance("a", &c1).unwrap()),
            bits(&ref_mgr.advance("a", &c1).unwrap())
        );
        assert!(mgr.contains("a") && !mgr.is_spilled("a"));
        let st = mgr.stats();
        assert_eq!((st.spills, st.rehydrations), (2, 1), "advancing 'a' demoted 'b'");
        assert_eq!(st.evicted, 0, "a spill is not a context-destroying eviction");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn advance_while_spill_in_flight_never_serves_stale_state() {
        let dir = tempdir("inflight");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m.clone(), cfg).unwrap();
        let mut ref_mgr = SessionManager::new(m, SessionConfig::default()).unwrap();
        let (c0, c1, c2) = (chunk(24, 180), chunk(24, 181), chunk(24, 182));

        mgr.advance("a", &c0).unwrap();
        ref_mgr.advance("a", &c0).unwrap();
        // hold the background writer, then evict "a": its spill stays
        // observably in flight
        mgr.set_spill_hold(true);
        mgr.advance("b", &c1).unwrap();
        ref_mgr.advance("b", &c1).unwrap();
        assert!(mgr.is_spilled("a"));
        assert_eq!(mgr.stats().pending_spills, 1, "write must still be in flight");

        // advancing "a" with its spill in flight must take the parked
        // resident copy (no disk read possible — nothing committed yet)
        // and must be bitwise identical to the uninterrupted stream
        assert_eq!(
            bits(&mgr.advance("a", &c2).unwrap()),
            bits(&ref_mgr.advance("a", &c2).unwrap()),
            "in-flight spill served stale state"
        );
        assert!(mgr.contains("a"));

        // release the writer: the canceled write must never commit a
        // stale snapshot that a later rehydration could pick up
        mgr.set_spill_hold(false);
        mgr.sync_spills().unwrap();
        let st = mgr.stats();
        assert!(st.spill_cancels >= 1, "the superseded write must be canceled");
        // "a" is resident; the only tier occupant may be "b"'s spill
        assert!(mgr.contains("a") && !mgr.is_spilled("a"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn close_during_inflight_spill_never_resurrects_the_dead_stream() {
        let dir = tempdir("close_inflight");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("a", &chunk(24, 190)).unwrap();
        // hold the writer, evict "a" (spill in flight), then close it
        mgr.set_spill_hold(true);
        mgr.advance("b", &chunk(24, 191)).unwrap();
        assert!(mgr.is_spilled("a"));
        assert!(mgr.close("a"));
        // reopening the id starts a FRESH stream at offset 0 — and must
        // keep doing so even after the lagging write is released: the
        // canceled job must never publish the dead stream's snapshot
        assert_eq!(mgr.advance("a", &chunk(24, 192)).unwrap().offset, 0);
        mgr.set_spill_hold(false);
        mgr.sync_spills().unwrap();
        assert!(mgr.stats().spill_cancels >= 1);
        // the fresh stream continues from ITS own position, not the dead one's
        assert_eq!(mgr.advance("a", &chunk(24, 193)).unwrap().offset, 24);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_enqueues_and_background_commit_lands() {
        let dir = tempdir("async_commit");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("a", &chunk(16, 90)).unwrap();
        mgr.advance("b", &chunk(16, 91)).unwrap(); // evicts "a" (enqueue)
        mgr.sync_spills().unwrap();
        let st = mgr.stats();
        assert_eq!(st.pending_spills, 0, "sync drains the queue");
        assert_eq!(st.spill_commits, 1);
        assert!(st.spill_enqueue_nanos > 0 && st.spill_write_nanos > 0);
        // the committed snapshot is on disk and rehydratable
        assert!(mgr.is_spilled("a"));
        assert_eq!(mgr.advance("a", &chunk(16, 92)).unwrap().offset, 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pending_limit_sheds_to_loud_eviction() {
        let dir = tempdir("shed");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // one resident slot; staging bounded to roughly one encoded
        // snapshot (a snapshot carries at least the per-session state,
        // so two can never fit under 2×per)
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 2 * per,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        // hold the writer: parked snapshots accumulate instead of draining
        mgr.set_spill_hold(true);
        mgr.advance("a", &chunk(16, 200)).unwrap();
        mgr.advance("b", &chunk(16, 201)).unwrap(); // spills "a" (fits)
        let st = mgr.stats();
        assert!(mgr.is_spilled("a"));
        assert_eq!(st.spill_sheds, 0);
        assert!(st.spill_pending_bytes > 0, "staged bytes must be visible");
        assert!(st.spill_pending_bytes <= 2 * per as u64, "high-water mark respected");

        // evicting "b" would stage a second snapshot past the mark: the
        // spill is shed and the eviction degrades to the loud kind
        mgr.advance("c", &chunk(16, 202)).unwrap();
        let st = mgr.stats();
        assert_eq!(st.spill_sheds, 1, "over-mark spill must shed");
        assert_eq!(st.evicted, 1, "the shed spill becomes a loud eviction");
        assert!(!mgr.is_spilled("b"));
        let err = mgr.advance("b", &chunk(16, 203)).unwrap_err();
        assert!(format!("{err:#}").contains("evicted"), "{err:#}");

        // draining the writer releases the staged bytes; the spill that
        // did fit stays transparently resumable
        mgr.set_spill_hold(false);
        mgr.sync_spills().unwrap();
        let st = mgr.stats();
        assert_eq!((st.pending_spills, st.spill_pending_bytes), (0, 0));
        assert_eq!(mgr.advance("a", &chunk(16, 204)).unwrap().offset, 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn close_drops_spilled_snapshots_too() {
        let dir = tempdir("close");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("a", &chunk(16, 83)).unwrap();
        mgr.advance("b", &chunk(16, 84)).unwrap();
        assert!(mgr.is_spilled("a"));
        assert!(mgr.close("a"), "closing a spilled session reports it existed");
        assert!(!mgr.is_spilled("a"));
        // the id is reusable and starts a *fresh* stream
        let s = mgr.advance("a", &chunk(16, 85)).unwrap();
        assert_eq!(s.offset, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spilled_snapshot_fails_loudly() {
        let dir = tempdir("corrupt");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("a", &chunk(16, 86)).unwrap();
        mgr.advance("b", &chunk(16, 87)).unwrap();
        assert!(mgr.is_spilled("a"));
        // wait for the background write to commit, then flip one byte of
        // the spilled snapshot
        mgr.sync_spills().unwrap();
        let snap = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| p.extension().is_some_and(|x| x == "snap"))
            .expect("one spilled snapshot on disk");
        let mut bytes = std::fs::read(&snap).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&snap, &bytes).unwrap();

        let err = mgr.advance("a", &chunk(16, 88)).unwrap_err();
        assert!(format!("{err:#}").contains("rehydrating"), "{err:#}");
        // acknowledging the loss frees the id
        mgr.close("a");
        assert_eq!(mgr.advance("a", &chunk(16, 89)).unwrap().offset, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_all_restore_from_migrates_every_session() {
        let ck_dir = tempdir("ckall");
        let spill_dir = tempdir("ckall_spill");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // one resident slot + spill tier: "a" ends up spilled, "b" resident
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(spill_dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut donor = SessionManager::new(m.clone(), cfg).unwrap();
        let (ca, cb) = (chunk(20, 90), chunk(20, 91));
        donor.advance("a", &ca).unwrap();
        donor.advance("b", &cb).unwrap();
        assert!(donor.is_spilled("a") && donor.contains("b"));
        assert!(donor.checkpoint_all(&spill_dir).is_err(), "spill dir is not a valid target");
        assert_eq!(donor.checkpoint_all(&ck_dir).unwrap(), 2);

        // a warm replica (no spill tier needed) adopts both sessions...
        let mut replica = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        assert_eq!(replica.restore_from(&ck_dir).unwrap(), 2);
        assert!(replica.contains("a") && replica.contains("b"));
        assert_eq!(replica.tokens_seen("a"), Some(20));

        // ...and continues them exactly where the donor would have
        let mut reference = SessionManager::new(m, SessionConfig::default()).unwrap();
        reference.advance("a", &ca).unwrap();
        reference.advance("b", &cb).unwrap();
        let next = chunk(20, 92);
        assert_eq!(
            bits(&replica.advance("a", &next).unwrap()),
            bits(&reference.advance("a", &next).unwrap())
        );

        // adopting over a live id must refuse, not overwrite
        assert!(replica.restore_from(&ck_dir).is_err());
        let _ = std::fs::remove_dir_all(&ck_dir);
        let _ = std::fs::remove_dir_all(&spill_dir);
    }

    #[test]
    fn reexport_to_reused_dir_drops_stale_sessions() {
        let dir = tempdir("reexport");
        let m = model();
        let mut donor = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        donor.advance("a", &chunk(16, 100)).unwrap();
        donor.advance("b", &chunk(16, 101)).unwrap();
        assert_eq!(donor.checkpoint_all(&dir).unwrap(), 2);
        // "a" closes; a re-export into the SAME dir must not keep it
        donor.close("a");
        assert_eq!(donor.checkpoint_all(&dir).unwrap(), 1);
        let mut replica = SessionManager::new(m, SessionConfig::default()).unwrap();
        assert_eq!(replica.restore_from(&dir).unwrap(), 1);
        assert!(replica.contains("b"));
        assert!(!replica.contains("a"), "closed session resurrected from a stale export");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn delta_checkpoint_writes_only_dirty_sessions() {
        let dir = tempdir("delta");
        let m = model();
        let mut mgr = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        for (i, id) in ["a", "b", "c"].iter().enumerate() {
            mgr.advance(id, &chunk(16, 120 + i as u64)).unwrap();
        }
        // first export seeds the dirty markers (full)
        assert_eq!(mgr.checkpoint_all(&dir).unwrap(), 3);
        let gen0 = Checkpointer::open(&dir).unwrap().generation();

        // advancing only "b" must make the next delta write exactly one
        // record (O(k) for k dirty) and retain the other two untouched
        mgr.advance("b", &chunk(16, 130)).unwrap();
        let d = mgr.checkpoint_delta(&dir).unwrap();
        assert_eq!((d.written, d.retained, d.removed), (1, 2, 0));
        assert!(d.generation > gen0, "each export commits a new generation");

        // a clean delta writes nothing at all
        let d = mgr.checkpoint_delta(&dir).unwrap();
        assert_eq!((d.written, d.retained), (0, 3));

        // closing "c" retires its record on the next delta
        mgr.close("c");
        let d = mgr.checkpoint_delta(&dir).unwrap();
        assert_eq!((d.written, d.retained, d.removed), (0, 2, 1));

        // the delta chain restores exactly what a fresh full export would
        let full = tempdir("delta_full");
        mgr.checkpoint_all(&full).unwrap();
        let mut from_delta = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        let mut from_full = SessionManager::new(m, SessionConfig::default()).unwrap();
        assert_eq!(from_delta.restore_from(&dir).unwrap(), 2);
        assert_eq!(from_full.restore_from(&full).unwrap(), 2);
        for id in ["a", "b"] {
            let next = chunk(16, 140);
            assert_eq!(
                bits(&from_delta.advance(id, &next).unwrap()),
                bits(&from_full.advance(id, &next).unwrap()),
                "delta-chain restore diverged for '{id}'"
            );
        }
        let st = mgr.stats();
        assert_eq!((st.delta_written, st.delta_retained), (1, 7));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&full);
    }

    #[test]
    fn delta_retains_clean_spilled_sessions() {
        let dir = tempdir("delta_spill");
        let spill = tempdir("delta_spill_tier");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(spill.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("a", &chunk(16, 150)).unwrap();
        mgr.advance("b", &chunk(16, 151)).unwrap(); // spills "a"
        mgr.sync_spills().unwrap();
        assert_eq!(mgr.checkpoint_delta(&dir).unwrap().written, 2);
        // nothing advanced: the committed spill and the resident session
        // are both provably clean
        let d = mgr.checkpoint_delta(&dir).unwrap();
        assert_eq!((d.written, d.retained), (0, 2));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&spill);
    }

    #[test]
    fn redraw_crossings_are_counted() {
        let mut rng = Pcg64::new(61);
        // redraw every 24 tokens: a 40-token advance crosses one boundary
        let m = Arc::new(NativeModel::synthetic(
            &SyntheticConfig { redraw_every: 24, ..Default::default() },
            &mut rng,
        ));
        let mut mgr = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        mgr.advance("r", &chunk(20, 160)).unwrap();
        let st = mgr.stats();
        assert_eq!((st.epoch_crossings, st.state_resets), (0, 0), "no boundary yet");
        mgr.advance("r", &chunk(20, 161)).unwrap(); // crosses 24
        let st = mgr.stats();
        assert_eq!(st.epoch_crossings, 1);
        // every (layer, head) state resets once per crossing
        let states = m.n_layers() * m.n_heads;
        assert_eq!(st.state_resets, states as u64);
        // two more boundaries (48, 72) in one big chunk
        mgr.advance("r", &chunk(48, 162)).unwrap();
        let st = mgr.stats();
        assert_eq!(st.epoch_crossings, 2);
        assert_eq!(st.state_resets, 3 * states as u64);
    }

    #[test]
    fn restart_clears_stale_spill_snapshots() {
        let dir = tempdir("stale_spill");
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        let cfg = SessionConfig {
            max_state_bytes: per,
            max_sessions: 0,
            spill_dir: Some(dir.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut first = SessionManager::new(m.clone(), cfg.clone()).unwrap();
        first.advance("a", &chunk(16, 102)).unwrap();
        first.advance("b", &chunk(16, 103)).unwrap();
        assert!(first.is_spilled("a"));
        drop(first); // the process "dies": resident 'b' is gone for good

        // a new manager on the same spill dir must NOT resume 'a'
        // mid-stream while 'b' silently vanished — the spill tier is a
        // cache, not a recovery mechanism
        let mut second = SessionManager::new(m, cfg).unwrap();
        assert!(!second.is_spilled("a"), "stale spill snapshot survived a restart");
        assert_eq!(second.advance("a", &chunk(16, 104)).unwrap().offset, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_without_spill_refuses_over_budget_exports() {
        let dir = tempdir("overbudget");
        let m = model();
        let mut donor = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        for (i, id) in ["a", "b", "c"].iter().enumerate() {
            donor.advance(id, &chunk(16, 110 + i as u64)).unwrap();
        }
        donor.checkpoint_all(&dir).unwrap();
        let per = donor.per_session_bytes();

        // room for two sessions, no spill tier: adopting three would
        // destroy one immediately — refuse instead, adopting nothing
        let cfg = SessionConfig { max_state_bytes: 2 * per, ..Default::default() };
        let mut replica = SessionManager::new(m.clone(), cfg).unwrap();
        assert!(replica.restore_from(&dir).is_err());
        assert!(replica.is_empty(), "a refused restore must adopt nothing");

        // the same adoption with a spill tier succeeds: overflow demotes
        // to disk, recoverably, instead of being destroyed
        let spill = tempdir("overbudget_spill");
        let cfg = SessionConfig {
            max_state_bytes: 2 * per,
            max_sessions: 0,
            spill_dir: Some(spill.clone()),
            spill_pending_limit: 0,
            ..Default::default()
        };
        let mut replica = SessionManager::new(m, cfg).unwrap();
        assert_eq!(replica.restore_from(&dir).unwrap(), 3);
        let st = replica.stats();
        assert_eq!(st.active + st.spilled, 3, "every adopted session stays live");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&spill);
    }

    #[test]
    fn restore_from_missing_dir_is_loud() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let ghost = tempdir("ghost");
        assert!(mgr.restore_from(&ghost).is_err());
    }
}
