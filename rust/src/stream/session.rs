//! Session management for concurrent long-context streams: many users
//! hold open streams against one model; each session carries only the
//! constant-size FAVOR prefix-sum state, and a global memory budget with
//! LRU eviction keeps residency bounded no matter how many streams are
//! opened and abandoned.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::train::NativeModel;

use super::scorer::{ChunkScorer, ChunkScores};

/// Budget knobs for a [`SessionManager`].
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// total bytes of carried attention state across all sessions; when
    /// exceeded, least-recently-used sessions are evicted (the active
    /// one is always preserved)
    pub max_state_bytes: usize,
    /// hard cap on simultaneously resident sessions (0 = no cap)
    pub max_sessions: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        // 64 MiB of stream state, no session-count cap
        SessionConfig { max_state_bytes: 64 << 20, max_sessions: 0 }
    }
}

/// Aggregate counters, cheap to copy out for metrics/logging.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    pub active: usize,
    pub resident_bytes: usize,
    pub opened: u64,
    pub closed: u64,
    pub evicted: u64,
    pub chunks: u64,
    pub tokens: u64,
}

struct Session {
    scorer: ChunkScorer,
    last_used: u64,
}

/// Keyed store of open streams over one model, with budgeted residency.
pub struct SessionManager {
    model: Arc<NativeModel>,
    cfg: SessionConfig,
    sessions: HashMap<String, Session>,
    /// ids dropped under memory pressure: a later chunk for one of these
    /// must fail loudly (the causal context is gone) rather than
    /// silently reopen at offset 0 with context-free scores
    evicted_ids: HashSet<String>,
    /// logical clock for LRU ordering
    clock: u64,
    /// bytes of carried state per session (uniform: one model)
    per_session_bytes: usize,
    opened: u64,
    closed: u64,
    evicted: u64,
    chunks: u64,
    tokens: u64,
}

impl SessionManager {
    /// Build over a streamable model. Errors if the model cannot stream
    /// (bidirectional or non-FAVOR attention).
    pub fn new(model: Arc<NativeModel>, cfg: SessionConfig) -> Result<SessionManager> {
        // probe streamability once up front so `advance` can't half-open
        let probe = ChunkScorer::new(model.clone())?;
        let per_session_bytes = probe.state_bytes();
        Ok(SessionManager {
            model,
            cfg,
            sessions: HashMap::new(),
            evicted_ids: HashSet::new(),
            clock: 0,
            per_session_bytes,
            opened: 0,
            closed: 0,
            evicted: 0,
            chunks: 0,
            tokens: 0,
        })
    }

    /// Carried-state bytes for one session (constant for a given model).
    pub fn per_session_bytes(&self) -> usize {
        self.per_session_bytes
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.sessions.contains_key(id)
    }

    /// Total resident carried-state bytes.
    pub fn resident_bytes(&self) -> usize {
        self.sessions.len() * self.per_session_bytes
    }

    pub fn stats(&self) -> SessionStats {
        SessionStats {
            active: self.sessions.len(),
            resident_bytes: self.resident_bytes(),
            opened: self.opened,
            closed: self.closed,
            evicted: self.evicted,
            chunks: self.chunks,
            tokens: self.tokens,
        }
    }

    /// Tokens consumed so far by a resident session.
    pub fn tokens_seen(&self, id: &str) -> Option<usize> {
        self.sessions.get(id).map(|s| s.scorer.tokens_seen())
    }

    /// Feed the next chunk of stream `id` (opening it on first use) and
    /// return the chunk's scores. May evict other idle sessions to stay
    /// within budget; the session being advanced is never evicted. A
    /// session that *was* evicted fails loudly here — its causal context
    /// is gone, so silently restarting it would return wrong scores;
    /// `close` it (acknowledging the loss) to reuse the id.
    pub fn advance(&mut self, id: &str, chunk: &[u8]) -> Result<ChunkScores> {
        let needs_open = !self.sessions.contains_key(id);
        if needs_open {
            if self.evicted_ids.contains(id) {
                return Err(anyhow!(
                    "session '{id}' was evicted under memory pressure; \
                     close it and start a new session"
                ));
            }
            let scorer = ChunkScorer::new(self.model.clone())?;
            self.sessions.insert(id.to_string(), Session { scorer, last_used: self.clock });
            self.opened += 1;
            self.enforce_budget(id);
        }
        self.clock += 1;
        let clock = self.clock;
        let session = self
            .sessions
            .get_mut(id)
            .ok_or_else(|| anyhow!("session '{id}' vanished"))?;
        session.last_used = clock;
        let scores = session.scorer.advance(chunk)?;
        self.chunks += 1;
        self.tokens += chunk.len() as u64;
        Ok(scores)
    }

    /// Explicitly end a stream, releasing its state immediately (and
    /// acknowledging a prior eviction, freeing the id for reuse).
    /// Returns whether the session was resident.
    pub fn close(&mut self, id: &str) -> bool {
        self.evicted_ids.remove(id);
        let existed = self.sessions.remove(id).is_some();
        if existed {
            self.closed += 1;
        }
        existed
    }

    /// Evict least-recently-used sessions (never `keep`) until both the
    /// byte budget and the session cap hold.
    fn enforce_budget(&mut self, keep: &str) {
        loop {
            let over_bytes = self.resident_bytes() > self.cfg.max_state_bytes;
            let over_count =
                self.cfg.max_sessions > 0 && self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                return;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(k, _)| k.as_str() != keep)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    self.sessions.remove(&k);
                    self.evicted_ids.insert(k);
                    self.evicted += 1;
                }
                // only the active session is left; let it exceed the
                // budget rather than refusing to serve it
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::{NativeModel, SyntheticConfig};

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(11);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn chunk(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    #[test]
    fn sessions_are_independent_streams() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let c = chunk(32, 0);
        let a1 = mgr.advance("a", &c).unwrap();
        let _ = mgr.advance("b", &chunk(32, 1)).unwrap();
        // a fresh session fed the same chunk reproduces session a's start
        let a2 = mgr.advance("c", &c).unwrap();
        assert_eq!(a1.logprob, a2.logprob);
        assert_eq!(mgr.tokens_seen("a"), Some(32));
        assert_eq!(mgr.len(), 3);
    }

    #[test]
    fn offsets_accumulate_within_a_session() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let s0 = mgr.advance("s", &chunk(20, 2)).unwrap();
        let s1 = mgr.advance("s", &chunk(20, 3)).unwrap();
        assert_eq!(s0.offset, 0);
        assert_eq!(s1.offset, 20);
        assert_eq!(mgr.tokens_seen("s"), Some(40));
    }

    #[test]
    fn budget_evicts_lru_and_preserves_active() {
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly two sessions
        let cfg = SessionConfig { max_state_bytes: 2 * per, max_sessions: 0 };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("old", &chunk(16, 4)).unwrap();
        mgr.advance("mid", &chunk(16, 5)).unwrap();
        // opening a third must evict the least-recently-used ("old")
        mgr.advance("new", &chunk(16, 6)).unwrap();
        assert!(!mgr.contains("old"), "LRU session should be evicted");
        assert!(mgr.contains("mid"), "recently used session survives");
        assert!(mgr.contains("new"), "active session is never evicted");
        assert_eq!(mgr.stats().evicted, 1);
        assert!(mgr.resident_bytes() <= 2 * per);

        // the evicted stream must fail loudly, not silently restart…
        assert!(mgr.advance("old", &chunk(16, 7)).is_err());
        // …until the client acknowledges the loss by closing the id
        mgr.close("old");
        assert!(mgr.advance("old", &chunk(16, 8)).is_ok());
    }

    #[test]
    fn session_cap_is_enforced() {
        let cfg = SessionConfig { max_state_bytes: usize::MAX, max_sessions: 2 };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        for (i, id) in ["a", "b", "c", "d"].iter().enumerate() {
            mgr.advance(id, &chunk(8, 10 + i as u64)).unwrap();
        }
        assert_eq!(mgr.len(), 2);
        assert!(mgr.contains("d"));
    }

    #[test]
    fn close_releases_state() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        mgr.advance("x", &chunk(8, 20)).unwrap();
        assert!(mgr.resident_bytes() > 0);
        assert!(mgr.close("x"));
        assert!(!mgr.close("x"));
        assert_eq!(mgr.resident_bytes(), 0);
        assert!(mgr.is_empty());
        let st = mgr.stats();
        assert_eq!((st.opened, st.closed), (1, 1));
    }

    #[test]
    fn single_oversized_session_still_served() {
        let cfg = SessionConfig { max_state_bytes: 1, max_sessions: 0 };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        // budget smaller than one session: the active stream still works
        let s = mgr.advance("only", &chunk(8, 30)).unwrap();
        assert_eq!(s.len(), 8);
        assert!(mgr.contains("only"));
    }
}
