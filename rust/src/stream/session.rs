//! Session management for concurrent long-context streams: many users
//! hold open streams against one model; each session carries only the
//! constant-size FAVOR prefix-sum state, and a global memory budget with
//! LRU eviction keeps residency bounded no matter how many streams are
//! opened and abandoned.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::train::NativeModel;

use super::scorer::{ChunkScorer, ChunkScores};

/// Budget knobs for a [`SessionManager`].
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// total bytes of carried attention state across all sessions; when
    /// exceeded, least-recently-used sessions are evicted (the active
    /// one is always preserved)
    pub max_state_bytes: usize,
    /// hard cap on simultaneously resident sessions (0 = no cap)
    pub max_sessions: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        // 64 MiB of stream state, no session-count cap
        SessionConfig { max_state_bytes: 64 << 20, max_sessions: 0 }
    }
}

/// Chunks are fused into one wave only when the longest is at most this
/// multiple of the shortest — past that, the padding rows the fused
/// `Batch` carries for the short chunks outweigh the fusion win.
const COMPAT_LEN_RATIO: usize = 2;

/// Aggregate counters, cheap to copy out for metrics/logging.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionStats {
    pub active: usize,
    pub resident_bytes: usize,
    pub opened: u64,
    pub closed: u64,
    pub evicted: u64,
    pub chunks: u64,
    pub tokens: u64,
}

struct Session {
    scorer: ChunkScorer,
    last_used: u64,
}

/// Keyed store of open streams over one model, with budgeted residency.
pub struct SessionManager {
    model: Arc<NativeModel>,
    cfg: SessionConfig,
    sessions: HashMap<String, Session>,
    /// ids dropped under memory pressure: a later chunk for one of these
    /// must fail loudly (the causal context is gone) rather than
    /// silently reopen at offset 0 with context-free scores
    evicted_ids: HashSet<String>,
    /// logical clock for LRU ordering
    clock: u64,
    /// bytes of carried state per session (uniform: one model)
    per_session_bytes: usize,
    opened: u64,
    closed: u64,
    evicted: u64,
    chunks: u64,
    tokens: u64,
}

impl SessionManager {
    /// Build over a streamable model. Errors if the model cannot stream
    /// (bidirectional or non-FAVOR attention).
    pub fn new(model: Arc<NativeModel>, cfg: SessionConfig) -> Result<SessionManager> {
        // probe streamability once up front so `advance` can't half-open;
        // budget the *steady-state* residency (prefix sums + the carried
        // vocab-sized context row), which every live session reaches
        // after its first chunk — charging only the attention state
        // undercounted by vocab×4 bytes per session
        let probe = ChunkScorer::new(model.clone())?;
        let per_session_bytes = probe.steady_state_bytes();
        Ok(SessionManager {
            model,
            cfg,
            sessions: HashMap::new(),
            evicted_ids: HashSet::new(),
            clock: 0,
            per_session_bytes,
            opened: 0,
            closed: 0,
            evicted: 0,
            chunks: 0,
            tokens: 0,
        })
    }

    /// Carried-state bytes for one session (constant for a given model).
    pub fn per_session_bytes(&self) -> usize {
        self.per_session_bytes
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.sessions.contains_key(id)
    }

    /// Total resident carried-state bytes.
    pub fn resident_bytes(&self) -> usize {
        self.sessions.len() * self.per_session_bytes
    }

    pub fn stats(&self) -> SessionStats {
        SessionStats {
            active: self.sessions.len(),
            resident_bytes: self.resident_bytes(),
            opened: self.opened,
            closed: self.closed,
            evicted: self.evicted,
            chunks: self.chunks,
            tokens: self.tokens,
        }
    }

    /// Tokens consumed so far by a resident session.
    pub fn tokens_seen(&self, id: &str) -> Option<usize> {
        self.sessions.get(id).map(|s| s.scorer.tokens_seen())
    }

    /// Feed the next chunk of stream `id` (opening it on first use) and
    /// return the chunk's scores. May evict other idle sessions to stay
    /// within budget; the session being advanced is never evicted. A
    /// session that *was* evicted fails loudly here — its causal context
    /// is gone, so silently restarting it would return wrong scores;
    /// `close` it (acknowledging the loss) to reuse the id.
    /// Thin wrapper over [`Self::advance_batch`] with B = 1.
    pub fn advance(&mut self, id: &str, chunk: &[u8]) -> Result<ChunkScores> {
        self.advance_batch(&[id], &[chunk]).pop().expect("B=1 advance")
    }

    /// Feed the next chunk of several streams in one fused forward
    /// ([`ChunkScorer::advance_batch`] →
    /// [`crate::train::NativeModel::forward_chunk_batch`]): the dense
    /// per-token work of the whole batch runs as single matrix
    /// operations while each session's carried state advances exactly as
    /// B sequential [`Self::advance`] calls would. Results line up with
    /// `ids`; each request succeeds or fails independently (bad chunk,
    /// evicted id). The batch is served as one or more fused *waves*: a
    /// wave holds each session at most once (a repeated id advances in
    /// submission order across successive waves, so callers may drain a
    /// queue without deduplicating) and only chunks within
    /// [`COMPAT_LEN_RATIO`]× of each other in length (beyond that, the
    /// padding rows the fused `Batch` would carry outweigh the fusion
    /// win). None of the batch's sessions is evicted while serving any
    /// part of it.
    pub fn advance_batch(&mut self, ids: &[&str], chunks: &[&[u8]]) -> Vec<Result<ChunkScores>> {
        assert_eq!(ids.len(), chunks.len(), "{} ids fed {} chunks", ids.len(), chunks.len());
        let mut results: Vec<Option<Result<ChunkScores>>> =
            (0..ids.len()).map(|_| None).collect();

        // per-request validation and open-on-first-use, before fusing
        let mut admitted: Vec<usize> = Vec::new();
        for (i, (&id, &chunk)) in ids.iter().zip(chunks).enumerate() {
            if chunk.is_empty() {
                results[i] = Some(Err(anyhow!("empty chunk")));
                continue;
            }
            if let Some(&t) = chunk.iter().find(|&&t| t as usize >= self.model.vocab_size) {
                results[i] = Some(Err(anyhow!(
                    "token {t} outside vocab (size {})",
                    self.model.vocab_size
                )));
                continue;
            }
            if !self.sessions.contains_key(id) {
                if self.evicted_ids.contains(id) {
                    results[i] = Some(Err(anyhow!(
                        "session '{id}' was evicted under memory pressure; \
                         close it and start a new session"
                    )));
                    continue;
                }
                match ChunkScorer::new(self.model.clone()) {
                    Ok(scorer) => {
                        self.sessions
                            .insert(id.to_string(), Session { scorer, last_used: self.clock });
                        self.opened += 1;
                    }
                    Err(e) => {
                        results[i] = Some(Err(e));
                        continue;
                    }
                }
            }
            admitted.push(i);
        }
        let keep: HashSet<&str> = admitted.iter().map(|&i| ids[i]).collect();
        self.enforce_budget(&keep);

        // fused waves: a wave holds each session at most once (so a
        // duplicated id advances sequentially in submission order) and
        // only length-compatible chunks. An id deferred for length is
        // blocked for the rest of the wave — a later chunk of the same
        // session must not jump ahead of it.
        let mut remaining = admitted;
        while !remaining.is_empty() {
            let mut wave: Vec<usize> = Vec::new();
            let mut in_wave: HashSet<&str> = HashSet::new();
            let mut blocked: HashSet<&str> = HashSet::new();
            let mut next: Vec<usize> = Vec::new();
            let (mut wlo, mut whi) = (0usize, 0usize); // wave's length window
            for i in remaining {
                let id = ids[i];
                if in_wave.contains(id) || blocked.contains(id) {
                    next.push(i);
                    continue;
                }
                let len = chunks[i].len();
                let (nlo, nhi) = if wave.is_empty() {
                    (len, len)
                } else {
                    (wlo.min(len), whi.max(len))
                };
                if nhi > COMPAT_LEN_RATIO * nlo {
                    blocked.insert(id);
                    next.push(i);
                    continue;
                }
                (wlo, whi) = (nlo, nhi);
                in_wave.insert(id);
                wave.push(i);
            }
            // pull the wave's scorers out of the map so they advance as
            // one contiguous mutable slice, then reinsert (each with its
            // own clock tick, in submission order, so LRU ordering stays
            // a deterministic total order exactly as sequential advances
            // would produce)
            let mut scorers: Vec<ChunkScorer> = wave
                .iter()
                .map(|&i| {
                    self.sessions.remove(ids[i]).expect("admitted session resident").scorer
                })
                .collect();
            let wave_chunks: Vec<&[u8]> = wave.iter().map(|&i| chunks[i]).collect();
            match ChunkScorer::advance_batch(&mut scorers, &wave_chunks) {
                Ok(scores) => {
                    for ((&i, scorer), sc) in wave.iter().zip(scorers).zip(scores) {
                        self.chunks += 1;
                        self.tokens += chunks[i].len() as u64;
                        self.clock += 1;
                        self.sessions.insert(
                            ids[i].to_string(),
                            Session { scorer, last_used: self.clock },
                        );
                        results[i] = Some(Ok(sc));
                    }
                }
                Err(e) => {
                    // advance_batch validates before touching any state,
                    // so the scorers are unmodified: keep them resident
                    let msg = format!("{e:#}");
                    for (&i, scorer) in wave.iter().zip(scorers) {
                        self.clock += 1;
                        self.sessions.insert(
                            ids[i].to_string(),
                            Session { scorer, last_used: self.clock },
                        );
                        results[i] = Some(Err(anyhow!("{msg}")));
                    }
                }
            }
            remaining = next;
        }
        results.into_iter().map(|r| r.expect("every request answered")).collect()
    }

    /// Explicitly end a stream, releasing its state immediately (and
    /// acknowledging a prior eviction, freeing the id for reuse).
    /// Returns whether the session was resident.
    pub fn close(&mut self, id: &str) -> bool {
        self.evicted_ids.remove(id);
        let existed = self.sessions.remove(id).is_some();
        if existed {
            self.closed += 1;
        }
        existed
    }

    /// Evict least-recently-used sessions (never one in `keep`) until
    /// both the byte budget and the session cap hold.
    fn enforce_budget(&mut self, keep: &HashSet<&str>) {
        loop {
            let over_bytes = self.resident_bytes() > self.cfg.max_state_bytes;
            let over_count =
                self.cfg.max_sessions > 0 && self.sessions.len() > self.cfg.max_sessions;
            if !over_bytes && !over_count {
                return;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(k, _)| !keep.contains(k.as_str()))
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    self.sessions.remove(&k);
                    self.evicted_ids.insert(k);
                    self.evicted += 1;
                }
                // only actively-served sessions are left; let them
                // exceed the budget rather than refusing to serve them
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::{NativeModel, SyntheticConfig};

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(11);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn chunk(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    #[test]
    fn sessions_are_independent_streams() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let c = chunk(32, 0);
        let a1 = mgr.advance("a", &c).unwrap();
        let _ = mgr.advance("b", &chunk(32, 1)).unwrap();
        // a fresh session fed the same chunk reproduces session a's start
        let a2 = mgr.advance("c", &c).unwrap();
        assert_eq!(a1.logprob, a2.logprob);
        assert_eq!(mgr.tokens_seen("a"), Some(32));
        assert_eq!(mgr.len(), 3);
    }

    #[test]
    fn offsets_accumulate_within_a_session() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let s0 = mgr.advance("s", &chunk(20, 2)).unwrap();
        let s1 = mgr.advance("s", &chunk(20, 3)).unwrap();
        assert_eq!(s0.offset, 0);
        assert_eq!(s1.offset, 20);
        assert_eq!(mgr.tokens_seen("s"), Some(40));
    }

    #[test]
    fn budget_evicts_lru_and_preserves_active() {
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly two sessions
        let cfg = SessionConfig { max_state_bytes: 2 * per, max_sessions: 0 };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("old", &chunk(16, 4)).unwrap();
        mgr.advance("mid", &chunk(16, 5)).unwrap();
        // opening a third must evict the least-recently-used ("old")
        mgr.advance("new", &chunk(16, 6)).unwrap();
        assert!(!mgr.contains("old"), "LRU session should be evicted");
        assert!(mgr.contains("mid"), "recently used session survives");
        assert!(mgr.contains("new"), "active session is never evicted");
        assert_eq!(mgr.stats().evicted, 1);
        assert!(mgr.resident_bytes() <= 2 * per);

        // the evicted stream must fail loudly, not silently restart…
        assert!(mgr.advance("old", &chunk(16, 7)).is_err());
        // …until the client acknowledges the loss by closing the id
        mgr.close("old");
        assert!(mgr.advance("old", &chunk(16, 8)).is_ok());
    }

    #[test]
    fn session_cap_is_enforced() {
        let cfg = SessionConfig { max_state_bytes: usize::MAX, max_sessions: 2 };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        for (i, id) in ["a", "b", "c", "d"].iter().enumerate() {
            mgr.advance(id, &chunk(8, 10 + i as u64)).unwrap();
        }
        assert_eq!(mgr.len(), 2);
        assert!(mgr.contains("d"));
    }

    #[test]
    fn close_releases_state() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        mgr.advance("x", &chunk(8, 20)).unwrap();
        assert!(mgr.resident_bytes() > 0);
        assert!(mgr.close("x"));
        assert!(!mgr.close("x"));
        assert_eq!(mgr.resident_bytes(), 0);
        assert!(mgr.is_empty());
        let st = mgr.stats();
        assert_eq!((st.opened, st.closed), (1, 1));
    }

    #[test]
    fn budget_charges_true_resident_bytes() {
        use crate::train::NativeAttention;
        let m = model();
        let mgr = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        // the estimate must equal the layers × heads × M × (d_h + 1)
        // prefix sums plus the carried vocab-sized context row
        let NativeAttention::Favor(fm) = &m.attention else {
            panic!("synthetic model must be FAVOR");
        };
        let dh = m.d_model / m.n_heads;
        let f32s = std::mem::size_of::<f32>();
        let expect = m.n_layers() * m.n_heads * fm.m() * (dh + 1) * f32s + m.vocab_size * f32s;
        assert_eq!(mgr.per_session_bytes(), expect);

        // ...and match what a live session actually carries at steady
        // state (after its first chunk)
        let mut scorer = ChunkScorer::new(m).unwrap();
        assert!(scorer.resident_bytes() < mgr.per_session_bytes(), "no context row yet");
        scorer.advance(&chunk(16, 40)).unwrap();
        assert_eq!(scorer.resident_bytes(), mgr.per_session_bytes());
        assert_eq!(scorer.steady_state_bytes(), mgr.per_session_bytes());
    }

    #[test]
    fn batched_advance_matches_sequential_and_orders_duplicates() {
        let m = model();
        let mut seq = SessionManager::new(m.clone(), SessionConfig::default()).unwrap();
        let mut bat = SessionManager::new(m, SessionConfig::default()).unwrap();
        let c0 = chunk(24, 50);
        let c1 = chunk(16, 51);
        let c2 = chunk(24, 52);
        // "a" appears twice: its second chunk must see the first's state
        let ids = ["a", "b", "a"];
        let chunks: Vec<&[u8]> = vec![&c0, &c1, &c2];
        let fused = bat.advance_batch(&ids, &chunks);
        for (i, (id, c)) in ids.iter().zip(&chunks).enumerate() {
            let want = seq.advance(id, c).unwrap();
            let got = fused[i].as_ref().expect("batched advance succeeds");
            assert_eq!(got.offset, want.offset, "request {i}");
            let diff = got
                .logprob
                .iter()
                .zip(&want.logprob)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-5, "request {i}: fused diverges by {diff}");
        }
        assert_eq!(bat.stats().chunks, 3);
        assert_eq!(bat.stats().tokens, (c0.len() + c1.len() + c2.len()) as u64);
    }

    #[test]
    fn batch_members_survive_budget_pressure_across_waves() {
        let m = model();
        let per = SessionManager::new(m.clone(), SessionConfig::default())
            .unwrap()
            .per_session_bytes();
        // room for exactly two sessions
        let cfg = SessionConfig { max_state_bytes: 2 * per, max_sessions: 0 };
        let mut mgr = SessionManager::new(m, cfg).unwrap();
        mgr.advance("live", &chunk(16, 70)).unwrap();
        mgr.advance("idle", &chunk(16, 71)).unwrap();
        // one window: a new session plus "live", with incompatible
        // lengths (100 > 2×8) so they land in separate fused waves.
        // Budget pressure must evict the idle session, never a batch
        // member — even one whose wave runs after the eviction.
        let short = chunk(8, 72);
        let long = chunk(100, 73);
        let res = mgr.advance_batch(&["new", "live"], &[&short, &long]);
        assert!(res[0].is_ok(), "new session must be served");
        assert!(
            res[1].is_ok(),
            "batch member in a later wave must not be evicted by an earlier wave: {:?}",
            res[1].as_ref().err()
        );
        assert!(mgr.contains("live") && mgr.contains("new"));
        assert!(!mgr.contains("idle"), "the idle session is the only valid victim");
    }

    #[test]
    fn batched_advance_isolates_per_request_failures() {
        let mut mgr = SessionManager::new(model(), SessionConfig::default()).unwrap();
        let good = chunk(12, 60);
        let empty: &[u8] = &[];
        let bad = vec![200u8; 4]; // outside vocab
        let res = mgr.advance_batch(&["ok", "e", "v"], &[&good, empty, &bad]);
        assert!(res[0].is_ok(), "valid request must survive bad neighbors");
        assert!(res[1].is_err());
        assert!(res[2].is_err());
        assert_eq!(mgr.stats().chunks, 1);
        // failed requests must not leave half-open sessions resident
        assert!(mgr.contains("ok"));
        assert!(!mgr.contains("e") && !mgr.contains("v"));
    }

    #[test]
    fn single_oversized_session_still_served() {
        let cfg = SessionConfig { max_state_bytes: 1, max_sessions: 0 };
        let mut mgr = SessionManager::new(model(), cfg).unwrap();
        // budget smaller than one session: the active stream still works
        let s = mgr.advance("only", &chunk(8, 30)).unwrap();
        assert_eq!(s.len(), 8);
        assert!(mgr.contains("only"));
    }
}
