//! The incremental FAVOR prefix-sum state — the streaming core of the
//! unidirectional attention (Alg. 1, Sec. 2.5.1 / 2.6).
//!
//! Causal FAVOR needs only the running M×(d+1) aggregate
//! G^PS = Σ_{j≤i} K'_j [V_j 1]ᵀ to produce row i's output, so a sequence
//! can be consumed *chunk by chunk* in O(M(d+1)) resident memory,
//! independent of how many tokens have streamed through. This module is
//! the single source of truth for that recurrence:
//! `favor::linear::favor_unidirectional` is a thin wrapper that runs one
//! chunk covering the whole sequence.

use crate::favor::features::FeatureMap;
use crate::favor::linear::STABILIZER;
use crate::tensor::{axpy, dot, Mat};

/// Storage precision of a [`StreamState`]'s resident prefix sums.
///
/// `F32` keeps the running G^PS matrix in full f32 — bitwise identical
/// to the historical behavior. `Bf16` stores it as bfloat16 (top 16
/// bits of the f32, round-to-nearest-even), halving resident bytes per
/// session; every chunk *accumulates* in f32 (the state is dequantized
/// into an f32 scratch, advanced with the exact recurrence, and
/// requantized once at the chunk boundary), so the only precision loss
/// is one bf16 rounding of the sums per chunk. bf16 shares f32's 8-bit
/// exponent, so no value-range rescaling is needed; the per-state
/// `scale` records the max-abs magnitude at the last requantize for
/// observability and snapshot integrity checks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StatePrecision {
    /// Full-precision f32 prefix sums (default; historical behavior).
    #[default]
    F32,
    /// bfloat16 storage with f32 chunk accumulation.
    Bf16,
}

impl StatePrecision {
    /// Canonical lowercase name, as accepted by [`Self::parse`].
    pub fn name(self) -> &'static str {
        match self {
            StatePrecision::F32 => "f32",
            StatePrecision::Bf16 => "bf16",
        }
    }

    /// Parse a precision name (`"f32"` / `"bf16"`).
    pub fn parse(s: &str) -> Option<StatePrecision> {
        match s {
            "f32" => Some(StatePrecision::F32),
            "bf16" => Some(StatePrecision::Bf16),
            _ => None,
        }
    }

    /// Resident bytes per stored prefix-sum entry.
    pub fn bytes_per_entry(self) -> usize {
        match self {
            StatePrecision::F32 => 4,
            StatePrecision::Bf16 => 2,
        }
    }
}

/// Encode an f32 as bfloat16 (round-to-nearest-even on the dropped
/// mantissa bits). The carry from the rounding increment propagates
/// correctly into the exponent across power-of-two boundaries.
pub fn bf16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// Decode a bfloat16 back to f32 — exact (bf16 values are a subset of
/// f32).
pub fn bf16_decode(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Streaming state of one attention head: the running M×(d+1) prefix-sum
/// matrix (value columns plus the fused ones-column for the denominator),
/// tagged with the redraw epoch its sums were accumulated under.
///
/// The sums live either in full f32 (`state`) or, under
/// [`StatePrecision::Bf16`], as bf16 words (`qstate`) that are expanded
/// to f32 only for the duration of each [`Self::advance`] call.
#[derive(Clone, Debug)]
pub struct StreamState {
    /// number of random features M
    m: usize,
    /// value/head dimension d
    d: usize,
    /// storage precision of the resident sums
    precision: StatePrecision,
    /// running G^PS, shape M×(d+1) — authoritative under `F32`, empty
    /// under `Bf16`
    state: Mat,
    /// bf16 words of G^PS, length M×(d+1) — authoritative under
    /// `Bf16`, empty under `F32`
    qstate: Vec<u16>,
    /// max-abs of the sums at the last requantize (bf16 bookkeeping;
    /// stays 0 under `F32`)
    scale: f32,
    /// total rows consumed since creation/reset (cumulative across
    /// redraw epochs — epoch transitions do not rewind it)
    tokens_seen: u64,
    /// the kernel redraw epoch the prefix sums belong to: sums from one
    /// epoch's feature space can never be mixed with another's
    epoch: u64,
}

/// One chunk of the exact f32 recurrence over a dense prefix-sum
/// matrix: `state += K'_i C_i^T` then `out_i = (Q'_i · G^PS)` row by
/// row. Shared verbatim by both precisions — the bf16 path calls it on
/// a dequantized scratch, so within a chunk the arithmetic is
/// operation-for-operation identical to f32 mode.
fn advance_dense(state: &mut Mat, qp: &Mat, kp: &Mat, v: &Mat, d: usize) -> Mat {
    let l = qp.rows;
    let mut out = Mat::zeros(l, d);
    let mut buf = vec![0.0f32; d + 1];
    for i in 0..l {
        // state += K'_i C_i^T  (C_i = [V_i 1])
        let krow = kp.row(i);
        let vrow = v.row(i);
        for (j, &kij) in krow.iter().enumerate() {
            if kij != 0.0 {
                let srow = &mut state.data[j * (d + 1)..(j + 1) * (d + 1)];
                axpy(kij, vrow, &mut srow[..d]);
                srow[d] += kij;
            }
        }
        // out_i = (Q'_i · G^PS) renormalized by the ones-column
        buf.fill(0.0);
        let qrow = qp.row(i);
        for (j, &qij) in qrow.iter().enumerate() {
            if qij != 0.0 {
                axpy(qij, &state.data[j * (d + 1)..(j + 1) * (d + 1)], &mut buf);
            }
        }
        let denom = buf[d] + STABILIZER;
        for (o, &b) in out.row_mut(i).iter_mut().zip(&buf[..d]) {
            *o = b / denom;
        }
    }
    out
}

/// Gradients of one [`advance_vjp`] call: cotangents of the chunk's
/// mapped features/values and of the entry prefix sums.
pub struct AdvanceGrads {
    /// dL/dphi(Q) for the chunk (L×M)
    pub dqp: Mat,
    /// dL/dphi(K) for the chunk (L×M)
    pub dkp: Mat,
    /// dL/dV for the chunk (L×d)
    pub dv: Mat,
    /// dL/dG^PS at chunk entry (M×(d+1)) — the "d-state out" that flows
    /// into the preceding chunk's backward, mirroring state in/state out
    pub dstate_in: Mat,
}

/// Reverse-mode gradient of one chunk of the prefix-sum recurrence (the
/// SLiM chunk-local backward): given the *entry* state `state_in` (the
/// dense f32 image [`StreamState::dense`] captured at the chunk
/// boundary), the chunk's inputs, the cotangent `dout` of the chunk's
/// attention outputs and the cotangent `dstate_out` of the chunk's *end*
/// state (zeros for the final chunk; the previous call's `dstate_in` for
/// any other), produce the input cotangents and the entry-state
/// cotangent.
///
/// Two sweeps, O(M(d+1)) resident memory beyond the chunk itself:
///   * forward sweep re-runs the exact recurrence from `state_in`
///     (operation-for-operation [`StreamState::advance`]'s arithmetic),
///     producing per-row `du_i` — the cotangent of the un-normalized row
///     aggregate `u_i = q'_i · G^PS_i` — and `dqp_i = G^PS_i · du_i`,
///     which only need the *current* state;
///   * reverse sweep carries the running state cotangent `dS` from
///     `dstate_out` back down: row i adds its `q'_i ⊗ du_i` contribution,
///     then reads off `dkp_i = dS · [v_i 1]` and `dv_i = k'_iᵀ dS` before
///     passing `dS` unchanged across the `S_i = S_{i−1} + …` update.
///
/// No per-row state trajectory is stored — only `du` (L×(d+1)) — which
/// is what keeps the chunked backward's footprint linear in the chunk,
/// not the stream.
pub fn advance_vjp(
    state_in: &Mat,
    qp: &Mat,
    kp: &Mat,
    v: &Mat,
    dout: &Mat,
    dstate_out: &Mat,
) -> AdvanceGrads {
    let l = qp.rows;
    let m = qp.cols;
    let d = v.cols;
    assert_eq!((state_in.rows, state_in.cols), (m, d + 1), "state_in must be M x (d+1)");
    assert_eq!((dstate_out.rows, dstate_out.cols), (m, d + 1), "dstate_out must be M x (d+1)");
    assert_eq!((kp.rows, kp.cols), (l, m), "kp shape mismatch");
    assert_eq!(v.rows, l, "v rows != qp rows");
    assert_eq!((dout.rows, dout.cols), (l, d), "dout shape mismatch");

    let mut dqp = Mat::zeros(l, m);
    let mut dkp = Mat::zeros(l, m);
    let mut dv = Mat::zeros(l, d);
    let mut du = Mat::zeros(l, d + 1);

    // ---- forward sweep: recompute S_i, emit du_i and dqp_i -------------
    let mut state = state_in.clone();
    let mut buf = vec![0.0f32; d + 1];
    for i in 0..l {
        // identical update arithmetic to `advance_dense`
        let krow = kp.row(i);
        let vrow = v.row(i);
        for (j, &kij) in krow.iter().enumerate() {
            if kij != 0.0 {
                let srow = &mut state.data[j * (d + 1)..(j + 1) * (d + 1)];
                axpy(kij, vrow, &mut srow[..d]);
                srow[d] += kij;
            }
        }
        buf.fill(0.0);
        let qrow = qp.row(i);
        for (j, &qij) in qrow.iter().enumerate() {
            if qij != 0.0 {
                axpy(qij, &state.data[j * (d + 1)..(j + 1) * (d + 1)], &mut buf);
            }
        }
        let denom = buf[d] + STABILIZER;
        // out_i[j] = u_i[j]/denom, denom = u_i[d] + STABILIZER:
        //   du_i[j] = dout_i[j]/denom            (j < d)
        //   du_i[d] = −Σ_j dout_i[j]·out_i[j]/denom
        let dorow = dout.row(i);
        let durow = du.row_mut(i);
        let mut dd = 0.0f32;
        for j in 0..d {
            durow[j] = dorow[j] / denom;
            dd += dorow[j] * (buf[j] / denom);
        }
        durow[d] = -dd / denom;
        // dqp_i[j] = S_i.row(j) · du_i  (needs only the current state;
        // NOT gated on qij == 0 — the gradient at a zero input is still
        // the gradient)
        let dqrow = dqp.row_mut(i);
        for (j, dq) in dqrow.iter_mut().enumerate() {
            *dq = dot(&state.data[j * (d + 1)..(j + 1) * (d + 1)], durow);
        }
    }

    // ---- reverse sweep: carry dS down, emit dkp_i and dv_i -------------
    let mut dstate = dstate_out.clone();
    for i in (0..l).rev() {
        // S_i fed both out_i (via u_i = q'_i·S_i) and S_{i+1}:
        //   dS_i = dS_{i+1} + q'_i ⊗ du_i
        let qrow = qp.row(i);
        let durow = du.row(i);
        for (j, &qij) in qrow.iter().enumerate() {
            if qij != 0.0 {
                axpy(qij, durow, &mut dstate.data[j * (d + 1)..(j + 1) * (d + 1)]);
            }
        }
        // S_i = S_{i−1} + k'_i [v_i 1]ᵀ:
        //   dkp_i[j] = dS_i.row(j)[..d]·v_i + dS_i.row(j)[d]
        //   dv_i    += Σ_j k'_ij · dS_i.row(j)[..d]
        //   dS_{i−1} = dS_i  (pass-through)
        let vrow = v.row(i);
        let krow = kp.row(i);
        let dkrow = dkp.row_mut(i);
        let dvrow = dv.row_mut(i);
        for j in 0..m {
            let dsrow = &dstate.data[j * (d + 1)..(j + 1) * (d + 1)];
            dkrow[j] = dot(&dsrow[..d], vrow) + dsrow[d];
            let kij = krow[j];
            if kij != 0.0 {
                axpy(kij, &dsrow[..d], dvrow);
            }
        }
    }

    AdvanceGrads { dqp, dkp, dv, dstate_in: dstate }
}

impl StreamState {
    /// Fresh f32 state for M features and value dimension d.
    pub fn new(m: usize, d: usize) -> StreamState {
        StreamState::with_precision(m, d, StatePrecision::F32)
    }

    /// Fresh state for M features and value dimension d with the given
    /// storage precision for the resident sums.
    pub fn with_precision(m: usize, d: usize, precision: StatePrecision) -> StreamState {
        let (state, qstate) = match precision {
            StatePrecision::F32 => (Mat::zeros(m, d + 1), Vec::new()),
            StatePrecision::Bf16 => (Mat::zeros(0, 0), vec![0u16; m * (d + 1)]),
        };
        StreamState { m, d, precision, state, qstate, scale: 0.0, tokens_seen: 0, epoch: 0 }
    }

    /// Number of random features M.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Value/head dimension d.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows consumed so far across all chunks (and all redraw epochs).
    pub fn tokens_seen(&self) -> u64 {
        self.tokens_seen
    }

    /// The kernel redraw epoch this state's prefix sums belong to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Storage precision of the resident prefix sums.
    pub fn precision(&self) -> StatePrecision {
        self.precision
    }

    /// Max-abs magnitude of the sums at the last bf16 requantize — the
    /// per-state scale bookkeeping surfaced in snapshots and gauges.
    /// Always 0 under [`StatePrecision::F32`].
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// The raw bf16 words of G^PS (row-major, M×(d+1)) — empty under
    /// [`StatePrecision::F32`]. Read-only view for snapshot
    /// serialization.
    pub fn quant_state(&self) -> &[u16] {
        &self.qstate
    }

    /// Cross into a new redraw epoch: zero the prefix sums (they live in
    /// the previous draw's feature space — attention context restarts at
    /// the boundary) while the cumulative token count keeps running.
    /// Called by the model forward when a chunk segment enters `epoch`.
    pub fn reset_for_epoch(&mut self, epoch: u64) {
        self.state.data.fill(0.0);
        self.qstate.fill(0);
        self.scale = 0.0;
        self.epoch = epoch;
    }

    /// The M×(d+1) prefix-sum matrix expanded to f32, whatever the
    /// storage precision — owned copy for snapshot serialization
    /// (`persist/snapshot.rs`) and diagnostics. Exact under `F32`; under
    /// `Bf16` this is the exact f32 image of the stored bf16 words (the
    /// decode is lossless).
    pub fn dense(&self) -> Mat {
        match self.precision {
            StatePrecision::F32 => self.state.clone(),
            StatePrecision::Bf16 => Mat::from_vec(
                self.m,
                self.d + 1,
                self.qstate.iter().map(|&h| bf16_decode(h)).collect(),
            ),
        }
    }

    /// Rebuild an f32 state from snapshot parts: the M×(d+1) prefix-sum
    /// matrix, the consumed-token count and the redraw epoch the sums
    /// were accumulated under. Inverse of reading
    /// [`Self::dense`]/[`Self::tokens_seen`]/[`Self::epoch`]; the
    /// restored state continues the stream bit-for-bit where the
    /// captured one stopped.
    pub fn from_parts(m: usize, d: usize, state: Mat, tokens_seen: u64, epoch: u64) -> StreamState {
        assert_eq!(
            (state.rows, state.cols),
            (m, d + 1),
            "prefix-sum matrix must be M x (d+1)"
        );
        StreamState {
            m,
            d,
            precision: StatePrecision::F32,
            state,
            qstate: Vec::new(),
            scale: 0.0,
            tokens_seen,
            epoch,
        }
    }

    /// Rebuild a bf16 state from snapshot parts: the raw bf16 words of
    /// G^PS plus the recorded requantize scale. Inverse of reading
    /// [`Self::quant_state`]/[`Self::scale`]; the restored state
    /// continues the stream bit-for-bit where the captured bf16 state
    /// stopped.
    pub fn from_quant_parts(
        m: usize,
        d: usize,
        qstate: Vec<u16>,
        scale: f32,
        tokens_seen: u64,
        epoch: u64,
    ) -> StreamState {
        assert_eq!(qstate.len(), m * (d + 1), "bf16 prefix sums must be M x (d+1)");
        StreamState {
            m,
            d,
            precision: StatePrecision::Bf16,
            state: Mat::zeros(0, 0),
            qstate,
            scale,
            tokens_seen,
            epoch,
        }
    }

    /// Resident size of the carried state in bytes — constant in the
    /// streamed length, the whole point of the subsystem. Halves under
    /// [`StatePrecision::Bf16`].
    pub fn state_bytes(&self) -> usize {
        self.m * (self.d + 1) * self.precision.bytes_per_entry()
    }

    /// Forget everything and start a new stream.
    pub fn reset(&mut self) {
        self.state.data.fill(0.0);
        self.qstate.fill(0);
        self.scale = 0.0;
        self.tokens_seen = 0;
        self.epoch = 0;
    }

    /// Consume one chunk of mapped features/values and return the chunk's
    /// attention outputs. `qp`/`kp` are the feature-mapped queries/keys
    /// (chunk_len × M), `v` the values (chunk_len × d). Row i's output
    /// uses the running sum over every previously consumed row plus rows
    /// ≤ i of this chunk — identical, operation for operation, to the
    /// single-shot `favor_unidirectional` on the concatenated stream.
    pub fn advance(&mut self, qp: &Mat, kp: &Mat, v: &Mat) -> Mat {
        let l = qp.rows;
        let (m, d) = (self.m, self.d);
        assert_eq!(qp.cols, m, "qp features != state M");
        assert_eq!(kp.cols, m, "kp features != state M");
        assert_eq!(kp.rows, l, "kp rows != qp rows");
        assert_eq!(v.rows, l, "v rows != qp rows");
        assert_eq!(v.cols, d, "v dim != state d");

        let out = match self.precision {
            StatePrecision::F32 => advance_dense(&mut self.state, qp, kp, v, d),
            StatePrecision::Bf16 => {
                // dequantize → exact f32 recurrence → requantize once at
                // the chunk boundary (f32 accumulation, bf16 storage)
                let mut scratch = Mat::from_vec(
                    m,
                    d + 1,
                    self.qstate.iter().map(|&h| bf16_decode(h)).collect(),
                );
                let out = advance_dense(&mut scratch, qp, kp, v, d);
                let mut max_abs = 0.0f32;
                for (q, &x) in self.qstate.iter_mut().zip(&scratch.data) {
                    max_abs = max_abs.max(x.abs());
                    *q = bf16_encode(x);
                }
                self.scale = max_abs;
                out
            }
        };
        self.tokens_seen += l as u64;
        out
    }
}

/// A self-contained streaming attention head: a feature map plus its
/// running state. Feeds raw q/k/v chunks, applies φ internally.
#[derive(Clone, Debug)]
pub struct FavorStream {
    fm: FeatureMap,
    state: StreamState,
}

impl FavorStream {
    /// Stream with the given feature map over value dimension `d`.
    pub fn new(fm: FeatureMap, d: usize) -> FavorStream {
        let m = fm.m();
        FavorStream { fm, state: StreamState::new(m, d) }
    }

    /// The running prefix-sum state.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// The feature map φ this stream applies.
    pub fn feature_map(&self) -> &FeatureMap {
        &self.fm
    }

    /// Forget everything and start a new stream.
    pub fn reset(&mut self) {
        self.state.reset();
    }

    /// Consume a raw q/k/v chunk (chunk_len × d each) and return the
    /// chunk's causal attention outputs.
    pub fn advance(&mut self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let qp = self.fm.apply(q);
        let kp = self.fm.apply(k);
        self.state.advance(&qp, &kp, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::favor::linear::favor_unidirectional;
    use crate::favor::{favor_attention, Direction, FeatureKind};
    use crate::linalg::OrfMechanism;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Mat {
        Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect())
    }

    #[test]
    fn two_chunks_match_single_shot() {
        let (l, d, m) = (48usize, 8usize, 16usize);
        let mut rng = Pcg64::new(0);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, l, d, 0.5);
        let k = rand_mat(&mut rng, l, d, 0.5);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let single = favor_unidirectional(&qp, &kp, &v);

        let cut = 17;
        let mut st = StreamState::new(m, d);
        let out0 = st.advance(
            &qp.rows_slice(0, cut),
            &kp.rows_slice(0, cut),
            &v.rows_slice(0, cut),
        );
        let out1 = st.advance(
            &qp.rows_slice(cut, l),
            &kp.rows_slice(cut, l),
            &v.rows_slice(cut, l),
        );
        assert_eq!(st.tokens_seen(), l as u64);
        assert!(out0.max_abs_diff(&single.rows_slice(0, cut)) < 1e-6);
        assert!(out1.max_abs_diff(&single.rows_slice(cut, l)) < 1e-6);
    }

    #[test]
    fn state_size_constant_in_stream_length() {
        let (d, m) = (8usize, 16usize);
        let mut rng = Pcg64::new(1);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let mut stream = FavorStream::new(fm, d);
        let bytes0 = stream.state().state_bytes();
        for _ in 0..10 {
            let q = rand_mat(&mut rng, 32, d, 0.5);
            let k = rand_mat(&mut rng, 32, d, 0.5);
            let v = rand_mat(&mut rng, 32, d, 1.0);
            stream.advance(&q, &k, &v);
        }
        assert_eq!(stream.state().state_bytes(), bytes0);
        assert_eq!(stream.state().tokens_seen(), 320);
        assert_eq!(bytes0, m * (d + 1) * 4);
    }

    #[test]
    fn favor_stream_matches_full_attention() {
        let (l, d, m) = (40usize, 4usize, 8usize);
        let mut rng = Pcg64::new(2);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, l, d, 0.5);
        let k = rand_mat(&mut rng, l, d, 0.5);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let full = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);

        let mut stream = FavorStream::new(fm, d);
        let mut rows = Vec::new();
        for lo in (0..l).step_by(7) {
            let hi = (lo + 7).min(l);
            let out = stream.advance(
                &q.rows_slice(lo, hi),
                &k.rows_slice(lo, hi),
                &v.rows_slice(lo, hi),
            );
            rows.extend(out.data);
        }
        let streamed = Mat::from_vec(l, d, rows);
        assert!(streamed.max_abs_diff(&full) < 1e-6);
    }

    #[test]
    fn epoch_reset_restarts_context_keeps_token_count() {
        let (d, m) = (4usize, 8usize);
        let mut rng = Pcg64::new(9);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, 10, d, 0.5);
        let k = rand_mat(&mut rng, 10, d, 0.5);
        let v = rand_mat(&mut rng, 10, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let mut st = StreamState::new(m, d);
        let first = st.advance(&qp, &kp, &v);
        st.reset_for_epoch(1);
        assert_eq!(st.epoch(), 1);
        assert_eq!(st.tokens_seen(), 10, "token count survives the epoch crossing");
        // the zeroed sums make the next chunk behave like a fresh stream
        let again = st.advance(&qp, &kp, &v);
        assert!(first.max_abs_diff(&again) < 1e-7);
        assert_eq!(st.tokens_seen(), 20);
        st.reset();
        assert_eq!((st.epoch(), st.tokens_seen()), (0, 0));
    }

    #[test]
    fn bf16_codec_roundtrips_and_rounds_to_nearest_even() {
        // bf16-representable values roundtrip exactly
        for v in [0.0f32, 1.0, -2.5, 0.15625, 3.0e20, -1.0e-20] {
            let enc = bf16_encode(v);
            assert_eq!(bf16_decode(enc).to_bits(), ((enc as u32) << 16));
            assert_eq!(bf16_encode(bf16_decode(enc)), enc, "re-encode is stable");
        }
        // rounding error is bounded by half a bf16 ulp (2^-8 relative)
        for i in 0..500 {
            let v = (i as f32 * 0.731 - 180.0) * 1.37;
            let rt = bf16_decode(bf16_encode(v));
            assert!((rt - v).abs() <= v.abs() * (1.0 / 256.0), "v={v} rt={rt}");
        }
        // tie rounds to even mantissa: 1 + 2^-8 * 0.5 exactly between
        // 1.0 and 1 + 2^-7 → even neighbor 1.0
        let tie = f32::from_bits(0x3f80_8000);
        assert_eq!(bf16_decode(bf16_encode(tie)), 1.0);
    }

    #[test]
    fn bf16_state_halves_resident_bytes() {
        let (d, m) = (8usize, 16usize);
        let f32_state = StreamState::new(m, d);
        let bf16_state = StreamState::with_precision(m, d, StatePrecision::Bf16);
        assert_eq!(f32_state.precision(), StatePrecision::F32);
        assert_eq!(bf16_state.precision(), StatePrecision::Bf16);
        assert_eq!(f32_state.state_bytes(), m * (d + 1) * 4);
        assert_eq!(bf16_state.state_bytes() * 2, f32_state.state_bytes());
    }

    #[test]
    fn bf16_stream_tracks_f32_within_tolerance() {
        let (l, d, m) = (64usize, 8usize, 16usize);
        let mut rng = Pcg64::new(7);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, l, d, 0.5);
        let k = rand_mat(&mut rng, l, d, 0.5);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let mut exact = StreamState::new(m, d);
        let mut quant = StreamState::with_precision(m, d, StatePrecision::Bf16);
        let mut worst = 0.0f32;
        for lo in (0..l).step_by(9) {
            let hi = (lo + 9).min(l);
            let (qs, ks, vs) =
                (qp.rows_slice(lo, hi), kp.rows_slice(lo, hi), v.rows_slice(lo, hi));
            let oe = exact.advance(&qs, &ks, &vs);
            let oq = quant.advance(&qs, &ks, &vs);
            worst = worst.max(oe.max_abs_diff(&oq));
        }
        // bf16 has ~2^-8 relative mantissa precision; attention outputs
        // are denominator-normalized so the per-chunk requantize error
        // stays well inside a few bf16 ulps of the output magnitude
        assert!(worst < 3e-2, "bf16 drifted too far from f32: {worst}");
        assert!(quant.scale() > 0.0, "requantize records the max-abs scale");
        assert_eq!(quant.tokens_seen(), l as u64);
    }

    #[test]
    fn bf16_quant_parts_roundtrip_continues_bitwise() {
        let (d, m) = (4usize, 8usize);
        let mut rng = Pcg64::new(11);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, 20, d, 0.5);
        let k = rand_mat(&mut rng, 20, d, 0.5);
        let v = rand_mat(&mut rng, 20, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let mut st = StreamState::with_precision(m, d, StatePrecision::Bf16);
        st.advance(
            &qp.rows_slice(0, 10),
            &kp.rows_slice(0, 10),
            &v.rows_slice(0, 10),
        );
        let mut restored = StreamState::from_quant_parts(
            m,
            d,
            st.quant_state().to_vec(),
            st.scale(),
            st.tokens_seen(),
            st.epoch(),
        );
        assert_eq!(restored.state_bytes(), st.state_bytes());
        let a = st.advance(
            &qp.rows_slice(10, 20),
            &kp.rows_slice(10, 20),
            &v.rows_slice(10, 20),
        );
        let b = restored.advance(
            &qp.rows_slice(10, 20),
            &kp.rows_slice(10, 20),
            &v.rows_slice(10, 20),
        );
        let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "restored bf16 state must continue bit-for-bit");
        assert_eq!(st.quant_state(), restored.quant_state());
    }

    /// Scalar objective for the finite-difference probes: a fixed random
    /// weighting of every output entry plus every end-state entry, so
    /// both cotangent inputs of the VJP are exercised at once.
    fn probe_loss(
        state_in: &Mat,
        qp: &Mat,
        kp: &Mat,
        v: &Mat,
        wout: &Mat,
        wstate: &Mat,
    ) -> f64 {
        let d = v.cols;
        let mut st = StreamState::from_parts(qp.cols, d, state_in.clone(), 0, 0);
        let out = st.advance(qp, kp, v);
        let mut acc = 0.0f64;
        for (o, w) in out.data.iter().zip(&wout.data) {
            acc += (*o as f64) * (*w as f64);
        }
        for (s, w) in st.dense().data.iter().zip(&wstate.data) {
            acc += (*s as f64) * (*w as f64);
        }
        acc
    }

    #[test]
    fn advance_vjp_matches_finite_differences() {
        let (l, d, m) = (6usize, 3usize, 5usize);
        let mut rng = Pcg64::new(21);
        // strictly positive features keep the recurrence smooth (no ReLU
        // kinks under the FD probe) — the shapes FAVOR+ actually produces
        let mk = |rng: &mut Pcg64, r: usize, c: usize, lo: f32| {
            Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v.abs() * 0.4 + lo).collect())
        };
        let qp = mk(&mut rng, l, m, 0.05);
        let kp = mk(&mut rng, l, m, 0.05);
        let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let state_in = mk(&mut rng, m, d + 1, 0.0);
        let wout = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let wstate = Mat::from_vec(m, d + 1, rng.gaussian_vec(m * (d + 1)));

        let g = advance_vjp(&state_in, &qp, &kp, &v, &wout, &wstate);

        let eps = 1e-3f32;
        let check = |which: &str, base: &Mat, grad: &Mat, perturb: &dyn Fn(&Mat) -> f64| {
            for idx in 0..base.data.len() {
                let mut hi = base.clone();
                hi.data[idx] += eps;
                let mut lo = base.clone();
                lo.data[idx] -= eps;
                let fd = (perturb(&hi) - perturb(&lo)) / (2.0 * eps as f64);
                let an = grad.data[idx] as f64;
                assert!(
                    (fd - an).abs() <= 1e-3 + 0.02 * fd.abs().max(an.abs()),
                    "{which}[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        };
        check("dqp", &qp, &g.dqp, &|qpx| probe_loss(&state_in, qpx, &kp, &v, &wout, &wstate));
        check("dkp", &kp, &g.dkp, &|kpx| probe_loss(&state_in, &qp, kpx, &v, &wout, &wstate));
        check("dv", &v, &g.dv, &|vx| probe_loss(&state_in, &qp, &kp, vx, &wout, &wstate));
        check("dstate_in", &state_in, &g.dstate_in, &|sx| {
            probe_loss(sx, &qp, &kp, &v, &wout, &wstate)
        });
    }

    #[test]
    fn advance_vjp_chains_across_chunk_boundary() {
        // backprop through [0,cut) + [cut,l) with the d-state handoff
        // must equal backprop through the single chunk [0,l)
        let (l, d, m, cut) = (10usize, 4usize, 6usize, 4usize);
        let mut rng = Pcg64::new(22);
        let mk = |rng: &mut Pcg64, r: usize, c: usize| {
            Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v.abs() * 0.3 + 0.02).collect())
        };
        let qp = mk(&mut rng, l, m);
        let kp = mk(&mut rng, l, m);
        let v = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let dout = Mat::from_vec(l, d, rng.gaussian_vec(l * d));
        let zero_state = Mat::zeros(m, d + 1);

        let whole = advance_vjp(&zero_state, &qp, &kp, &v, &dout, &Mat::zeros(m, d + 1));

        // the boundary state is the recurrence run over the head chunk
        let mut st = StreamState::new(m, d);
        st.advance(&qp.rows_slice(0, cut), &kp.rows_slice(0, cut), &v.rows_slice(0, cut));
        let mid = st.dense();
        let tail = advance_vjp(
            &mid,
            &qp.rows_slice(cut, l),
            &kp.rows_slice(cut, l),
            &v.rows_slice(cut, l),
            &dout.rows_slice(cut, l),
            &Mat::zeros(m, d + 1),
        );
        let head = advance_vjp(
            &zero_state,
            &qp.rows_slice(0, cut),
            &kp.rows_slice(0, cut),
            &v.rows_slice(0, cut),
            &dout.rows_slice(0, cut),
            &tail.dstate_in,
        );

        let glue = |a: &Mat, b: &Mat| {
            let mut data = a.data.clone();
            data.extend_from_slice(&b.data);
            Mat::from_vec(l, a.cols, data)
        };
        assert!(glue(&head.dqp, &tail.dqp).max_abs_diff(&whole.dqp) < 1e-5);
        assert!(glue(&head.dkp, &tail.dkp).max_abs_diff(&whole.dkp) < 1e-5);
        assert!(glue(&head.dv, &tail.dv).max_abs_diff(&whole.dv) < 1e-5);
        assert!(head.dstate_in.max_abs_diff(&whole.dstate_in) < 1e-5);
    }

    #[test]
    fn reset_starts_a_fresh_stream() {
        let (d, m) = (4usize, 8usize);
        let mut rng = Pcg64::new(3);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, 12, d, 0.5);
        let k = rand_mat(&mut rng, 12, d, 0.5);
        let v = rand_mat(&mut rng, 12, d, 1.0);

        let mut stream = FavorStream::new(fm, d);
        let first = stream.advance(&q, &k, &v);
        stream.reset();
        assert_eq!(stream.state().tokens_seen(), 0);
        let again = stream.advance(&q, &k, &v);
        assert!(first.max_abs_diff(&again) < 1e-7);
    }
}
