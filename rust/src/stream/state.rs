//! The incremental FAVOR prefix-sum state — the streaming core of the
//! unidirectional attention (Alg. 1, Sec. 2.5.1 / 2.6).
//!
//! Causal FAVOR needs only the running M×(d+1) aggregate
//! G^PS = Σ_{j≤i} K'_j [V_j 1]ᵀ to produce row i's output, so a sequence
//! can be consumed *chunk by chunk* in O(M(d+1)) resident memory,
//! independent of how many tokens have streamed through. This module is
//! the single source of truth for that recurrence:
//! `favor::linear::favor_unidirectional` is a thin wrapper that runs one
//! chunk covering the whole sequence.

use crate::favor::features::FeatureMap;
use crate::favor::linear::STABILIZER;
use crate::tensor::{axpy, Mat};

/// Streaming state of one attention head: the running M×(d+1) prefix-sum
/// matrix (value columns plus the fused ones-column for the denominator),
/// tagged with the redraw epoch its sums were accumulated under.
#[derive(Clone, Debug)]
pub struct StreamState {
    /// number of random features M
    m: usize,
    /// value/head dimension d
    d: usize,
    /// running G^PS, shape M×(d+1)
    state: Mat,
    /// total rows consumed since creation/reset (cumulative across
    /// redraw epochs — epoch transitions do not rewind it)
    tokens_seen: u64,
    /// the kernel redraw epoch the prefix sums belong to: sums from one
    /// epoch's feature space can never be mixed with another's
    epoch: u64,
}

impl StreamState {
    /// Fresh state for M features and value dimension d.
    pub fn new(m: usize, d: usize) -> StreamState {
        StreamState { m, d, state: Mat::zeros(m, d + 1), tokens_seen: 0, epoch: 0 }
    }

    /// Number of random features M.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Value/head dimension d.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows consumed so far across all chunks (and all redraw epochs).
    pub fn tokens_seen(&self) -> u64 {
        self.tokens_seen
    }

    /// The kernel redraw epoch this state's prefix sums belong to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Cross into a new redraw epoch: zero the prefix sums (they live in
    /// the previous draw's feature space — attention context restarts at
    /// the boundary) while the cumulative token count keeps running.
    /// Called by the model forward when a chunk segment enters `epoch`.
    pub fn reset_for_epoch(&mut self, epoch: u64) {
        self.state.data.fill(0.0);
        self.epoch = epoch;
    }

    /// The raw M×(d+1) prefix-sum matrix — read-only view for snapshot
    /// serialization (`persist/snapshot.rs`).
    pub fn matrix(&self) -> &Mat {
        &self.state
    }

    /// Rebuild a state from snapshot parts: the M×(d+1) prefix-sum
    /// matrix, the consumed-token count and the redraw epoch the sums
    /// were accumulated under. Inverse of reading
    /// [`Self::matrix`]/[`Self::tokens_seen`]/[`Self::epoch`]; the
    /// restored state continues the stream bit-for-bit where the
    /// captured one stopped.
    pub fn from_parts(m: usize, d: usize, state: Mat, tokens_seen: u64, epoch: u64) -> StreamState {
        assert_eq!(
            (state.rows, state.cols),
            (m, d + 1),
            "prefix-sum matrix must be M x (d+1)"
        );
        StreamState { m, d, state, tokens_seen, epoch }
    }

    /// Resident size of the carried state in bytes — constant in the
    /// streamed length, the whole point of the subsystem.
    pub fn state_bytes(&self) -> usize {
        self.state.data.len() * std::mem::size_of::<f32>()
    }

    /// Forget everything and start a new stream.
    pub fn reset(&mut self) {
        self.state.data.fill(0.0);
        self.tokens_seen = 0;
        self.epoch = 0;
    }

    /// Consume one chunk of mapped features/values and return the chunk's
    /// attention outputs. `qp`/`kp` are the feature-mapped queries/keys
    /// (chunk_len × M), `v` the values (chunk_len × d). Row i's output
    /// uses the running sum over every previously consumed row plus rows
    /// ≤ i of this chunk — identical, operation for operation, to the
    /// single-shot `favor_unidirectional` on the concatenated stream.
    pub fn advance(&mut self, qp: &Mat, kp: &Mat, v: &Mat) -> Mat {
        let l = qp.rows;
        let (m, d) = (self.m, self.d);
        assert_eq!(qp.cols, m, "qp features != state M");
        assert_eq!(kp.cols, m, "kp features != state M");
        assert_eq!(kp.rows, l, "kp rows != qp rows");
        assert_eq!(v.rows, l, "v rows != qp rows");
        assert_eq!(v.cols, d, "v dim != state d");

        let mut out = Mat::zeros(l, d);
        let mut buf = vec![0.0f32; d + 1];
        for i in 0..l {
            // state += K'_i C_i^T  (C_i = [V_i 1])
            let krow = kp.row(i);
            let vrow = v.row(i);
            for (j, &kij) in krow.iter().enumerate() {
                if kij != 0.0 {
                    let srow = &mut self.state.data[j * (d + 1)..(j + 1) * (d + 1)];
                    axpy(kij, vrow, &mut srow[..d]);
                    srow[d] += kij;
                }
            }
            // out_i = (Q'_i · G^PS) renormalized by the ones-column
            buf.fill(0.0);
            let qrow = qp.row(i);
            for (j, &qij) in qrow.iter().enumerate() {
                if qij != 0.0 {
                    axpy(qij, &self.state.data[j * (d + 1)..(j + 1) * (d + 1)], &mut buf);
                }
            }
            let denom = buf[d] + STABILIZER;
            for (o, &b) in out.row_mut(i).iter_mut().zip(&buf[..d]) {
                *o = b / denom;
            }
        }
        self.tokens_seen += l as u64;
        out
    }
}

/// A self-contained streaming attention head: a feature map plus its
/// running state. Feeds raw q/k/v chunks, applies φ internally.
#[derive(Clone, Debug)]
pub struct FavorStream {
    fm: FeatureMap,
    state: StreamState,
}

impl FavorStream {
    /// Stream with the given feature map over value dimension `d`.
    pub fn new(fm: FeatureMap, d: usize) -> FavorStream {
        let m = fm.m();
        FavorStream { fm, state: StreamState::new(m, d) }
    }

    /// The running prefix-sum state.
    pub fn state(&self) -> &StreamState {
        &self.state
    }

    /// The feature map φ this stream applies.
    pub fn feature_map(&self) -> &FeatureMap {
        &self.fm
    }

    /// Forget everything and start a new stream.
    pub fn reset(&mut self) {
        self.state.reset();
    }

    /// Consume a raw q/k/v chunk (chunk_len × d each) and return the
    /// chunk's causal attention outputs.
    pub fn advance(&mut self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let qp = self.fm.apply(q);
        let kp = self.fm.apply(k);
        self.state.advance(&qp, &kp, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::favor::linear::favor_unidirectional;
    use crate::favor::{favor_attention, Direction, FeatureKind};
    use crate::linalg::OrfMechanism;
    use crate::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, scale: f32) -> Mat {
        Mat::from_vec(r, c, rng.gaussian_vec(r * c).iter().map(|v| v * scale).collect())
    }

    #[test]
    fn two_chunks_match_single_shot() {
        let (l, d, m) = (48usize, 8usize, 16usize);
        let mut rng = Pcg64::new(0);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, l, d, 0.5);
        let k = rand_mat(&mut rng, l, d, 0.5);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let single = favor_unidirectional(&qp, &kp, &v);

        let cut = 17;
        let mut st = StreamState::new(m, d);
        let out0 = st.advance(
            &qp.rows_slice(0, cut),
            &kp.rows_slice(0, cut),
            &v.rows_slice(0, cut),
        );
        let out1 = st.advance(
            &qp.rows_slice(cut, l),
            &kp.rows_slice(cut, l),
            &v.rows_slice(cut, l),
        );
        assert_eq!(st.tokens_seen(), l as u64);
        assert!(out0.max_abs_diff(&single.rows_slice(0, cut)) < 1e-6);
        assert!(out1.max_abs_diff(&single.rows_slice(cut, l)) < 1e-6);
    }

    #[test]
    fn state_size_constant_in_stream_length() {
        let (d, m) = (8usize, 16usize);
        let mut rng = Pcg64::new(1);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let mut stream = FavorStream::new(fm, d);
        let bytes0 = stream.state().state_bytes();
        for _ in 0..10 {
            let q = rand_mat(&mut rng, 32, d, 0.5);
            let k = rand_mat(&mut rng, 32, d, 0.5);
            let v = rand_mat(&mut rng, 32, d, 1.0);
            stream.advance(&q, &k, &v);
        }
        assert_eq!(stream.state().state_bytes(), bytes0);
        assert_eq!(stream.state().tokens_seen(), 320);
        assert_eq!(bytes0, m * (d + 1) * 4);
    }

    #[test]
    fn favor_stream_matches_full_attention() {
        let (l, d, m) = (40usize, 4usize, 8usize);
        let mut rng = Pcg64::new(2);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, l, d, 0.5);
        let k = rand_mat(&mut rng, l, d, 0.5);
        let v = rand_mat(&mut rng, l, d, 1.0);
        let full = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);

        let mut stream = FavorStream::new(fm, d);
        let mut rows = Vec::new();
        for lo in (0..l).step_by(7) {
            let hi = (lo + 7).min(l);
            let out = stream.advance(
                &q.rows_slice(lo, hi),
                &k.rows_slice(lo, hi),
                &v.rows_slice(lo, hi),
            );
            rows.extend(out.data);
        }
        let streamed = Mat::from_vec(l, d, rows);
        assert!(streamed.max_abs_diff(&full) < 1e-6);
    }

    #[test]
    fn epoch_reset_restarts_context_keeps_token_count() {
        let (d, m) = (4usize, 8usize);
        let mut rng = Pcg64::new(9);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, 10, d, 0.5);
        let k = rand_mat(&mut rng, 10, d, 0.5);
        let v = rand_mat(&mut rng, 10, d, 1.0);
        let (qp, kp) = (fm.apply(&q), fm.apply(&k));

        let mut st = StreamState::new(m, d);
        let first = st.advance(&qp, &kp, &v);
        st.reset_for_epoch(1);
        assert_eq!(st.epoch(), 1);
        assert_eq!(st.tokens_seen(), 10, "token count survives the epoch crossing");
        // the zeroed sums make the next chunk behave like a fresh stream
        let again = st.advance(&qp, &kp, &v);
        assert!(first.max_abs_diff(&again) < 1e-7);
        assert_eq!(st.tokens_seen(), 20);
        st.reset();
        assert_eq!((st.epoch(), st.tokens_seen()), (0, 0));
    }

    #[test]
    fn reset_starts_a_fresh_stream() {
        let (d, m) = (4usize, 8usize);
        let mut rng = Pcg64::new(3);
        let fm = FeatureMap::sample(FeatureKind::Relu, m, d, OrfMechanism::Regular, &mut rng);
        let q = rand_mat(&mut rng, 12, d, 0.5);
        let k = rand_mat(&mut rng, 12, d, 0.5);
        let v = rand_mat(&mut rng, 12, d, 1.0);

        let mut stream = FavorStream::new(fm, d);
        let first = stream.advance(&q, &k, &v);
        stream.reset();
        assert_eq!(stream.state().tokens_seen(), 0);
        let again = stream.advance(&q, &k, &v);
        assert!(first.max_abs_diff(&again) < 1e-7);
    }
}
