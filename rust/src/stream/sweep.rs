//! Shared measurement core for the chunked-latency sweeps — one
//! methodology consumed by `xp stream`, `benches/stream_scaling.rs` and
//! `examples/long_context.rs`, so the flatness claim is always measured
//! the same way: stream `total` corpus tokens through a fresh scorer in
//! fixed chunks, and compare the mean per-chunk wall time of the first
//! and last deciles (growth there would mean per-chunk cost depends on
//! how much has already streamed).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::protein::Corpus;
use crate::rng::Pcg64;
use crate::train::NativeModel;

use super::scorer::ChunkScorer;

/// One measured total-length point of a chunked-latency sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// total tokens streamed
    pub total: usize,
    /// tokens per chunk
    pub chunk: usize,
    /// number of chunks consumed
    pub n_chunks: usize,
    /// mean per-chunk seconds over the first decile of chunks
    pub first_secs: f64,
    /// mean per-chunk seconds over the last decile of chunks
    pub last_secs: f64,
    /// resident carried-state bytes after the full stream
    pub state_bytes: usize,
    /// wall time of the whole stream (tokens/s = total / wall)
    pub wall_secs: f64,
}

impl SweepPoint {
    /// last-decile / first-decile per-chunk latency; ~1.0 means flat.
    pub fn flatness_ratio(&self) -> f64 {
        self.last_secs / self.first_secs.max(1e-12)
    }

    /// Aggregate streaming throughput of the point.
    pub fn tokens_per_sec(&self) -> f64 {
        (self.n_chunks * self.chunk) as f64 / self.wall_secs.max(1e-12)
    }
}

/// Stream `total` tokens of concatenated corpus proteins through a fresh
/// [`ChunkScorer`] in `chunk`-sized pieces, timing every chunk.
pub fn chunked_latency_point(
    model: &Arc<NativeModel>,
    corpus: &Corpus,
    chunk: usize,
    total: usize,
    rng: &mut Pcg64,
) -> Result<SweepPoint> {
    let mut scorer = ChunkScorer::new(model.clone())?;
    let n_chunks = (total / chunk).max(1);
    let mut times = Vec::with_capacity(n_chunks);
    let t_all = Instant::now();
    for _ in 0..n_chunks {
        let toks = corpus.concat_stream(chunk, 1, rng).pop().unwrap();
        let t0 = Instant::now();
        scorer.advance(&toks)?;
        times.push(t0.elapsed().as_secs_f64());
    }
    let wall_secs = t_all.elapsed().as_secs_f64();
    let head = (n_chunks / 10).max(1);
    let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len() as f64;
    Ok(SweepPoint {
        total,
        chunk,
        n_chunks,
        first_secs: mean(&times[..head]),
        last_secs: mean(&times[n_chunks - head..]),
        state_bytes: scorer.state_bytes(),
        wall_secs,
    })
}

/// One measured point of the fused-throughput comparison: the same B
/// token streams advanced one session at a time vs fused through
/// [`ChunkScorer::advance_batch`].
#[derive(Clone, Copy, Debug)]
pub struct FusedPoint {
    /// concurrent sessions B
    pub n_sessions: usize,
    /// tokens per chunk
    pub chunk: usize,
    /// chunks advanced per session
    pub n_chunks: usize,
    /// wall seconds to advance every session sequentially
    pub seq_secs: f64,
    /// wall seconds to advance all sessions via fused batches
    pub fused_secs: f64,
    /// max |logprob| divergence between the two paths (must be ~0: the
    /// fused path is an execution strategy, not an approximation)
    pub max_diff: f64,
}

impl FusedPoint {
    /// Tokens consumed across all sessions (each path consumes this many).
    pub fn total_tokens(&self) -> usize {
        self.n_sessions * self.chunk * self.n_chunks
    }

    /// Aggregate throughput of the sequential path.
    pub fn seq_tokens_per_sec(&self) -> f64 {
        self.total_tokens() as f64 / self.seq_secs.max(1e-12)
    }

    /// Aggregate throughput of the fused path.
    pub fn fused_tokens_per_sec(&self) -> f64 {
        self.total_tokens() as f64 / self.fused_secs.max(1e-12)
    }

    /// Aggregate-throughput win of fusing (>1 means batching is faster).
    pub fn speedup(&self) -> f64 {
        self.seq_secs / self.fused_secs.max(1e-12)
    }
}

/// Advance `n_sessions` independent corpus streams for `n_chunks` rounds
/// of `chunk` tokens each, twice over the same token streams: once one
/// session at a time ([`ChunkScorer::advance`]), once fused
/// ([`ChunkScorer::advance_batch`]); time both and cross-check scores.
pub fn fused_throughput_point(
    model: &Arc<NativeModel>,
    corpus: &Corpus,
    n_sessions: usize,
    chunk: usize,
    n_chunks: usize,
    rng: &mut Pcg64,
) -> Result<FusedPoint> {
    let streams: Vec<Vec<Vec<u8>>> = (0..n_sessions)
        .map(|_| {
            (0..n_chunks)
                .map(|_| corpus.concat_stream(chunk, 1, rng).pop().unwrap())
                .collect()
        })
        .collect();
    let fresh = |n: usize| -> Result<Vec<ChunkScorer>> {
        (0..n).map(|_| ChunkScorer::new(model.clone())).collect()
    };

    let mut seq_scorers = fresh(n_sessions)?;
    let mut seq_scores = Vec::with_capacity(n_sessions * n_chunks);
    let t0 = Instant::now();
    for c in 0..n_chunks {
        for (s, scorer) in seq_scorers.iter_mut().enumerate() {
            seq_scores.push(scorer.advance(&streams[s][c])?);
        }
    }
    let seq_secs = t0.elapsed().as_secs_f64();

    let mut fused_scorers = fresh(n_sessions)?;
    let mut fused_scores = Vec::with_capacity(n_sessions * n_chunks);
    let t1 = Instant::now();
    for c in 0..n_chunks {
        let chunks: Vec<&[u8]> = streams.iter().map(|st| st[c].as_slice()).collect();
        fused_scores.extend(ChunkScorer::advance_batch(&mut fused_scorers, &chunks)?);
    }
    let fused_secs = t1.elapsed().as_secs_f64();

    let max_diff = seq_scores
        .iter()
        .zip(&fused_scores)
        .flat_map(|(a, b)| a.logprob.iter().zip(&b.logprob))
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    Ok(FusedPoint { n_sessions, chunk, n_chunks, seq_secs, fused_secs, max_diff })
}

/// Geometric ladder of totals ending exactly at `max_total`.
pub fn sweep_totals(start: usize, factor: usize, max_total: usize) -> Vec<usize> {
    let mut totals = Vec::new();
    let mut t = start;
    while t < max_total {
        totals.push(t);
        t *= factor.max(2);
    }
    totals.push(max_total);
    totals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::CorpusConfig;
    use crate::train::SyntheticConfig;

    #[test]
    fn totals_ladder_ends_at_max() {
        assert_eq!(sweep_totals(4096, 4, 65536), vec![4096, 16384, 65536]);
        assert_eq!(sweep_totals(4096, 4, 8192), vec![4096, 8192]);
        assert_eq!(sweep_totals(4096, 4, 2048), vec![2048]);
        assert_eq!(sweep_totals(4096, 4, 4096), vec![4096]);
    }

    #[test]
    fn fused_point_consumes_everything_and_agrees() {
        let mut rng = Pcg64::new(4);
        let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
        let corpus = Corpus::generate(CorpusConfig::default());
        let p = fused_throughput_point(&model, &corpus, 3, 32, 2, &mut rng).unwrap();
        assert_eq!(p.total_tokens(), 3 * 32 * 2);
        assert!(p.seq_secs > 0.0 && p.fused_secs > 0.0);
        assert!(p.speedup() > 0.0);
        assert!(
            p.max_diff < 1e-4,
            "fused and sequential scores must agree (diff {})",
            p.max_diff
        );
    }

    #[test]
    fn point_measures_all_chunks() {
        let mut rng = Pcg64::new(0);
        let model = Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng));
        let corpus = Corpus::generate(CorpusConfig::default());
        let p = chunked_latency_point(&model, &corpus, 64, 512, &mut rng).unwrap();
        assert_eq!(p.n_chunks, 8);
        assert!(p.first_secs > 0.0 && p.last_secs > 0.0);
        assert!(p.state_bytes > 0);
        assert!(p.flatness_ratio() > 0.0);
    }
}
