//! # Performer: linearly scalable long-context Transformers for proteins
//!
//! A three-layer reproduction of *"Masked Language Modeling for Proteins
//! via Linearly Scalable Long-Context Transformers"* (Choromanski et al.,
//! 2020) — the Performer architecture and its FAVOR attention mechanism.
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the FAVOR
//!   feature maps and linear-attention contractions.
//! * **L2** (`python/compile/model.py`): the JAX Performer/Transformer
//!   protein language model, AOT-lowered to HLO text.
//! * **L3** (this crate): the coordinator — PJRT runtime, training
//!   driver, serving router/batcher, synthetic protein data pipeline,
//!   plus a native FAVOR implementation for analysis and benchmarking.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for measured reproductions of every table/figure.

pub mod benchlib;
pub mod configx;
pub mod coordinator;
pub mod favor;
pub mod jsonx;
pub mod linalg;
pub mod protein;
pub mod rng;
pub mod runtime;
pub mod tensor;
pub mod train;
