//! # Performer: linearly scalable long-context Transformers for proteins
//!
//! A three-layer reproduction of *"Masked Language Modeling for Proteins
//! via Linearly Scalable Long-Context Transformers"* (Choromanski et al.,
//! 2020) — the Performer architecture and its FAVOR attention mechanism.
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the FAVOR
//!   feature maps and linear-attention contractions.
//! * **L2** (`python/compile/model.py`): the JAX Performer/Transformer
//!   protein language model, AOT-lowered to HLO text.
//! * **L3** (this crate): the coordinator — PJRT runtime, training
//!   driver, serving router/batcher, synthetic protein data pipeline,
//!   a native FAVOR implementation for analysis and benchmarking, the
//!   `stream` subsystem for stateful chunked long-context inference,
//!   the `persist` subsystem that makes those sessions durable
//!   (spill-to-disk eviction, checkpoint/restore migration), and the
//!   `net` subsystem that puts the whole thing on the wire (TCP frame
//!   protocol, load-shedding server, shard router with live session
//!   migration).
//!
//! See `DESIGN.md` for the system inventory; the experiment harness is
//! the `xp` binary (`rust/src/bin/xp.rs`), which writes its measured
//! tables/figures as CSV under `results/`.

// The numeric kernels index deliberately (tight f32 loops over `Mat`
// rows where iterator chains obscure the stride arithmetic); silence the
// corresponding style lint crate-wide rather than per-loop.
#![allow(clippy::needless_range_loop)]
// Every public item carries rustdoc; CI builds the docs with
// RUSTDOCFLAGS="-D warnings", so doc rot fails the build.
#![warn(missing_docs)]

pub mod benchlib;
pub mod configx;
pub mod coordinator;
pub mod favor;
pub mod jsonx;
pub mod linalg;
pub mod net;
pub mod obs;
pub mod persist;
pub mod protein;
pub mod rng;
pub mod runtime;
pub mod stream;
pub mod tensor;
pub mod train;
