//! Amino-acid vocabulary: 20 standard + 5 anomalous residues [15] plus
//! the special tokens the MLM/LM tasks need. Token ids are stable — the
//! AOT models are compiled against vocab_size = 30.

/// Special tokens.
pub const PAD: u8 = 0;
/// the MLM mask token
pub const MASK: u8 = 1;
/// beginning-of-sequence
pub const BOS: u8 = 2;
/// end-of-sequence (doubles as the separator in concatenated mode)
pub const EOS: u8 = 3; // also the protein separator in concatenated mode

/// First amino-acid token id.
pub const AA_BASE: u8 = 4;

/// The 20 standard amino acids, in the conventional alphabetical
/// one-letter order, followed by the 5 anomalous ones (B, O, U, X, Z).
pub const AA_LETTERS: [char; 25] = [
    'A', 'C', 'D', 'E', 'F', 'G', 'H', 'I', 'K', 'L', 'M', 'N', 'P', 'Q', 'R',
    'S', 'T', 'V', 'W', 'Y', 'B', 'O', 'U', 'X', 'Z',
];

/// count of standard amino acids
pub const N_STANDARD_AA: usize = 20;
/// count of all amino-acid tokens (standard + anomalous)
pub const N_AA: usize = 25;
/// total vocabulary size the models are compiled against
pub const VOCAB_SIZE: usize = AA_BASE as usize + N_AA + 1; // 30 (one reserved)

/// Empirical amino-acid frequencies (%) in TrEMBL, matching the UniProt
/// statistics page referenced by Appendix C.2 (standard AAs; anomalous
/// residues get a tiny epsilon mass).
pub const TREMBL_FREQ: [(char, f64); 20] = [
    ('A', 9.07), ('C', 1.28), ('D', 5.45), ('E', 6.17), ('F', 3.90),
    ('G', 7.27), ('H', 2.22), ('I', 5.55), ('K', 4.92), ('L', 9.89),
    ('M', 2.38), ('N', 3.88), ('P', 4.86), ('Q', 3.80), ('R', 5.77),
    ('S', 6.75), ('T', 5.54), ('V', 6.87), ('W', 1.30), ('Y', 2.91),
];

/// Physicochemical class per standard AA (for the Fig. 6 class-coloured
/// histogram): 0=hydrophobic, 1=polar, 2=acidic, 3=basic, 4=special.
pub fn aa_class(letter: char) -> u8 {
    match letter {
        'A' | 'I' | 'L' | 'M' | 'F' | 'V' | 'W' | 'Y' => 0,
        'N' | 'Q' | 'S' | 'T' => 1,
        'D' | 'E' => 2,
        'R' | 'H' | 'K' => 3,
        _ => 4, // C, G, P + anomalous
    }
}

/// Token id for an amino-acid letter.
pub fn aa_token(letter: char) -> Option<u8> {
    AA_LETTERS.iter().position(|&c| c == letter).map(|i| AA_BASE + i as u8)
}

/// Letter for a token id (special tokens map to punctuation).
pub fn token_letter(tok: u8) -> char {
    match tok {
        PAD => '.',
        MASK => '_',
        BOS => '^',
        EOS => '$',
        t if (t as usize) < AA_BASE as usize + N_AA => {
            AA_LETTERS[(t - AA_BASE) as usize]
        }
        _ => '?',
    }
}

/// Unnormalized sampling weights over all 25 AA tokens (empirical TrEMBL
/// frequencies for the standard 20, epsilon for the anomalous 5).
pub fn aa_weights() -> Vec<f64> {
    let mut w = vec![0.02; N_AA]; // anomalous epsilon
    for &(letter, pct) in &TREMBL_FREQ {
        let idx = AA_LETTERS.iter().position(|&c| c == letter).unwrap();
        w[idx] = pct;
    }
    w
}

/// Encode a letter string into token ids (skips unknown characters).
pub fn encode(seq: &str) -> Vec<u8> {
    seq.chars().filter_map(aa_token).collect()
}

/// Decode token ids into a letter string.
pub fn decode(toks: &[u8]) -> String {
    toks.iter().map(|&t| token_letter(t)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_size_matches_model() {
        assert_eq!(VOCAB_SIZE, 30);
    }

    #[test]
    fn aa_tokens_distinct_and_in_range() {
        let mut seen = std::collections::HashSet::new();
        for &c in &AA_LETTERS {
            let t = aa_token(c).unwrap();
            assert!(t >= AA_BASE && (t as usize) < VOCAB_SIZE);
            assert!(seen.insert(t));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = "MKVLAW";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn weights_cover_all_aas_and_favor_leucine() {
        let w = aa_weights();
        assert_eq!(w.len(), N_AA);
        let leu = AA_LETTERS.iter().position(|&c| c == 'L').unwrap();
        let trp = AA_LETTERS.iter().position(|&c| c == 'W').unwrap();
        assert!(w[leu] > w[trp]);
        assert!(w.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn classes_cover_standard_aas() {
        for &(letter, _) in &TREMBL_FREQ {
            assert!(aa_class(letter) <= 4);
        }
    }

    #[test]
    fn specials_decode_distinctly() {
        assert_eq!(token_letter(PAD), '.');
        assert_eq!(token_letter(MASK), '_');
        assert_eq!(token_letter(EOS), '$');
    }
}
