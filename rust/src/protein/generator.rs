//! Synthetic Pfam-style protein corpus (DESIGN.md §Substitutions).
//!
//! The paper trains on TrEMBL (105M sequences). We cannot ship TrEMBL, so
//! we build a generator that preserves the *task structure* the paper's
//! experiments exercise:
//!
//!   * family structure — each sequence is a noisy copy of one of K
//!     family consensus sequences (substitutions, indels), so a masked
//!     token is recoverable from long-range family context, and models
//!     with better global attention should score better (Fig. 4's axis);
//!   * empirical residue distribution — consensus residues are drawn
//!     from the TrEMBL amino-acid frequencies (Fig. 6's histogram);
//!   * length distribution — log-normal matched to Table 1's statistics
//!     (median 289, mean ≈ 353);
//!   * OOD split — held-out families, mirroring the held-out-Pfam
//!     protocol of Appendix C.1.

use crate::rng::Pcg64;

use super::vocab::{self, aa_weights, AA_BASE};

/// One protein family: a consensus sequence + mutation parameters.
#[derive(Clone, Debug)]
pub struct Family {
    /// stable family id (OOD families number after IID ones)
    pub id: usize,
    /// the family's consensus residues (token ids)
    pub consensus: Vec<u8>,
    /// per-position substitution probability
    pub sub_rate: f64,
    /// insertion/deletion probability per position
    pub indel_rate: f64,
}

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// in-distribution families (train/valid/test)
    pub n_families: usize,
    /// held-out families for the OOD split
    pub n_ood_families: usize,
    /// log-normal length parameters — defaults match Table 1
    pub len_mu: f64,
    /// log-normal σ of consensus lengths
    pub len_sigma: f64,
    /// shortest consensus length
    pub min_len: usize,
    /// longest consensus length
    pub max_len: usize,
    /// per-position substitution probability applied to copies
    pub sub_rate: f64,
    /// insertion/deletion probability per position
    pub indel_rate: f64,
    /// generation seed (the corpus is fully deterministic)
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_families: 60,
            n_ood_families: 12,
            // exp(mu) = median = 289; sigma chosen so mean ~= 353
            len_mu: 289f64.ln(),
            len_sigma: 0.63,
            min_len: 8,
            max_len: 2048,
            sub_rate: 0.15,
            indel_rate: 0.02,
            seed: 0,
        }
    }
}

/// A generated corpus: IID families (train/valid/test) + OOD families.
pub struct Corpus {
    /// the parameters the corpus was generated with
    pub cfg: CorpusConfig,
    /// in-distribution families
    pub families: Vec<Family>,
    /// held-out families (OOD split)
    pub ood_families: Vec<Family>,
    aa_w: Vec<f64>,
}

impl Corpus {
    /// Deterministically generate the corpus from its config.
    pub fn generate(cfg: CorpusConfig) -> Self {
        let mut rng = Pcg64::new(cfg.seed);
        let aa_w = aa_weights();
        let mk_family = |id: usize, rng: &mut Pcg64| {
            let len = sample_length(cfg.len_mu, cfg.len_sigma, cfg.min_len, cfg.max_len, rng);
            let consensus: Vec<u8> =
                (0..len).map(|_| AA_BASE + rng.categorical(&aa_w) as u8).collect();
            Family { id, consensus, sub_rate: cfg.sub_rate, indel_rate: cfg.indel_rate }
        };
        let families: Vec<Family> =
            (0..cfg.n_families).map(|i| mk_family(i, &mut rng)).collect();
        let ood_families: Vec<Family> = (0..cfg.n_ood_families)
            .map(|i| mk_family(cfg.n_families + i, &mut rng))
            .collect();
        Corpus { cfg, families, ood_families, aa_w }
    }

    /// Sample one sequence from a family: substitutions + indels.
    pub fn sample_from_family(&self, fam: &Family, rng: &mut Pcg64) -> Vec<u8> {
        let mut seq = Vec::with_capacity(fam.consensus.len() + 8);
        for &aa in &fam.consensus {
            let r = rng.uniform();
            if r < fam.indel_rate / 2.0 {
                continue; // deletion
            }
            if r < fam.indel_rate {
                // insertion of a random residue, then the original
                seq.push(AA_BASE + rng.categorical(&self.aa_w) as u8);
            }
            if rng.uniform() < fam.sub_rate {
                seq.push(AA_BASE + rng.categorical(&self.aa_w) as u8);
            } else {
                seq.push(aa);
            }
        }
        if seq.is_empty() {
            seq.push(fam.consensus[0]);
        }
        seq
    }

    /// Sample a sequence from the IID pool (train/valid/test share
    /// families; the split differs by RNG stream).
    pub fn sample_iid(&self, rng: &mut Pcg64) -> (usize, Vec<u8>) {
        let f = rng.below(self.families.len());
        (f, self.sample_from_family(&self.families[f], rng))
    }

    /// Sample a sequence from the held-out (OOD) families.
    pub fn sample_ood(&self, rng: &mut Pcg64) -> (usize, Vec<u8>) {
        let f = rng.below(self.ood_families.len());
        (self.ood_families[f].id, self.sample_from_family(&self.ood_families[f], rng))
    }

    /// Fixed-length window: BOS + sequence clipped/padded to `l` tokens
    /// (the paper clips single sequences to L=1024; Appendix C.1).
    pub fn window(&self, seq: &[u8], l: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(l);
        out.push(vocab::BOS);
        out.extend(seq.iter().take(l.saturating_sub(2)));
        out.push(vocab::EOS);
        while out.len() < l {
            out.push(vocab::PAD);
        }
        out.truncate(l);
        out
    }

    /// Concatenated long-context stream (Appendix C.1's L=8192 task):
    /// proteins joined by EOS, chopped into non-overlapping windows.
    pub fn concat_stream(&self, l: usize, count: usize, rng: &mut Pcg64) -> Vec<Vec<u8>> {
        let mut windows = Vec::with_capacity(count);
        let mut buf: Vec<u8> = Vec::with_capacity(l * 2);
        while windows.len() < count {
            let (_, seq) = self.sample_iid(rng);
            buf.extend_from_slice(&seq);
            buf.push(vocab::EOS);
            while buf.len() >= l && windows.len() < count {
                windows.push(buf[..l].to_vec());
                buf.drain(..l);
            }
        }
        windows
    }
}

fn sample_length(mu: f64, sigma: f64, lo: usize, hi: usize, rng: &mut Pcg64) -> usize {
    let z = rng.gaussian();
    ((mu + sigma * z).exp() as usize).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::N_AA;

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig { n_families: 10, n_ood_families: 3, ..Default::default() })
    }

    #[test]
    fn family_ids_disjoint() {
        let c = corpus();
        let iid: Vec<usize> = c.families.iter().map(|f| f.id).collect();
        let ood: Vec<usize> = c.ood_families.iter().map(|f| f.id).collect();
        assert!(iid.iter().all(|i| !ood.contains(i)));
    }

    #[test]
    fn sequences_are_aa_tokens() {
        let c = corpus();
        let mut rng = Pcg64::new(1);
        for _ in 0..20 {
            let (_, s) = c.sample_iid(&mut rng);
            assert!(!s.is_empty());
            assert!(s.iter().all(|&t| t >= AA_BASE && (t as usize) < AA_BASE as usize + N_AA));
        }
    }

    #[test]
    fn family_members_similar_but_not_identical() {
        // indels off: positional comparison is only meaningful without
        // alignment shifts (with indels the family signal is still there
        // but needs an aligner to expose)
        let c = Corpus::generate(CorpusConfig {
            n_families: 10,
            indel_rate: 0.0,
            ..Default::default()
        });
        let mut rng = Pcg64::new(2);
        let fam = &c.families[0];
        let a = c.sample_from_family(fam, &mut rng);
        let b = c.sample_from_family(fam, &mut rng);
        // compare against the consensus over the shared prefix length
        let n = a.len().min(fam.consensus.len());
        let matches = (0..n).filter(|&i| a[i] == fam.consensus[i]).count();
        assert!(matches as f64 / n as f64 > 0.5, "family signal should survive noise");
        assert_ne!(a, b, "independent samples should differ");
    }

    #[test]
    fn window_has_bos_eos_pad() {
        let c = corpus();
        let w = c.window(&[10, 11, 12], 8);
        assert_eq!(w.len(), 8);
        assert_eq!(w[0], vocab::BOS);
        assert_eq!(w[4], vocab::EOS);
        assert!(w[5..].iter().all(|&t| t == vocab::PAD));
    }

    #[test]
    fn window_clips_long_sequences() {
        let c = corpus();
        let seq: Vec<u8> = (0..100).map(|_| AA_BASE).collect();
        let w = c.window(&seq, 16);
        assert_eq!(w.len(), 16);
        assert_eq!(w[0], vocab::BOS);
    }

    #[test]
    fn concat_windows_exact_length() {
        let c = corpus();
        let mut rng = Pcg64::new(3);
        let ws = c.concat_stream(128, 5, &mut rng);
        assert_eq!(ws.len(), 5);
        assert!(ws.iter().all(|w| w.len() == 128));
        // concatenated stream must contain separators
        assert!(ws.iter().any(|w| w.contains(&vocab::EOS)));
    }

    #[test]
    fn lengths_roughly_lognormal() {
        let cfg = CorpusConfig::default();
        let c = Corpus::generate(cfg);
        let lens: Vec<usize> = c.families.iter().map(|f| f.consensus.len()).collect();
        let mut sorted = lens.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        assert!(median > 150.0 && median < 550.0, "median {median}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Corpus::generate(CorpusConfig { seed: 9, ..Default::default() });
        let b = Corpus::generate(CorpusConfig { seed: 9, ..Default::default() });
        assert_eq!(a.families[0].consensus, b.families[0].consensus);
    }
}
