//! BLOSUM62 substitution matrix — the reference amino-acid similarity
//! structure Fig. 10 compares trained-attention similarity against
//! (following Vig et al. [50]).

use super::vocab::{aa_token, AA_BASE, N_STANDARD_AA};
use crate::tensor::Mat;

/// Standard one-letter order used by the raw BLOSUM62 table below.
const BLOSUM_ORDER: [char; 20] = [
    'A', 'R', 'N', 'D', 'C', 'Q', 'E', 'G', 'H', 'I', 'L', 'K', 'M', 'F',
    'P', 'S', 'T', 'W', 'Y', 'V',
];

/// BLOSUM62 scores (half-bit units), row-major in BLOSUM_ORDER.
#[rustfmt::skip]
const BLOSUM62: [[i8; 20]; 20] = [
    [ 4,-1,-2,-2, 0,-1,-1, 0,-2,-1,-1,-1,-1,-2,-1, 1, 0,-3,-2, 0],
    [-1, 5, 0,-2,-3, 1, 0,-2, 0,-3,-2, 2,-1,-3,-2,-1,-1,-3,-2,-3],
    [-2, 0, 6, 1,-3, 0, 0, 0, 1,-3,-3, 0,-2,-3,-2, 1, 0,-4,-2,-3],
    [-2,-2, 1, 6,-3, 0, 2,-1,-1,-3,-4,-1,-3,-3,-1, 0,-1,-4,-3,-3],
    [ 0,-3,-3,-3, 9,-3,-4,-3,-3,-1,-1,-3,-1,-2,-3,-1,-1,-2,-2,-1],
    [-1, 1, 0, 0,-3, 5, 2,-2, 0,-3,-2, 1, 0,-3,-1, 0,-1,-2,-1,-2],
    [-1, 0, 0, 2,-4, 2, 5,-2, 0,-3,-3, 1,-2,-3,-1, 0,-1,-3,-2,-2],
    [ 0,-2, 0,-1,-3,-2,-2, 6,-2,-4,-4,-2,-3,-3,-2, 0,-2,-2,-3,-3],
    [-2, 0, 1,-1,-3, 0, 0,-2, 8,-3,-3,-1,-2,-1,-2,-1,-2,-2, 2,-3],
    [-1,-3,-3,-3,-1,-3,-3,-4,-3, 4, 2,-3, 1, 0,-3,-2,-1,-3,-1, 3],
    [-1,-2,-3,-4,-1,-2,-3,-4,-3, 2, 4,-2, 2, 0,-3,-2,-1,-2,-1, 1],
    [-1, 2, 0,-1,-3, 1, 1,-2,-1,-3,-2, 5,-1,-3,-1, 0,-1,-3,-2,-2],
    [-1,-1,-2,-3,-1, 0,-2,-3,-2, 1, 2,-1, 5, 0,-2,-1,-1,-1,-1, 1],
    [-2,-3,-3,-3,-2,-3,-3,-3,-1, 0, 0,-3, 0, 6,-4,-2,-2, 1, 3,-1],
    [-1,-2,-2,-1,-3,-1,-1,-2,-2,-3,-3,-1,-2,-4, 7,-1,-1,-4,-3,-2],
    [ 1,-1, 1, 0,-1, 0, 0, 0,-1,-2,-2, 0,-1,-2,-1, 4, 1,-3,-2,-2],
    [ 0,-1, 0,-1,-1,-1,-1,-2,-2,-1,-1,-1,-1,-2,-1, 1, 5,-2,-2, 0],
    [-3,-3,-4,-4,-2,-2,-3,-2,-2,-3,-2,-3,-1, 1,-4,-3,-2,11, 2,-3],
    [-2,-2,-2,-3,-2,-1,-2,-3, 2,-1,-1,-2,-1, 3,-3,-2,-2, 2, 7,-2],
    [ 0,-3,-3,-3,-1,-2,-2,-3,-3, 3, 1,-2, 1,-1,-2,-2, 0,-3,-2, 4],
];

/// BLOSUM62 as a matrix indexed by *standard-AA index* (token − AA_BASE),
/// min-max normalized to [0, 1] off-diagonal (the "normalized BLOSUM"
/// presentation of Fig. 10).
pub fn normalized_blosum() -> Mat {
    let mut m = Mat::zeros(N_STANDARD_AA, N_STANDARD_AA);
    // map BLOSUM order -> token index order
    let idx: Vec<usize> = BLOSUM_ORDER
        .iter()
        .map(|&c| (aa_token(c).unwrap() - AA_BASE) as usize)
        .collect();
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..20 {
        for j in 0..20 {
            if i != j {
                lo = lo.min(BLOSUM62[i][j] as f32);
                hi = hi.max(BLOSUM62[i][j] as f32);
            }
        }
    }
    for i in 0..20 {
        for j in 0..20 {
            let v = (BLOSUM62[i][j] as f32 - lo) / (hi - lo);
            *m.at_mut(idx[i], idx[j]) = v;
        }
    }
    m
}

/// Pearson correlation between the off-diagonal entries of two AA
/// similarity matrices (the quantitative form of Fig. 10's comparison).
pub fn offdiag_correlation(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..a.rows {
        for j in 0..a.cols {
            if i != j {
                xs.push(a.at(i, j) as f64);
                ys.push(b.at(i, j) as f64);
            }
        }
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(&ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blosum_symmetric() {
        let m = normalized_blosum();
        for i in 0..20 {
            for j in 0..20 {
                assert!((m.at(i, j) - m.at(j, i)).abs() < 1e-6, "asym at {i},{j}");
            }
        }
    }

    #[test]
    fn normalized_range() {
        let m = normalized_blosum();
        for i in 0..20 {
            for j in 0..20 {
                if i != j {
                    assert!(m.at(i, j) >= 0.0 && m.at(i, j) <= 1.0);
                }
            }
        }
    }

    #[test]
    fn known_similar_pairs_score_high() {
        // Fig. 10 calls out (D, E) and (F, Y) as highly similar pairs.
        let m = normalized_blosum();
        let t = |c| (aa_token(c).unwrap() - AA_BASE) as usize;
        let de = m.at(t('D'), t('E'));
        let fy = m.at(t('F'), t('Y'));
        let dw = m.at(t('D'), t('W'));
        assert!(de > dw, "D-E ({de}) should beat D-W ({dw})");
        assert!(fy > dw, "F-Y ({fy}) should beat D-W ({dw})");
    }

    #[test]
    fn correlation_of_matrix_with_itself_is_one() {
        let m = normalized_blosum();
        assert!((offdiag_correlation(&m, &m) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn correlation_with_noise_is_low() {
        let m = normalized_blosum();
        let mut rng = crate::rng::Pcg64::new(0);
        let noise = Mat::from_vec(20, 20, rng.gaussian_vec(400));
        assert!(offdiag_correlation(&m, &noise).abs() < 0.3);
    }
}
