//! Dataset statistics — regenerates Table 1 (length statistics per split)
//! and the Fig. 6 amino-acid histogram.

use super::vocab::{aa_class, token_letter, AA_BASE, N_STANDARD_AA};

/// Length summary statistics in the exact columns of Table 1.
#[derive(Clone, Debug, PartialEq)]
pub struct LengthStats {
    /// number of sequences
    pub count: usize,
    /// shortest length
    pub min: usize,
    /// longest length
    pub max: usize,
    /// mean length
    pub mean: f64,
    /// standard deviation of lengths
    pub std: f64,
    /// median length
    pub median: f64,
}

/// Summarize a length sample in Table 1's columns.
pub fn length_stats(lengths: &[usize]) -> LengthStats {
    assert!(!lengths.is_empty());
    let count = lengths.len();
    let min = *lengths.iter().min().unwrap();
    let max = *lengths.iter().max().unwrap();
    let mean = lengths.iter().map(|&l| l as f64).sum::<f64>() / count as f64;
    let var = lengths.iter().map(|&l| (l as f64 - mean).powi(2)).sum::<f64>() / count as f64;
    let mut sorted = lengths.to_vec();
    sorted.sort_unstable();
    let median = if count % 2 == 0 {
        (sorted[count / 2 - 1] + sorted[count / 2]) as f64 / 2.0
    } else {
        sorted[count / 2] as f64
    };
    LengthStats { count, min, max, mean, std: var.sqrt(), median }
}

impl LengthStats {
    /// A Table-1-style row: Count | Min | Max | Mean | STD | Median.
    pub fn table_row(&self, name: &str) -> String {
        format!(
            "| {:<10} | {:>9} | {:>5} | {:>6} | {:>8.2} | {:>8.2} | {:>8.2} |",
            name, self.count, self.min, self.max, self.mean, self.std, self.median
        )
    }
}

/// Standard-AA frequency histogram (Fig. 6): (letter, class, fraction).
pub fn aa_histogram(freqs: &[f64]) -> Vec<(char, u8, f64)> {
    let total: f64 = (0..N_STANDARD_AA).map(|i| freqs[AA_BASE as usize + i]).sum();
    (0..N_STANDARD_AA)
        .map(|i| {
            let tok = AA_BASE + i as u8;
            let letter = token_letter(tok);
            (letter, aa_class(letter), freqs[tok as usize] / total.max(1.0))
        })
        .collect()
}

/// ASCII bar chart of the histogram, sorted by frequency (how Fig. 6 is
/// rendered in text form by `xp fig6`).
pub fn render_histogram(hist: &[(char, u8, f64)]) -> String {
    let class_names = ["hydrophobic", "polar", "acidic", "basic", "special"];
    let mut rows: Vec<_> = hist.to_vec();
    rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let mut out = String::new();
    for (letter, class, frac) in rows {
        let bar = "#".repeat((frac * 400.0) as usize);
        out.push_str(&format!(
            "{letter}  {:>5.2}%  {:<12} {bar}\n",
            frac * 100.0,
            class_names[class as usize]
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::generator::{Corpus, CorpusConfig};
    use crate::protein::masking::token_frequencies;
    use crate::rng::Pcg64;

    #[test]
    fn stats_of_known_values() {
        let s = length_stats(&[1, 2, 3, 4, 100]);
        assert_eq!(s.count, 5);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 100);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 22.0).abs() < 1e-9);
    }

    #[test]
    fn median_even_count() {
        let s = length_stats(&[1, 2, 3, 4]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn corpus_stats_resemble_table1_shape() {
        // Scaled-down corpus should reproduce the *shape*: median < mean
        // (right-skewed log-normal), std of the same order as the mean.
        let c = Corpus::generate(CorpusConfig::default());
        let mut rng = Pcg64::new(0);
        let lens: Vec<usize> = (0..2000).map(|_| c.sample_iid(&mut rng).1.len()).collect();
        let s = length_stats(&lens);
        assert!(s.median < s.mean, "log-normal is right-skewed");
        assert!(s.std > 0.3 * s.mean && s.std < 3.0 * s.mean);
    }

    #[test]
    fn histogram_sums_to_one() {
        let c = Corpus::generate(CorpusConfig::default());
        let mut rng = Pcg64::new(1);
        let ws: Vec<Vec<u8>> = (0..200).map(|_| c.window(&c.sample_iid(&mut rng).1, 128)).collect();
        let h = aa_histogram(&token_frequencies(&ws));
        let total: f64 = h.iter().map(|(_, _, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // leucine should be among the most frequent (TrEMBL empirical)
        let leu = h.iter().find(|(c, _, _)| *c == 'L').unwrap().2;
        let trp = h.iter().find(|(c, _, _)| *c == 'W').unwrap().2;
        assert!(leu > trp);
    }

    #[test]
    fn render_contains_all_letters() {
        let c = Corpus::generate(CorpusConfig::default());
        let mut rng = Pcg64::new(2);
        let ws: Vec<Vec<u8>> = (0..50).map(|_| c.window(&c.sample_iid(&mut rng).1, 128)).collect();
        let h = aa_histogram(&token_frequencies(&ws));
        let txt = render_histogram(&h);
        for ch in ['A', 'L', 'W', 'Y'] {
            assert!(txt.contains(ch), "missing {ch} in histogram");
        }
    }
}
