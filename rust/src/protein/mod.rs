//! Protein data substrate: vocabulary, synthetic Pfam-style corpus,
//! masking/next-token task construction, dataset statistics (Table 1,
//! Fig. 6) and the BLOSUM reference for Fig. 10.

pub mod blosum;
pub mod generator;
pub mod masking;
pub mod stats;
pub mod vocab;

pub use generator::{Corpus, CorpusConfig, Family};
pub use masking::{empirical_baseline, lm_batch, mlm_batch, token_frequencies, Batch, MaskPolicy};
pub use stats::{aa_histogram, length_stats, LengthStats};
