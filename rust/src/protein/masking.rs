//! Task construction: BERT-style masking for the bidirectional MLM and
//! shifted next-token targets for the unidirectional LM (Appendix C.3's
//! two evaluation protocols).

use crate::rng::Pcg64;

use super::vocab::{self, AA_BASE, MASK, N_AA, PAD};

/// A ready-to-execute batch: row-major (b, l) i32 tokens/targets and f32
/// weights (1.0 where the loss counts).
#[derive(Clone, Debug)]
pub struct Batch {
    /// batch size
    pub b: usize,
    /// sequence length
    pub l: usize,
    /// input token ids, row-major (b, l)
    pub tokens: Vec<i32>,
    /// prediction targets, row-major (b, l)
    pub targets: Vec<i32>,
    /// loss weights (1.0 where the loss counts)
    pub weights: Vec<f32>,
}

impl Batch {
    /// All-PAD batch of shape (b, l).
    pub fn new(b: usize, l: usize) -> Self {
        Batch {
            b,
            l,
            tokens: vec![PAD as i32; b * l],
            targets: vec![PAD as i32; b * l],
            weights: vec![0.0; b * l],
        }
    }

    /// Fraction of positions that contribute to the loss.
    pub fn masked_fraction(&self) -> f64 {
        let nz = self.weights.iter().filter(|&&w| w > 0.0).count();
        nz as f64 / self.weights.len() as f64
    }
}

/// Masking hyperparameters — the paper's protocol: "mask each token with
/// 15% probability", BERT's 80/10/10 replacement split.
#[derive(Clone, Copy, Debug)]
pub struct MaskPolicy {
    /// per-token masking probability (paper: 0.15)
    pub rate: f64,
    /// of masked tokens, fraction replaced by MASK (BERT: 0.8)
    pub mask_prob: f64,
    /// of masked tokens, fraction replaced by a random residue (0.1)
    pub random_prob: f64,
}

impl Default for MaskPolicy {
    fn default() -> Self {
        MaskPolicy { rate: 0.15, mask_prob: 0.8, random_prob: 0.1 }
    }
}

/// Build a bidirectional-MLM batch from fixed-length windows.
pub fn mlm_batch(windows: &[Vec<u8>], l: usize, policy: MaskPolicy, rng: &mut Pcg64) -> Batch {
    let b = windows.len();
    let mut batch = Batch::new(b, l);
    for (row, win) in windows.iter().enumerate() {
        assert_eq!(win.len(), l, "window length mismatch");
        for (col, &tok) in win.iter().enumerate() {
            let idx = row * l + col;
            batch.targets[idx] = tok as i32;
            let is_aa = tok >= AA_BASE;
            if is_aa && rng.uniform() < policy.rate {
                batch.weights[idx] = 1.0;
                let r = rng.uniform();
                batch.tokens[idx] = if r < policy.mask_prob {
                    MASK as i32
                } else if r < policy.mask_prob + policy.random_prob {
                    (AA_BASE + rng.below(N_AA) as u8) as i32
                } else {
                    tok as i32 // keep
                };
            } else {
                batch.tokens[idx] = tok as i32;
            }
        }
    }
    batch
}

/// Build a unidirectional (next-token) batch: target[i] = token[i+1],
/// weights 0 on padding and on the final position.
pub fn lm_batch(windows: &[Vec<u8>], l: usize) -> Batch {
    let b = windows.len();
    let mut batch = Batch::new(b, l);
    for (row, win) in windows.iter().enumerate() {
        assert_eq!(win.len(), l);
        for col in 0..l {
            let idx = row * l + col;
            batch.tokens[idx] = win[col] as i32;
            if col + 1 < l {
                batch.targets[idx] = win[col + 1] as i32;
                let next_is_real = win[col + 1] != PAD;
                let cur_is_real = win[col] != PAD;
                batch.weights[idx] = if next_is_real && cur_is_real { 1.0 } else { 0.0 };
            }
        }
    }
    batch
}

/// The empirical baseline of Appendix C.2: predict every masked token
/// from the training-set frequency distribution. Returns (accuracy,
/// perplexity) over the batch's weighted positions.
pub fn empirical_baseline(batch: &Batch, freqs: &[f64]) -> (f64, f64) {
    // freqs indexed by token id, normalized internally
    let total: f64 = freqs.iter().sum();
    let argmax = freqs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let mut correct = 0.0;
    let mut nll = 0.0;
    let mut n = 0.0;
    for i in 0..batch.targets.len() {
        if batch.weights[i] > 0.0 {
            let t = batch.targets[i] as usize;
            let p = (freqs.get(t).copied().unwrap_or(0.0) / total).max(1e-12);
            nll -= p.ln();
            if t == argmax {
                correct += 1.0;
            }
            n += 1.0;
        }
    }
    if n == 0.0 {
        return (0.0, f64::INFINITY);
    }
    (correct / n, (nll / n).exp())
}

/// Training-set token frequencies over the full vocab (for the empirical
/// baseline and the Fig. 6 histogram).
pub fn token_frequencies(windows: &[Vec<u8>]) -> Vec<f64> {
    let mut f = vec![0.0f64; vocab::VOCAB_SIZE];
    for w in windows {
        for &t in w {
            f[t as usize] += 1.0;
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::generator::{Corpus, CorpusConfig};

    fn windows(n: usize, l: usize) -> Vec<Vec<u8>> {
        let c = Corpus::generate(CorpusConfig { n_families: 5, ..Default::default() });
        let mut rng = Pcg64::new(7);
        (0..n).map(|_| {
            let (_, s) = c.sample_iid(&mut rng);
            c.window(&s, l)
        }).collect()
    }

    #[test]
    fn mlm_masks_about_15_percent_of_aas() {
        let ws = windows(16, 128);
        let mut rng = Pcg64::new(0);
        let b = mlm_batch(&ws, 128, MaskPolicy::default(), &mut rng);
        // fraction relative to AA positions, not all positions
        let n_aa: usize = ws.iter().flatten().filter(|&&t| t >= AA_BASE).count();
        let n_masked = b.weights.iter().filter(|&&w| w > 0.0).count();
        let frac = n_masked as f64 / n_aa as f64;
        assert!((frac - 0.15).abs() < 0.04, "masked fraction {frac}");
    }

    #[test]
    fn mlm_targets_are_original_tokens() {
        let ws = windows(4, 64);
        let mut rng = Pcg64::new(1);
        let b = mlm_batch(&ws, 64, MaskPolicy::default(), &mut rng);
        for (row, w) in ws.iter().enumerate() {
            for col in 0..64 {
                assert_eq!(b.targets[row * 64 + col], w[col] as i32);
            }
        }
    }

    #[test]
    fn mlm_unmasked_positions_unchanged() {
        let ws = windows(4, 64);
        let mut rng = Pcg64::new(2);
        let b = mlm_batch(&ws, 64, MaskPolicy::default(), &mut rng);
        for (row, w) in ws.iter().enumerate() {
            for col in 0..64 {
                let i = row * 64 + col;
                if b.weights[i] == 0.0 {
                    assert_eq!(b.tokens[i], w[col] as i32);
                }
            }
        }
    }

    #[test]
    fn mlm_never_masks_specials() {
        let ws = windows(8, 64);
        let mut rng = Pcg64::new(3);
        let b = mlm_batch(&ws, 64, MaskPolicy::default(), &mut rng);
        for (row, w) in ws.iter().enumerate() {
            for col in 0..64 {
                if w[col] < AA_BASE {
                    assert_eq!(b.weights[row * 64 + col], 0.0);
                }
            }
        }
    }

    #[test]
    fn lm_targets_shifted() {
        let ws = windows(2, 32);
        let b = lm_batch(&ws, 32);
        for (row, w) in ws.iter().enumerate() {
            for col in 0..31 {
                assert_eq!(b.targets[row * 32 + col], w[col + 1] as i32);
            }
            assert_eq!(b.weights[row * 32 + 31], 0.0, "last position has no target");
        }
    }

    #[test]
    fn lm_padding_unweighted() {
        let c = Corpus::generate(CorpusConfig::default());
        let w = c.window(&[10, 11], 16); // mostly padding
        let b = lm_batch(&[w.clone()], 16);
        for col in 0..16 {
            if w[col] == PAD {
                assert_eq!(b.weights[col], 0.0);
            }
        }
    }

    #[test]
    fn empirical_baseline_beats_uniform_on_skewed_data() {
        let ws = windows(32, 128);
        let freqs = token_frequencies(&ws);
        let mut rng = Pcg64::new(4);
        let b = mlm_batch(&ws, 128, MaskPolicy::default(), &mut rng);
        let (acc, ppl) = empirical_baseline(&b, &freqs);
        // paper: ~9.9% accuracy, ~17.8 perplexity for the empirical baseline
        assert!(acc > 0.04 && acc < 0.25, "acc {acc}");
        assert!(ppl > 5.0 && ppl < 30.0, "ppl {ppl}");
    }

    #[test]
    fn frequencies_count_all_tokens() {
        let ws = windows(4, 32);
        let f = token_frequencies(&ws);
        let total: f64 = f.iter().sum();
        assert_eq!(total as usize, 4 * 32);
    }
}
