//! Deterministic random number generation substrate.
//!
//! The registry image has no `rand` crate, so we carry our own: a PCG64
//! (permuted congruential generator, O'Neill 2014) with Box–Muller
//! Gaussians. Every stochastic component in the library (random features,
//! synthetic corpus, masking, LSH rotations) draws from this, so entire
//! experiments are reproducible from a single `u64` seed.

/// FNV-1a offset basis — seed for [`fnv1a64`] / [`fnv1a64_extend`].
pub const FNV1A64_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold more bytes into a running 64-bit FNV-1a hash. The persistence
/// layer uses this both for snapshot file names and for the model
/// weight digest, so the algorithm lives once, here, at the bottom of
/// the dependency graph.
pub fn fnv1a64_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One-shot 64-bit FNV-1a hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_extend(FNV1A64_SEED, bytes)
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed a generator (full 128-bit state scrambled from the u64).
    pub fn new(seed: u64) -> Self {
        // splitmix-style scrambling to fill 128-bit state from a u64 seed
        let mut s = Self {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        s.next_u64();
        s.state = s.state.wrapping_add((seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        s.next_u64();
        s
    }

    /// Derive an independent stream (stable: depends only on seed + tag).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xd6e8_feb8_6659_fd93))
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals as f32.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() as f32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// k distinct indices out of n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(9);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac {frac}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Pcg64::new(17);
        let ks = r.choose_k(20, 10);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(ks.iter().all(|&i| i < 20));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Pcg64::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(1); // second fork advances base -> differs
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
