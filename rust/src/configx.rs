//! Typed configuration system for the launcher.
//!
//! Configs load from JSON files (`--config path.json`) with CLI
//! `key=value` overrides, mirroring what gin did for the paper's
//! published training setup. Defaults reproduce the scaled-down "base"
//! protein-MLM run from DESIGN.md.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonx::Json;
use crate::protein::CorpusConfig;

/// Training-run configuration (paper Appendix B.1 defaults where they
/// transfer to this scale).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// artifact tag, e.g. "base_perf_relu_bid"
    pub artifact: String,
    /// optimizer steps to run
    pub steps: usize,
    /// validation cadence in steps (0 = never)
    pub eval_every: usize,
    /// batches per evaluation
    pub eval_batches: usize,
    /// logging cadence in steps
    pub log_every: usize,
    /// rng seed for data/masking
    pub seed: u64,
    /// resample FAVOR features every N steps (0 = never) — the paper's
    /// feature-redrawing strategy, Sec. 4.2
    pub resample_every: usize,
    /// path to save/load the training checkpoint
    pub checkpoint: Option<String>,
    /// SLiM chunk length L_c in tokens (0 = chunked training off).
    /// With `synthetic` this trains a native stack chunk-by-chunk; with
    /// an artifact it reroutes `TrainState` through the native path
    pub chunked: usize,
    /// train a fully native synthetic Performer stack (no artifacts,
    /// no PJRT) — the SLiM path's self-contained mode
    pub synthetic: bool,
    /// sequence length per row for synthetic native training
    pub seq_len: usize,
    /// batch size for synthetic native training
    pub batch: usize,
    /// Adam learning rate for the native chunked trainer
    pub lr: f64,
    /// kernel redraw period in tokens for the synthetic stack (0 =
    /// never) — chunk boundaries align to it automatically
    pub redraw: usize,
    /// carried/checkpointed stream-state precision: "f32" | "bf16"
    pub precision: String,
    /// run a second full-sequence (chunk_len = 0) trainer from the same
    /// init and data, and fail unless per-step losses agree — the
    /// chunked-vs-oracle smoke check CI runs
    pub check_full: bool,
    /// synthetic corpus parameters
    pub corpus: CorpusConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "base_perf_relu_bid".into(),
            steps: 200,
            eval_every: 50,
            eval_batches: 4,
            log_every: 10,
            seed: 0,
            resample_every: 0,
            checkpoint: None,
            chunked: 0,
            synthetic: false,
            seq_len: 128,
            batch: 4,
            lr: 1e-3,
            redraw: 0,
            precision: "f32".into(),
            check_full: false,
            corpus: CorpusConfig::default(),
        }
    }
}

/// Serving configuration for the coordinator.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact tag to serve
    pub artifact: String,
    /// max requests fused into one executable call (≤ compiled batch)
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub max_wait_ms: u64,
    /// serving worker threads per pool
    pub workers: usize,
    /// rng seed for the demo request load
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact: "base_perf_relu_bid".into(),
            max_batch: 8,
            max_wait_ms: 5,
            workers: 1,
            seed: 0,
        }
    }
}

fn apply_corpus_key(c: &mut CorpusConfig, key: &str, val: &Json) -> Result<bool> {
    match key {
        "n_families" => c.n_families = val.as_usize()?,
        "n_ood_families" => c.n_ood_families = val.as_usize()?,
        "sub_rate" => c.sub_rate = val.as_f64()?,
        "indel_rate" => c.indel_rate = val.as_f64()?,
        "corpus_seed" => c.seed = val.as_f64()? as u64,
        _ => return Ok(false),
    }
    Ok(true)
}

impl TrainConfig {
    /// Apply one `key=value` override (JSON-typed value).
    pub fn apply_key(&mut self, key: &str, val: &Json) -> Result<()> {
        match key {
            "artifact" => self.artifact = val.as_str()?.to_string(),
            "steps" => self.steps = val.as_usize()?,
            "eval_every" => self.eval_every = val.as_usize()?,
            "eval_batches" => self.eval_batches = val.as_usize()?,
            "log_every" => self.log_every = val.as_usize()?,
            "seed" => self.seed = val.as_f64()? as u64,
            "resample_every" => self.resample_every = val.as_usize()?,
            "checkpoint" => self.checkpoint = Some(val.as_str()?.to_string()),
            "chunked" => self.chunked = val.as_usize()?,
            "synthetic" => self.synthetic = val.as_usize()? != 0,
            "seq_len" => self.seq_len = val.as_usize()?,
            "batch" => self.batch = val.as_usize()?,
            "lr" => self.lr = val.as_f64()?,
            "redraw" => self.redraw = val.as_usize()?,
            "precision" => self.precision = val.as_str()?.to_string(),
            "check_full" => self.check_full = val.as_usize()? != 0,
            _ => {
                if !apply_corpus_key(&mut self.corpus, key, val)? {
                    bail!("unknown train config key '{key}'");
                }
            }
        }
        Ok(())
    }

    /// Defaults, then a JSON config file (if given), then CLI overrides.
    pub fn from_sources(file: Option<&Path>, overrides: &[String]) -> Result<TrainConfig> {
        let mut cfg = TrainConfig::default();
        if let Some(path) = file {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            let j = Json::parse(&text)?;
            if let Json::Obj(m) = &j {
                for (k, v) in m {
                    cfg.apply_key(k, v)?;
                }
            } else {
                bail!("config file must be a JSON object");
            }
        }
        for ov in overrides {
            let (k, v) = parse_override(ov)?;
            cfg.apply_key(&k, &v)?;
        }
        Ok(cfg)
    }
}

impl ServeConfig {
    /// Apply one `key=value` override (JSON-typed value).
    pub fn apply_key(&mut self, key: &str, val: &Json) -> Result<()> {
        match key {
            "artifact" => self.artifact = val.as_str()?.to_string(),
            "max_batch" => self.max_batch = val.as_usize()?,
            "max_wait_ms" => self.max_wait_ms = val.as_f64()? as u64,
            "workers" => self.workers = val.as_usize()?,
            "seed" => self.seed = val.as_f64()? as u64,
            _ => bail!("unknown serve config key '{key}'"),
        }
        Ok(())
    }

    /// Defaults, then a JSON config file (if given), then CLI overrides.
    pub fn from_sources(file: Option<&Path>, overrides: &[String]) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(path) = file {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            if let Json::Obj(m) = Json::parse(&text)? {
                for (k, v) in &m {
                    cfg.apply_key(k, v)?;
                }
            }
        }
        for ov in overrides {
            let (k, v) = parse_override(ov)?;
            cfg.apply_key(&k, &v)?;
        }
        Ok(cfg)
    }
}

/// Parse `key=value` where value is JSON if it parses, else a string.
pub fn parse_override(s: &str) -> Result<(String, Json)> {
    let (k, v) = s
        .split_once('=')
        .with_context(|| format!("override '{s}' must be key=value"))?;
    let val = Json::parse(v).unwrap_or_else(|_| Json::Str(v.to_string()));
    Ok((k.to_string(), val))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = TrainConfig::default();
        assert!(c.steps > 0);
        assert_eq!(c.artifact, "base_perf_relu_bid");
    }

    #[test]
    fn overrides_apply() {
        let cfg = TrainConfig::from_sources(
            None,
            &["steps=500".into(), "artifact=tiny_relu_bid".into(), "sub_rate=0.3".into()],
        )
        .unwrap();
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.artifact, "tiny_relu_bid");
        assert!((cfg.corpus.sub_rate - 0.3).abs() < 1e-12);
    }

    #[test]
    fn chunked_keys_parse() {
        let cfg = TrainConfig::from_sources(
            None,
            &[
                "synthetic=1".into(),
                "chunked=24".into(),
                "seq_len=96".into(),
                "batch=2".into(),
                "lr=0.002".into(),
                "redraw=32".into(),
                "precision=bf16".into(),
                "check_full=1".into(),
            ],
        )
        .unwrap();
        assert!(cfg.synthetic);
        assert_eq!(cfg.chunked, 24);
        assert_eq!(cfg.seq_len, 96);
        assert_eq!(cfg.batch, 2);
        assert!((cfg.lr - 0.002).abs() < 1e-12);
        assert_eq!(cfg.redraw, 32);
        assert_eq!(cfg.precision, "bf16");
        assert!(cfg.check_full);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(TrainConfig::from_sources(None, &["bogus=1".into()]).is_err());
    }

    #[test]
    fn file_then_override_precedence() {
        let dir = std::env::temp_dir();
        let p = dir.join("performer_cfg_test.json");
        std::fs::write(&p, r#"{"steps": 100, "seed": 7}"#).unwrap();
        let cfg = TrainConfig::from_sources(Some(&p), &["steps=250".into()]).unwrap();
        assert_eq!(cfg.steps, 250); // CLI wins
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn serve_config_parses() {
        let cfg =
            ServeConfig::from_sources(None, &["max_batch=16".into(), "max_wait_ms=2".into()])
                .unwrap();
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.max_wait_ms, 2);
    }

    #[test]
    fn string_values_without_quotes() {
        let (k, v) = parse_override("artifact=base_lsh_bid").unwrap();
        assert_eq!(k, "artifact");
        assert_eq!(v.as_str().unwrap(), "base_lsh_bid");
    }
}
