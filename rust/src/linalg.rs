//! Linear-algebra substrate for orthogonal random features (Sec. 2.4).
//!
//! Three ORF mechanisms from the paper, plus the iid baseline:
//!   * R-ORF — Gaussian orthogonal matrices via modified Gram–Schmidt,
//!     rows rescaled by chi_d norms so marginals stay N(0, I) [56].
//!   * H-ORF — SORF-style products H·D of normalized Walsh–Hadamard
//!     transforms and random sign diagonals (O(M log d) apply cost) [13].
//!   * G-ORF — products of random Givens rotations [11].

use crate::rng::Pcg64;
use crate::tensor::Mat;

/// Orthogonalize the rows of `a` in place (modified Gram–Schmidt).
/// Returns false if a row collapses to ~zero (numerically dependent).
pub fn gram_schmidt_rows(a: &mut Mat) -> bool {
    let (n, d) = (a.rows, a.cols);
    assert!(n <= d, "cannot orthonormalize {n} rows in R^{d}");
    for i in 0..n {
        let orig_norm = a.row(i).iter().map(|v| v * v).sum::<f32>().sqrt();
        for j in 0..i {
            let proj = crate::tensor::dot(a.row(i), a.row(j));
            let rowj = a.row(j).to_vec();
            for (v, w) in a.row_mut(i).iter_mut().zip(&rowj) {
                *v -= proj * w;
            }
        }
        let norm = a.row(i).iter().map(|v| v * v).sum::<f32>().sqrt();
        // relative tolerance: detects numerically dependent rows
        if norm < 1e-5 * (orig_norm + 1e-30) {
            return false;
        }
        for v in a.row_mut(i) {
            *v /= norm;
        }
    }
    true
}

/// In-place fast Walsh–Hadamard transform over a power-of-two slice,
/// normalized so the implied matrix is orthonormal.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT needs power-of-two length");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= scale;
    }
}

/// Apply a random Givens rotation sequence (indices + angles) to rows.
#[derive(Clone, Debug)]
pub struct GivensSeq {
    /// (i, j, theta) rotations, applied in order
    pub rotations: Vec<(usize, usize, f32)>, // (i, j, theta)
    /// dimensionality the rotations act on
    pub dim: usize,
}

impl GivensSeq {
    /// Sample `count` random rotations in `dim` dimensions.
    pub fn random(dim: usize, count: usize, rng: &mut Pcg64) -> Self {
        let mut rotations = Vec::with_capacity(count);
        for _ in 0..count {
            let i = rng.below(dim);
            let mut j = rng.below(dim - 1);
            if j >= i {
                j += 1;
            }
            rotations.push((i, j, rng.uniform_in(0.0, std::f64::consts::TAU) as f32));
        }
        GivensSeq { rotations, dim }
    }

    /// Dense matrix form (product of all rotations applied to I).
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::eye(self.dim);
        for &(i, j, theta) in &self.rotations {
            let (c, s) = (theta.cos(), theta.sin());
            for col in 0..self.dim {
                let (vi, vj) = (m.at(i, col), m.at(j, col));
                *m.at_mut(i, col) = c * vi - s * vj;
                *m.at_mut(j, col) = s * vi + c * vj;
            }
        }
        m
    }
}

/// Which projection-matrix mechanism to use for FAVOR features.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrfMechanism {
    /// unstructured i.i.d. Gaussian rows
    Iid,
    /// exactly orthogonal blocks via Gram–Schmidt (R-ORF)
    Regular,  // R-ORF
    /// Hadamard-diagonal block products (H-ORF)
    Hadamard, // H-ORF
    /// random Givens-rotation products (G-ORF)
    Givens,   // G-ORF
}

impl OrfMechanism {
    /// Every mechanism, in the order surfaced by error messages.
    pub const ALL: [OrfMechanism; 4] = [
        OrfMechanism::Iid,
        OrfMechanism::Regular,
        OrfMechanism::Hadamard,
        OrfMechanism::Givens,
    ];

    /// Canonical name (CLI/report spelling).
    pub fn name(&self) -> &'static str {
        match self {
            OrfMechanism::Iid => "iid",
            OrfMechanism::Regular => "r-orf",
            OrfMechanism::Hadamard => "h-orf",
            OrfMechanism::Givens => "g-orf",
        }
    }

    /// Like [`Self::parse`], but an unknown mechanism names every valid
    /// one — same contract as `FeatureKind::parse_or_err`.
    pub fn parse_or_err(s: &str) -> anyhow::Result<Self> {
        Self::parse(s).ok_or_else(|| {
            let valid: Vec<&str> = Self::ALL.iter().map(OrfMechanism::name).collect();
            anyhow::anyhow!("unknown ORF mechanism '{s}' (valid: {})", valid.join(", "))
        })
    }

    /// Parse a mechanism name; None if unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "iid" => OrfMechanism::Iid,
            "r-orf" | "regular" => OrfMechanism::Regular,
            "h-orf" | "hadamard" => OrfMechanism::Hadamard,
            "g-orf" | "givens" => OrfMechanism::Givens,
            _ => return None,
        })
    }
}

/// One orthogonal d×d block for the given mechanism.
fn orthogonal_block(d: usize, mech: OrfMechanism, rng: &mut Pcg64) -> Mat {
    match mech {
        OrfMechanism::Iid => unreachable!("iid has no orthogonal block"),
        OrfMechanism::Regular => loop {
            let mut g = Mat::from_vec(d, d, rng.gaussian_vec(d * d));
            if gram_schmidt_rows(&mut g) {
                return g;
            }
        },
        OrfMechanism::Hadamard => {
            assert!(d.is_power_of_two(), "H-ORF needs power-of-two d, got {d}");
            // (HD)^3: three rounds of sign-flip + Hadamard
            let mut m = Mat::eye(d);
            for _ in 0..3 {
                let signs: Vec<f32> = (0..d)
                    .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
                    .collect();
                for col in 0..d {
                    let mut column: Vec<f32> =
                        (0..d).map(|r| m.at(r, col) * signs[r]).collect();
                    fwht(&mut column);
                    for r in 0..d {
                        *m.at_mut(r, col) = column[r];
                    }
                }
            }
            m
        }
        OrfMechanism::Givens => {
            let count = d * (usize::BITS - d.leading_zeros()) as usize; // d log2 d
            GivensSeq::random(d, count.max(d), rng).to_mat()
        }
    }
}

/// W ∈ R^{M×d} with rows marginally ~ N(0, sigma² I_d). Orthogonal
/// mechanisms draw independent d×d blocks (block-local orthogonality, as
/// in [56]); `chi_norms` rescales rows by chi_d-distributed norms so row
/// marginals match the iid Gaussian case exactly.
pub fn projection_matrix(
    m: usize,
    d: usize,
    mech: OrfMechanism,
    sigma: f32,
    chi_norms: bool,
    rng: &mut Pcg64,
) -> Mat {
    let mut w = Mat::zeros(m, d);
    match mech {
        OrfMechanism::Iid => {
            w.data = rng.gaussian_vec(m * d);
        }
        _ => {
            let mut filled = 0;
            while filled < m {
                let block = orthogonal_block(d, mech, rng);
                let take = (m - filled).min(d);
                for r in 0..take {
                    let norm = if chi_norms {
                        rng.gaussian_vec(d).iter().map(|v| v * v).sum::<f32>().sqrt()
                    } else {
                        (d as f32).sqrt()
                    };
                    for c in 0..d {
                        *w.at_mut(filled + r, c) = block.at(r, c) * norm;
                    }
                }
                filled += take;
            }
        }
    }
    w.scale(sigma);
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_rows_orthogonal(w: &Mat, tol: f32) {
        for i in 0..w.rows.min(w.cols) {
            for j in 0..i {
                let d = crate::tensor::dot(w.row(i), w.row(j));
                let ni = crate::tensor::dot(w.row(i), w.row(i)).sqrt();
                let nj = crate::tensor::dot(w.row(j), w.row(j)).sqrt();
                assert!(
                    (d / (ni * nj)).abs() < tol,
                    "rows {i},{j} not orthogonal: cos={}",
                    d / (ni * nj)
                );
            }
        }
    }

    #[test]
    fn gram_schmidt_orthonormalizes() {
        let mut rng = Pcg64::new(0);
        let mut a = Mat::from_vec(6, 8, rng.gaussian_vec(48));
        assert!(gram_schmidt_rows(&mut a));
        assert_rows_orthogonal(&a, 1e-5);
        for i in 0..6 {
            let n = crate::tensor::dot(a.row(i), a.row(i));
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_schmidt_detects_dependence() {
        let mut a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 2.0, 4.0, 6.0]);
        assert!(!gram_schmidt_rows(&mut a));
    }

    #[test]
    fn fwht_is_orthonormal_involution() {
        let mut rng = Pcg64::new(1);
        let x = rng.gaussian_vec(16);
        let mut y = x.clone();
        fwht(&mut y);
        // norm preserved
        let nx: f32 = x.iter().map(|v| v * v).sum();
        let ny: f32 = y.iter().map(|v| v * v).sum();
        assert!((nx - ny).abs() / nx < 1e-5);
        // H^2 = I (normalized Hadamard is an involution)
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn givens_product_is_orthogonal() {
        let mut rng = Pcg64::new(2);
        let g = GivensSeq::random(8, 24, &mut rng).to_mat();
        assert_rows_orthogonal(&g, 1e-5);
    }

    #[test]
    fn rorf_blocks_orthogonal() {
        let mut rng = Pcg64::new(3);
        let w = projection_matrix(8, 8, OrfMechanism::Regular, 1.0, false, &mut rng);
        assert_rows_orthogonal(&w, 1e-4);
    }

    #[test]
    fn horf_blocks_orthogonal() {
        let mut rng = Pcg64::new(4);
        let w = projection_matrix(8, 8, OrfMechanism::Hadamard, 1.0, false, &mut rng);
        assert_rows_orthogonal(&w, 1e-4);
    }

    #[test]
    fn gorf_blocks_orthogonal() {
        let mut rng = Pcg64::new(5);
        let w = projection_matrix(8, 8, OrfMechanism::Givens, 1.0, false, &mut rng);
        assert_rows_orthogonal(&w, 1e-4);
    }

    #[test]
    fn iid_marginals_gaussian() {
        let mut rng = Pcg64::new(6);
        let w = projection_matrix(256, 16, OrfMechanism::Iid, 2.0, true, &mut rng);
        let mean: f32 = w.data.iter().sum::<f32>() / w.data.len() as f32;
        let var: f32 =
            w.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / w.data.len() as f32;
        assert!(mean.abs() < 0.1);
        assert!((var - 4.0).abs() < 0.4, "var {var}");
    }

    #[test]
    fn chi_norm_rows_match_gaussian_row_norms() {
        // E[||row||^2] = sigma^2 * d for both iid and chi-rescaled ORF rows
        let mut rng = Pcg64::new(7);
        let d = 16;
        let w = projection_matrix(512, d, OrfMechanism::Regular, 1.0, true, &mut rng);
        let mean_sq: f32 = (0..w.rows)
            .map(|i| crate::tensor::dot(w.row(i), w.row(i)))
            .sum::<f32>()
            / w.rows as f32;
        assert!((mean_sq - d as f32).abs() < 2.0, "mean row norm^2 {mean_sq}");
    }

    #[test]
    fn blocks_cover_m_greater_than_d() {
        let mut rng = Pcg64::new(8);
        let w = projection_matrix(20, 8, OrfMechanism::Regular, 1.0, true, &mut rng);
        assert_eq!((w.rows, w.cols), (20, 8));
        // rows within each block of 8 are orthogonal
        for blk in 0..2 {
            for i in 0..8 {
                for j in 0..i {
                    let a = blk * 8 + i;
                    let b = blk * 8 + j;
                    let cosv = crate::tensor::dot(w.row(a), w.row(b))
                        / (crate::tensor::dot(w.row(a), w.row(a)).sqrt()
                            * crate::tensor::dot(w.row(b), w.row(b)).sqrt());
                    assert!(cosv.abs() < 1e-4);
                }
            }
        }
    }
}
