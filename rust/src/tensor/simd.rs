//! Explicit SIMD kernels for the dense core, behind the `simd` cargo
//! feature with runtime dispatch.
//!
//! Dispatch strategy: [`active_level`] resolves once per process to the
//! widest instruction set the host supports (AVX2 → SSE2 on x86_64,
//! NEON on aarch64, scalar otherwise or when the feature is off), with a
//! `PERFORMER_SIMD` env override (`off`/`scalar`/`sse2`/`avx2`/`neon`)
//! and an in-process [`set_level_override`] hook the benches use to
//! measure SIMD-on vs SIMD-off on the same machine. Every kernel also
//! has an explicit-level `_at` entry point so property tests can compare
//! levels race-free regardless of the global setting.
//!
//! Oracle discipline (what the prop tests pin):
//!
//! * **axpy is bitwise-identical across levels.** The vector body uses a
//!   separate multiply and add (never FMA), so each lane computes
//!   `y[i] + alpha * x[i]` with exactly the two IEEE roundings the
//!   scalar loop performs. Since every matmul path (`matmul_into`,
//!   `matmul_block`, `matmul_at_b`, the streaming state advance) is
//!   axpy-based with the k-accumulation order preserved, vectorizing
//!   them changes no bits.
//! * **dot re-associates** (per-lane partial sums + a horizontal
//!   reduction), so it is held to a ULP-scaled tolerance against the
//!   serial kernel, not bitwise equality.
//! * **exp/softmax paths** use a Cephes-style degree-5 polynomial
//!   ([`exp_poly`]) on the vector levels; the scalar level keeps libm
//!   `exp` and serves as the tolerance oracle (the polynomial agrees
//!   with libm to ~1 ulp of relative error over the clamped range).
//!   Within one vectorized row the remainder lanes use the *same*
//!   polynomial, so a row is internally consistent and identical inputs
//!   produce identical rows within a build.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Instruction-set level a kernel dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// portable serial kernels — the oracle the SIMD paths are tested
    /// against, and the only level when the `simd` feature is off
    Scalar,
    /// x86_64 128-bit baseline (axpy/dot vectorized; exp stays scalar —
    /// SSE2 has no packed round-to-nearest)
    Sse2,
    /// x86_64 256-bit lanes incl. the vectorized exp polynomial
    Avx2,
    /// aarch64 128-bit lanes incl. the vectorized exp polynomial
    Neon,
}

impl SimdLevel {
    /// Lower-case name (`scalar`/`sse2`/`avx2`/`neon`), as accepted by
    /// the `PERFORMER_SIMD` env override.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    fn code(self) -> u8 {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Sse2 => 2,
            SimdLevel::Avx2 => 3,
            SimdLevel::Neon => 4,
        }
    }

    fn from_code(v: u8) -> Option<SimdLevel> {
        match v {
            1 => Some(SimdLevel::Scalar),
            2 => Some(SimdLevel::Sse2),
            3 => Some(SimdLevel::Avx2),
            4 => Some(SimdLevel::Neon),
            _ => None,
        }
    }
}

/// Whether `level` can actually run on this build + host. Scalar is
/// always supported; the vector levels need the `simd` feature, the
/// matching architecture, and (for AVX2) a runtime CPUID check.
pub fn supported(level: SimdLevel) -> bool {
    match level {
        SimdLevel::Scalar => true,
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => true,
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        SimdLevel::Neon => true,
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// Every level this build + host can run, widest last.
pub fn supported_levels() -> Vec<SimdLevel> {
    [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Neon, SimdLevel::Avx2]
        .into_iter()
        .filter(|&l| supported(l))
        .collect()
}

fn hardware_level() -> SimdLevel {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        // SSE2 is part of the x86_64 baseline, no runtime check needed
        return SimdLevel::Sse2;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        // NEON is part of the aarch64 baseline
        return SimdLevel::Neon;
    }
    #[allow(unreachable_code)]
    SimdLevel::Scalar
}

fn detect() -> SimdLevel {
    if let Ok(v) = std::env::var("PERFORMER_SIMD") {
        let want = match v.to_ascii_lowercase().as_str() {
            "off" | "scalar" => Some(SimdLevel::Scalar),
            "sse2" => Some(SimdLevel::Sse2),
            "avx2" => Some(SimdLevel::Avx2),
            "neon" => Some(SimdLevel::Neon),
            _ => None, // unknown value: fall through to detection
        };
        if let Some(l) = want {
            if supported(l) {
                return l;
            }
        }
    }
    hardware_level()
}

// 0 = no override; else SimdLevel::code()
static OVERRIDE: AtomicU8 = AtomicU8::new(0);
static DETECTED: OnceLock<SimdLevel> = OnceLock::new();

/// The level the argument-free kernel entry points dispatch to: the
/// in-process override if set, else the detected level (env override or
/// hardware probe, cached after first use).
pub fn active_level() -> SimdLevel {
    match SimdLevel::from_code(OVERRIDE.load(Ordering::Relaxed)) {
        Some(l) => l,
        None => *DETECTED.get_or_init(detect),
    }
}

/// Force the dispatch level in-process (benches use this to time the
/// same matmul SIMD-on vs SIMD-off); `None` restores detection. An
/// unsupported request falls back to the detected level. Returns the
/// level now active.
pub fn set_level_override(level: Option<SimdLevel>) -> SimdLevel {
    match level {
        None => OVERRIDE.store(0, Ordering::Relaxed),
        Some(l) => {
            let eff = if supported(l) { l } else { *DETECTED.get_or_init(detect) };
            OVERRIDE.store(eff.code(), Ordering::Relaxed);
        }
    }
    active_level()
}

// ---------------------------------------------------------------------
// axpy — bitwise-identical across levels (mul + add, never FMA)
// ---------------------------------------------------------------------

#[inline]
fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y += alpha * x at an explicit dispatch level. Bitwise-identical to
/// the scalar loop at every level (see the module docs).
#[inline]
pub fn axpy_at(level: SimdLevel, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match level {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe { x86::axpy_sse2(alpha, x, y) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx2 => unsafe { x86::axpy_avx2(alpha, x, y) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        SimdLevel::Neon => unsafe { neon::axpy_neon(alpha, x, y) },
        _ => axpy_scalar(alpha, x, y),
    }
}

// ---------------------------------------------------------------------
// dot — re-associated, held to a ULP-scaled tolerance vs serial
// ---------------------------------------------------------------------

/// Serial 4-accumulator dot product — the tolerance oracle for the
/// vector levels. The unrolled body covers `4 * (n / 4)` elements and
/// the tail loop picks up exactly the remaining `n % 4` (audited +
/// pinned by the boundary-length tests: 0, 1, 3, 4, 5, 7).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Dense dot product at an explicit dispatch level.
#[inline]
pub fn dot_at(level: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match level {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Sse2 => unsafe { x86::dot_sse2(a, b) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx2 => unsafe { x86::dot_avx2(a, b) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        SimdLevel::Neon => unsafe { neon::dot_neon(a, b) },
        _ => dot_scalar(a, b),
    }
}

// ---------------------------------------------------------------------
// exp — Cephes-style degree-5 polynomial, mirrored lane-for-lane
// ---------------------------------------------------------------------

// Clamp range chosen so the exponent-bit reconstruction below never
// leaves the normal range: n = round(x·log2 e) ∈ [-124, 126] and the
// mantissa polynomial lands in [~0.7, ~1.42].
const EXP_HI: f32 = 87.0;
const EXP_LO: f32 = -86.0;
const LOG2EF: f32 = 1.442_695_f32;
const LN2_HI: f32 = 0.693_359_4;
const LN2_LO: f32 = -2.121_944_4e-4;
const EP0: f32 = 1.987_569_2e-4;
const EP1: f32 = 1.398_2e-3;
const EP2: f32 = 8.333_452e-3;
const EP3: f32 = 4.166_579_6e-2;
const EP4: f32 = 1.666_666_6e-1;
const EP5: f32 = 5e-1;

/// The scalar polynomial `exp` the vector levels mirror lane-for-lane
/// (remainder lanes of a vectorized row use this, so a row is
/// internally consistent). Input is clamped to `[-86, 87]`; agrees with
/// libm `exp` to ~1e-7 relative over that range.
#[inline]
pub fn exp_poly(x: f32) -> f32 {
    let x = x.clamp(EXP_LO, EXP_HI);
    // round-ties-even matches the packed round-to-nearest instruction
    let n = (x * LOG2EF).round_ties_even();
    // two-part ln2 subtraction keeps the reduced argument accurate
    let r = x - n * LN2_HI - n * LN2_LO;
    let z = r * r;
    let mut y = EP0;
    y = y * r + EP1;
    y = y * r + EP2;
    y = y * r + EP3;
    y = y * r + EP4;
    y = y * r + EP5;
    y = (y * z + r) + 1.0;
    // scale by 2^n by adding n to the exponent bits
    f32::from_bits((y.to_bits() as i32 + ((n as i32) << 23)) as u32)
}

// ---------------------------------------------------------------------
// fused exp row kernel — scale * exp(min(v - sub, clamp)) + eps
// ---------------------------------------------------------------------

#[inline]
fn fused_exp_scale_scalar(row: &mut [f32], sub: f32, clamp: f32, scale: f32, eps: f32) {
    // libm exp: bitwise-identical to the pre-SIMD FAVOR+ positive map,
    // and the tolerance oracle for the vector levels
    for v in row.iter_mut() {
        let t = (*v - sub).min(clamp);
        *v = scale * t.exp() + eps;
    }
}

#[cfg(any(
    all(feature = "simd", target_arch = "x86_64"),
    all(feature = "simd", target_arch = "aarch64")
))]
#[inline]
fn fused_exp_scale_poly_tail(row: &mut [f32], sub: f32, clamp: f32, scale: f32, eps: f32) {
    for v in row.iter_mut() {
        let t = (*v - sub).min(clamp);
        *v = scale * exp_poly(t) + eps;
    }
}

/// In place over a row: `v ← scale * exp(min(v - sub, clamp)) + eps`, at
/// an explicit dispatch level — the FAVOR+ positive map's inner loop
/// (`sub` is the row-local max-stabilizer diag term) and the generic
/// exp-kernel activation (`sub = 0`).
pub fn fused_exp_scale_at(
    level: SimdLevel,
    row: &mut [f32],
    sub: f32,
    clamp: f32,
    scale: f32,
    eps: f32,
) {
    match level {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx2 => unsafe { x86::fused_exp_scale_avx2(row, sub, clamp, scale, eps) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        SimdLevel::Neon => unsafe { neon::fused_exp_scale_neon(row, sub, clamp, scale, eps) },
        // SSE2 has no packed round-to-nearest; keep the scalar oracle
        _ => fused_exp_scale_scalar(row, sub, clamp, scale, eps),
    }
}

/// [`fused_exp_scale_at`] at the process-wide [`active_level`].
#[inline]
pub fn fused_exp_scale(row: &mut [f32], sub: f32, clamp: f32, scale: f32, eps: f32) {
    fused_exp_scale_at(active_level(), row, sub, clamp, scale, eps)
}

// ---------------------------------------------------------------------
// row softmax — max-stabilized, vector exp + re-associated sum
// ---------------------------------------------------------------------

fn softmax_row_scalar(row: &mut [f32]) {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Numerically stable softmax over one row in place, at an explicit
/// dispatch level. The vector levels use the polynomial exp and a
/// re-associated sum, so this is tolerance-oracled against scalar.
pub fn softmax_row_at(level: SimdLevel, row: &mut [f32]) {
    match level {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        SimdLevel::Avx2 => unsafe { x86::softmax_row_avx2(row) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        SimdLevel::Neon => unsafe { neon::softmax_row_neon(row) },
        _ => softmax_row_scalar(row),
    }
}

/// [`softmax_row_at`] at the process-wide [`active_level`].
#[inline]
pub fn softmax_row(row: &mut [f32]) {
    softmax_row_at(active_level(), row)
}

// ---------------------------------------------------------------------
// x86_64 kernels
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::*;
    use std::arch::x86_64::*;

    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm_set1_ps(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let vx = _mm_loadu_ps(x.as_ptr().add(i));
            let vy = _mm_loadu_ps(y.as_ptr().add(i));
            // mul + add (never FMA): exactly the scalar loop's roundings
            _mm_storeu_ps(y.as_mut_ptr().add(i), _mm_add_ps(vy, _mm_mul_ps(va, vx)));
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            // mul + add (never FMA): exactly the scalar loop's roundings
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let p0 = _mm_mul_ps(_mm_loadu_ps(a.as_ptr().add(i)), _mm_loadu_ps(b.as_ptr().add(i)));
            let p1 = _mm_mul_ps(
                _mm_loadu_ps(a.as_ptr().add(i + 4)),
                _mm_loadu_ps(b.as_ptr().add(i + 4)),
            );
            acc0 = _mm_add_ps(acc0, p0);
            acc1 = _mm_add_ps(acc1, p1);
            i += 8;
        }
        while i + 4 <= n {
            let p = _mm_mul_ps(_mm_loadu_ps(a.as_ptr().add(i)), _mm_loadu_ps(b.as_ptr().add(i)));
            acc0 = _mm_add_ps(acc0, p);
            i += 4;
        }
        let acc = _mm_add_ps(acc0, acc1);
        let s2 = _mm_add_ps(acc, _mm_movehl_ps(acc, acc));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
        let mut s = _mm_cvtss_f32(s1);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let p0 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            let p1 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i + 8)),
                _mm256_loadu_ps(b.as_ptr().add(i + 8)),
            );
            acc0 = _mm256_add_ps(acc0, p0);
            acc1 = _mm256_add_ps(acc1, p1);
            i += 16;
        }
        while i + 8 <= n {
            let p = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc0 = _mm256_add_ps(acc0, p);
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let hi = _mm256_extractf128_ps(acc, 1);
        let lo = _mm256_castps256_ps128(acc);
        let s4 = _mm_add_ps(lo, hi);
        let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
        let s1 = _mm_add_ss(s2, _mm_shuffle_ps(s2, s2, 1));
        let mut s = _mm_cvtss_f32(s1);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Lane-wise [`exp_poly`]: same constants, same operation order
    /// (separate mul/add, round-to-nearest-even), so each lane matches
    /// the scalar polynomial bit for bit.
    #[target_feature(enable = "avx2")]
    unsafe fn exp_avx2(v: __m256) -> __m256 {
        let x = _mm256_min_ps(_mm256_max_ps(v, _mm256_set1_ps(EXP_LO)), _mm256_set1_ps(EXP_HI));
        let n = _mm256_round_ps(
            _mm256_mul_ps(x, _mm256_set1_ps(LOG2EF)),
            _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC,
        );
        let r = _mm256_sub_ps(
            _mm256_sub_ps(x, _mm256_mul_ps(n, _mm256_set1_ps(LN2_HI))),
            _mm256_mul_ps(n, _mm256_set1_ps(LN2_LO)),
        );
        let z = _mm256_mul_ps(r, r);
        let mut y = _mm256_set1_ps(EP0);
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EP1));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EP2));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EP3));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EP4));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EP5));
        y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, z), r), _mm256_set1_ps(1.0));
        let ni = _mm256_cvtps_epi32(n); // n is already integral
        _mm256_castsi256_ps(_mm256_add_epi32(_mm256_castps_si256(y), _mm256_slli_epi32(ni, 23)))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn fused_exp_scale_avx2(row: &mut [f32], sub: f32, clamp: f32, scale: f32, eps: f32) {
        let n = row.len();
        let vs = _mm256_set1_ps(sub);
        let vc = _mm256_set1_ps(clamp);
        let vk = _mm256_set1_ps(scale);
        let ve = _mm256_set1_ps(eps);
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(row.as_ptr().add(i));
            let t = _mm256_min_ps(_mm256_sub_ps(v, vs), vc);
            let r = _mm256_add_ps(_mm256_mul_ps(exp_avx2(t), vk), ve);
            _mm256_storeu_ps(row.as_mut_ptr().add(i), r);
            i += 8;
        }
        // remainder lanes use the same polynomial as the vector body
        fused_exp_scale_poly_tail(&mut row[i..], sub, clamp, scale, eps);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn softmax_row_avx2(row: &mut [f32]) {
        let n = row.len();
        // row max
        let mut i = 0;
        let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
        while i + 8 <= n {
            vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(row.as_ptr().add(i)));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
        let mut mx = lanes.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        while i < n {
            mx = mx.max(row[i]);
            i += 1;
        }
        // exp(v - mx) and sum
        let vm = _mm256_set1_ps(mx);
        let mut vsum = _mm256_setzero_ps();
        i = 0;
        while i + 8 <= n {
            let e = exp_avx2(_mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(i)), vm));
            _mm256_storeu_ps(row.as_mut_ptr().add(i), e);
            vsum = _mm256_add_ps(vsum, e);
            i += 8;
        }
        _mm256_storeu_ps(lanes.as_mut_ptr(), vsum);
        let mut sum: f32 = lanes.iter().sum();
        while i < n {
            row[i] = exp_poly(row[i] - mx);
            sum += row[i];
            i += 1;
        }
        // normalize
        let inv = _mm256_set1_ps(1.0);
        let vsumv = _mm256_set1_ps(sum);
        let vinv = _mm256_div_ps(inv, vsumv);
        i = 0;
        while i + 8 <= n {
            let v = _mm256_mul_ps(_mm256_loadu_ps(row.as_ptr().add(i)), vinv);
            _mm256_storeu_ps(row.as_mut_ptr().add(i), v);
            i += 8;
        }
        let sinv = _mm_cvtss_f32(_mm256_castps256_ps128(vinv));
        while i < n {
            row[i] *= sinv;
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------
// aarch64 kernels
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use super::*;
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let mut i = 0;
        while i + 4 <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            // mul + add (never FMA): exactly the scalar loop's roundings
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vmulq_f32(va, vx)));
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vaddq_f32(
                acc0,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))),
            );
            acc1 = vaddq_f32(
                acc1,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i + 4)), vld1q_f32(b.as_ptr().add(i + 4))),
            );
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vaddq_f32(
                acc0,
                vmulq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i))),
            );
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// Lane-wise [`exp_poly`] (same constants and operation order).
    #[target_feature(enable = "neon")]
    unsafe fn exp_neon(v: float32x4_t) -> float32x4_t {
        let x = vminq_f32(vmaxq_f32(v, vdupq_n_f32(EXP_LO)), vdupq_n_f32(EXP_HI));
        // round-to-nearest-even, matching the scalar round_ties_even
        let n = vrndnq_f32(vmulq_f32(x, vdupq_n_f32(LOG2EF)));
        let r = vsubq_f32(
            vsubq_f32(x, vmulq_f32(n, vdupq_n_f32(LN2_HI))),
            vmulq_f32(n, vdupq_n_f32(LN2_LO)),
        );
        let z = vmulq_f32(r, r);
        let mut y = vdupq_n_f32(EP0);
        y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EP1));
        y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EP2));
        y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EP3));
        y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EP4));
        y = vaddq_f32(vmulq_f32(y, r), vdupq_n_f32(EP5));
        y = vaddq_f32(vaddq_f32(vmulq_f32(y, z), r), vdupq_n_f32(1.0));
        let ni = vcvtq_s32_f32(n); // n is already integral
        vreinterpretq_f32_s32(vaddq_s32(vreinterpretq_s32_f32(y), vshlq_n_s32(ni, 23)))
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fused_exp_scale_neon(row: &mut [f32], sub: f32, clamp: f32, scale: f32, eps: f32) {
        let n = row.len();
        let vs = vdupq_n_f32(sub);
        let vc = vdupq_n_f32(clamp);
        let vk = vdupq_n_f32(scale);
        let ve = vdupq_n_f32(eps);
        let mut i = 0;
        while i + 4 <= n {
            let v = vld1q_f32(row.as_ptr().add(i));
            let t = vminq_f32(vsubq_f32(v, vs), vc);
            let r = vaddq_f32(vmulq_f32(exp_neon(t), vk), ve);
            vst1q_f32(row.as_mut_ptr().add(i), r);
            i += 4;
        }
        // remainder lanes use the same polynomial as the vector body
        fused_exp_scale_poly_tail(&mut row[i..], sub, clamp, scale, eps);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn softmax_row_neon(row: &mut [f32]) {
        let n = row.len();
        let mut i = 0;
        let mut vmax = vdupq_n_f32(f32::NEG_INFINITY);
        while i + 4 <= n {
            vmax = vmaxq_f32(vmax, vld1q_f32(row.as_ptr().add(i)));
            i += 4;
        }
        let mut mx = vmaxvq_f32(vmax);
        while i < n {
            mx = mx.max(row[i]);
            i += 1;
        }
        let vm = vdupq_n_f32(mx);
        let mut vsum = vdupq_n_f32(0.0);
        i = 0;
        while i + 4 <= n {
            let e = exp_neon(vsubq_f32(vld1q_f32(row.as_ptr().add(i)), vm));
            vst1q_f32(row.as_mut_ptr().add(i), e);
            vsum = vaddq_f32(vsum, e);
            i += 4;
        }
        let mut sum = vaddvq_f32(vsum);
        while i < n {
            row[i] = exp_poly(row[i] - mx);
            sum += row[i];
            i += 1;
        }
        let sinv = 1.0 / sum;
        let vinv = vdupq_n_f32(sinv);
        i = 0;
        while i + 4 <= n {
            vst1q_f32(row.as_mut_ptr().add(i), vmulq_f32(vld1q_f32(row.as_ptr().add(i)), vinv));
            i += 4;
        }
        while i < n {
            row[i] *= sinv;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u32) -> (Vec<f32>, Vec<f32>) {
        let f = |i: usize, k: u32| ((i as u32 * 2654435761 + seed * k) % 1000) as f32 / 250.0 - 2.0;
        ((0..n).map(|i| f(i, 1)).collect(), (0..n).map(|i| f(i, 7)).collect())
    }

    #[test]
    fn axpy_bitwise_identical_across_levels() {
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 64, 100] {
            let (x, y0) = vecs(n, 3);
            let mut want = y0.clone();
            axpy_at(SimdLevel::Scalar, 0.37, &x, &mut want);
            for level in supported_levels() {
                let mut got = y0.clone();
                axpy_at(level, 0.37, &x, &mut got);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "axpy n={n} level={}", level.name());
            }
        }
    }

    #[test]
    fn dot_within_ulp_scaled_tolerance_of_scalar() {
        for n in [0usize, 1, 3, 4, 5, 7, 8, 15, 16, 17, 33, 100, 257] {
            let (a, b) = vecs(n, 11);
            let want = dot_scalar(&a, &b);
            // scale the tolerance by the magnitude actually summed
            let mag: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            for level in supported_levels() {
                let got = dot_at(level, &a, &b);
                assert!(
                    (got - want).abs() <= 1e-6 * mag + 1e-6,
                    "dot n={n} level={}: {got} vs {want}",
                    level.name()
                );
            }
        }
    }

    #[test]
    fn exp_poly_tracks_libm() {
        let mut worst = 0.0f32;
        let mut x = -86.0f32;
        while x < 87.0 {
            let rel = (exp_poly(x) - x.exp()).abs() / x.exp();
            worst = worst.max(rel);
            x += 0.137;
        }
        assert!(worst < 2e-6, "exp_poly rel error {worst}");
        assert_eq!(exp_poly(0.0), 1.0);
    }

    #[test]
    fn fused_exp_scale_matches_formula_per_level() {
        for n in [1usize, 5, 8, 13, 64] {
            let (row0, _) = vecs(n, 5);
            let (sub, clamp, scale, eps) = (0.4f32, 30.0f32, 0.125f32, 1e-6f32);
            for level in supported_levels() {
                let mut got = row0.clone();
                fused_exp_scale_at(level, &mut got, sub, clamp, scale, eps);
                for (g, v) in got.iter().zip(&row0) {
                    let want = scale * (v - sub).min(clamp).exp() + eps;
                    assert!(
                        (g - want).abs() <= 1e-5 * want.abs() + 1e-9,
                        "n={n} level={}: {g} vs {want}",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn softmax_row_normalized_and_near_scalar_at_every_level() {
        for n in [1usize, 4, 7, 8, 19, 64] {
            let (row0, _) = vecs(n, 9);
            let mut want = row0.clone();
            softmax_row_at(SimdLevel::Scalar, &mut want);
            for level in supported_levels() {
                let mut got = row0.clone();
                softmax_row_at(level, &mut got);
                let s: f32 = got.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "n={n} level={} sum {s}", level.name());
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-5,
                        "n={n} level={}: {g} vs {w}",
                        level.name()
                    );
                }
            }
        }
    }

    // NOTE: set_level_override flips a process-global; its round-trip
    // test lives in the prop_simd integration binary (whose other tests
    // all use the explicit-level `_at` entry points), not here, so the
    // bitwise-pinned feature-map tests in this lib binary never race a
    // mid-test level flip.
    #[test]
    fn scalar_level_is_always_supported_and_widest_last() {
        assert!(supported(SimdLevel::Scalar));
        let levels = supported_levels();
        assert_eq!(levels.first(), Some(&SimdLevel::Scalar));
        assert!(levels.contains(&active_level()));
    }
}
