//! Minimal dense f32 tensor substrate.
//!
//! The native FAVOR implementation, the exact/LSH attention baselines and
//! the analysis benches (Figs. 1, 2, 11, Thm. 1 checks) run on this — a
//! row-major, heap-backed matrix with the handful of BLAS-1/3 operations
//! attention needs. Hot paths (matmul) are written cache-blocked and,
//! above a work threshold, row-tiled across scoped threads, so the
//! paper's timing *shape* (linear vs quadratic in L) is measured on a
//! reasonable baseline, not an artificially slow one.
//!
//! [`Batch`] is the batched-execution representation: B sequences stacked
//! into one (B·stride)×D matrix with per-sequence row counts, so the
//! dense per-token work (LayerNorm, QKV, projections, FFN) of a whole
//! batch runs as single fused matrix operations.
//!
//! Two submodules make the dense core fast without changing its
//! contracts: [`simd`] (explicit AVX2/SSE2/NEON kernels behind the
//! `simd` cargo feature, runtime-dispatched, serial kernels kept as the
//! oracle) and [`autotune`] (the matmul depth tile is measured on the
//! machine once per process instead of being a fixed constant — a
//! bitwise-invariant choice, see its docs).

use std::fmt;
use std::sync::OnceLock;

pub mod autotune;
pub mod simd;

pub use autotune::k_tile;
pub use simd::{active_level, set_level_override, SimdLevel};

/// Row-major 2-D matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    /// number of rows
    pub rows: usize,
    /// number of columns
    pub cols: usize,
    /// row-major element storage (rows × cols)
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap row-major data (length must equal rows × cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build element-wise from f(row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// n×n identity.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    /// Element (i, j).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    /// Mutable element (i, j).
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    /// Row i as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    /// Row i as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// C = A @ B, cache-blocked ikj loop.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut out = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut out);
        out
    }

    /// y = A @ x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// Multiply every element by s, in place.
    pub fn scale(&mut self, s: f32) -> &mut Self {
        for v in &mut self.data {
            *v *= s;
        }
        self
    }

    /// Element-wise self += other.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Element-wise difference self − other.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|&v| f(v)).collect())
    }

    /// Row-wise softmax in place (numerically stable). Dispatches to the
    /// vectorized exp path at the active [`simd`] level (the scalar
    /// level keeps libm exp and is the tolerance oracle).
    pub fn softmax_rows(&mut self) {
        let level = simd::active_level();
        for i in 0..self.rows {
            simd::softmax_row_at(level, self.row_mut(i));
        }
    }

    /// Sum over each row -> length-`rows` vector.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Mean absolute difference to another matrix.
    pub fn mean_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Max absolute difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }

    /// Slice of rows [lo, hi).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }
}

#[inline]
/// Dense dot product, dispatched to the active [`simd`] level (serial:
/// 4-lane unrolled accumulation, which lets LLVM vectorize without
/// fast-math; vector levels re-associate and are tolerance-oracled).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot_at(simd::active_level(), a, b)
}

/// axpy: y += a * x, dispatched to the active [`simd`] level. Bitwise
/// identical at every level (the vector bodies use separate mul + add,
/// never FMA), so every axpy-based matmul keeps its bitwise contracts.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy_at(simd::active_level(), alpha, x, y)
}

/// Worker-thread count for the parallel matmul: `PERFORMER_THREADS` if
/// set, else `std::thread::available_parallelism` (cached after first use).
pub fn matmul_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("PERFORMER_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Below this many multiply-adds a matmul runs serially: thread spawn
/// costs more than it saves on matrices this small (roughly the size of
/// one unbatched chunk through one dense layer).
const PAR_WORK_THRESHOLD: usize = 4 << 20;

/// The ikj kernel at an explicit depth tile — the [`autotune`] sweep's
/// probe and the bitwise-invariance tests call this directly; everything
/// else goes through [`matmul_rows`]/[`matmul_into`], which block by the
/// tuned [`k_tile`]. For any tile choice each output row accumulates
/// over k in globally ascending order, so the tile never changes bits.
pub fn matmul_rows_tiled(
    a: &Mat,
    lo: usize,
    hi: usize,
    b: &Mat,
    out_rows: &mut [f32],
    tile: usize,
) {
    let n = b.cols;
    // one dispatch-level load hoisted out of the k/i loops
    let level = simd::active_level();
    for k0 in (0..a.cols).step_by(tile) {
        let k1 = (k0 + tile).min(a.cols);
        for i in lo..hi {
            let arow = &a.row(i)[k0..k1];
            let orow = &mut out_rows[(i - lo) * n..(i - lo + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    simd::axpy_at(level, aik, b.row(k0 + k), orow);
                }
            }
        }
    }
}

/// ikj kernel over output rows [lo, hi), writing into `out_rows` (a
/// `(hi-lo)×b.cols` row-major slab, pre-zeroed): streams B rows, writes
/// C rows — cache-friendly for row-major data. Depth-tiled by the
/// autotuned [`k_tile`] so the streamed B-row working set stays in
/// L1/L2 while C rows accumulate.
fn matmul_rows(a: &Mat, lo: usize, hi: usize, b: &Mat, out_rows: &mut [f32]) {
    matmul_rows_tiled(a, lo, hi, b, out_rows, autotune::k_tile())
}

/// out = A @ B into a preallocated buffer. Large products are row-tiled
/// across scoped threads (count from [`matmul_threads`]); small ones run
/// serially — on the unbatched serving path a per-sequence matmul stays
/// below the threshold, while a fused [`Batch`] crosses it and saturates
/// the cores, which is where batched execution wins its throughput.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    let threads = matmul_threads();
    let work = a.rows * a.cols * b.cols;
    if threads <= 1 || work < PAR_WORK_THRESHOLD || a.rows < 2 * threads {
        out.data.fill(0.0);
        matmul_rows(a, 0, a.rows, b, &mut out.data);
        return;
    }
    let rows_per = (a.rows + threads - 1) / threads;
    let n = b.cols;
    std::thread::scope(|scope| {
        for (t, slab) in out.data.chunks_mut(rows_per * n).enumerate() {
            let lo = t * rows_per;
            scope.spawn(move || {
                slab.fill(0.0);
                matmul_rows(a, lo, lo + slab.len() / n, b, slab);
            });
        }
    });
}

/// B sequences fused into one row-major matrix for batched execution:
/// sequence `s` owns rows `[s*stride, s*stride + lens[s])` of `data`,
/// where `stride = max(lens)`. Row-local operations (LayerNorm, dense
/// layers, elementwise maps) run once over the whole stack; anything
/// sequence-aware (attention, output slicing) uses the metadata to visit
/// only real rows. Rows past a sequence's length are padding: they flow
/// through the dense ops as dead freight and are never read back, so
/// ragged batches need no masking.
#[derive(Clone, Debug)]
pub struct Batch {
    /// the fused (n_seqs * stride) × cols matrix
    pub data: Mat,
    /// rows reserved per sequence (= longest member)
    pub stride: usize,
    /// actual rows of each sequence
    pub lens: Vec<usize>,
}

impl Batch {
    /// Zero-filled batch for sequences of the given lengths.
    pub fn zeros(lens: &[usize], cols: usize) -> Batch {
        let stride = lens.iter().copied().max().unwrap_or(0);
        Batch {
            data: Mat::zeros(lens.len() * stride, cols),
            stride,
            lens: lens.to_vec(),
        }
    }

    /// Row range `[lo, hi)` of sequence `s` in the fused matrix.
    pub fn seq_rows(&self, s: usize) -> (usize, usize) {
        (s * self.stride, s * self.stride + self.lens[s])
    }

    /// Copy out the real rows of sequence `s`.
    pub fn seq_mat(&self, s: usize) -> Mat {
        let (lo, hi) = self.seq_rows(s);
        self.data.rows_slice(lo, hi)
    }
}

/// out = A[row_lo..row_hi, col_lo..col_lo+b.rows] @ B — the same
/// K-tiled ikj/axpy kernel as [`matmul_into`]'s serial path (and the
/// parallel path is bitwise-identical to serial), reading the row/column
/// block of A in place instead of copying it out first. This is what the
/// fused feature-map application rides on: per-head φ over the stacked
/// QKV matrix without a `slice_head` memcpy per (sequence, head).
pub fn matmul_block(
    a: &Mat,
    row_lo: usize,
    row_hi: usize,
    col_lo: usize,
    b: &Mat,
    out: &mut Mat,
) {
    let kdim = b.rows;
    assert!(row_lo <= row_hi && row_hi <= a.rows, "bad row block");
    assert!(col_lo + kdim <= a.cols, "column block exceeds A");
    assert_eq!((out.rows, out.cols), (row_hi - row_lo, b.cols));
    out.data.fill(0.0);
    let n = b.cols;
    let tile = autotune::k_tile();
    let level = simd::active_level();
    for k0 in (0..kdim).step_by(tile) {
        let k1 = (k0 + tile).min(kdim);
        for i in row_lo..row_hi {
            let arow = &a.row(i)[col_lo + k0..col_lo + k1];
            let orow = &mut out.data[(i - row_lo) * n..(i - row_lo + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    simd::axpy_at(level, aik, b.row(k0 + k), orow);
                }
            }
        }
    }
}

/// C = A^T @ B without materializing A^T.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut out = Mat::zeros(a.cols, b.cols);
    let level = simd::active_level();
    for r in 0..a.rows {
        let arow = a.row(r);
        let brow = b.row(r);
        for (i, &ari) in arow.iter().enumerate() {
            if ari != 0.0 {
                simd::axpy_at(level, ari, brow, &mut out.data[i * b.cols..(i + 1) * b.cols]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.matmul(&Mat::eye(5)).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 7, |i, j| (i * 11 + j * 3) as f32);
        assert_eq!(a.t().t().data, a.data);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f32);
        let b = Mat::from_fn(4, 5, |i, j| (i * j) as f32 + 1.0);
        assert_eq!(matmul_at_b(&a, &b).data, a.t().matmul(&b).data);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut a = Mat::from_fn(3, 4, |i, j| (i * j) as f32);
        a.softmax_rows();
        for i in 0..3 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_stable_for_large_values() {
        let mut a = Mat::from_vec(1, 3, vec![1000.0, 1001.0, 1002.0]);
        a.softmax_rows();
        assert!(a.data.iter().all(|v| v.is_finite()));
        assert!((a.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_boundary_lengths() {
        // audit of the serial 4-way unroll's tail (`for i in chunks*4..n`):
        // the unrolled body covers 4*(n/4) elements and the tail loop the
        // remaining n%4, so every length is summed exactly once. These
        // boundary lengths (empty, shorter than one unroll, exactly one,
        // one-past, mid-tail) pin that — and double as the oracle
        // fixtures the SIMD dot is checked against in prop_simd.
        for n in [0usize, 1, 3, 4, 5, 7] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32 + 1.0) * 0.25).collect();
            let y: Vec<f32> = (0..n).map(|i| (n - i) as f32 * 0.5 - 0.7).collect();
            let naive: f64 =
                x.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let got = simd::dot_scalar(&x, &y) as f64;
            assert!((got - naive).abs() < 1e-5, "n={n}: {got} vs {naive}");
            // the public entry point agrees at whatever level is active
            assert!((dot(&x, &y) as f64 - naive).abs() < 1e-5, "dispatched dot, n={n}");
        }
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let x = vec![1.0, -1.0, 2.0, 0.5];
        let via_mat = a.matmul(&Mat::from_vec(4, 1, x.clone()));
        assert_eq!(a.matvec(&x), via_mat.data);
    }

    #[test]
    fn rows_slice_contents() {
        let a = Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        // 512*256*64 ≈ 8.4M mul-adds crosses PAR_WORK_THRESHOLD, so on a
        // multi-core host this takes the scoped-thread path
        let a = Mat::from_fn(512, 256, |i, j| ((i * 31 + j * 7) % 17) as f32 * 0.25 - 1.0);
        let b = Mat::from_fn(256, 64, |i, j| ((i + 3 * j) % 13) as f32 * 0.5 - 2.0);
        let mut par = Mat::zeros(512, 64);
        matmul_into(&a, &b, &mut par);
        let mut serial = Mat::zeros(512, 64);
        serial.data.fill(0.0);
        matmul_rows(&a, 0, a.rows, &b, &mut serial.data);
        assert_eq!(par.data, serial.data, "threaded matmul must be bitwise-identical");
    }

    #[test]
    fn k_tiled_kernel_matches_naive_for_deep_k() {
        // a.cols > the smallest autotune candidate exercises the
        // depth-tiling loop whatever tile the sweep picked
        let a = Mat::from_fn(3, 300, |i, j| ((i * 7 + j) % 5) as f32 - 2.0);
        let b = Mat::from_fn(300, 4, |i, j| ((i + j) % 3) as f32);
        let got = a.matmul(&b);
        let naive = Mat::from_fn(3, 4, |i, j| {
            (0..300).map(|k| a.at(i, k) * b.at(k, j)).sum::<f32>()
        });
        assert!(got.max_abs_diff(&naive) < 1e-3);
    }

    #[test]
    fn matmul_block_matches_copied_slice_bitwise() {
        // reading the block in place must equal slicing it out and
        // multiplying — bit for bit (same kernel, same order)
        let a = Mat::from_fn(9, 14, |i, j| ((i * 13 + j * 5) % 11) as f32 * 0.37 - 1.5);
        let b = Mat::from_fn(6, 4, |i, j| ((i * 3 + j) % 7) as f32 * 0.21 - 0.6);
        let (row_lo, row_hi, col_lo) = (2, 7, 5);
        let mut blk = Mat::zeros(row_hi - row_lo, b.cols);
        matmul_block(&a, row_lo, row_hi, col_lo, &b, &mut blk);
        let copied = Mat::from_fn(row_hi - row_lo, b.rows, |i, j| a.at(row_lo + i, col_lo + j));
        assert_eq!(blk.data, copied.matmul(&b).data);
    }

    #[test]
    fn matmul_block_full_range_equals_matmul() {
        let a = Mat::from_fn(5, 300, |i, j| ((i * 7 + j) % 9) as f32 - 4.0);
        let b = Mat::from_fn(300, 3, |i, j| ((i + j) % 5) as f32 * 0.5);
        let mut out = Mat::zeros(5, 3);
        matmul_block(&a, 0, 5, 0, &b, &mut out);
        assert_eq!(out.data, a.matmul(&b).data);
    }

    #[test]
    fn batch_layout_and_roundtrip() {
        // write ragged sequences through seq_rows, read back via seq_mat
        let seqs = [
            Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32),
            Mat::from_fn(1, 2, |_, j| 100.0 + j as f32),
            Mat::from_fn(2, 2, |i, j| 200.0 + (i * 2 + j) as f32),
        ];
        let lens: Vec<usize> = seqs.iter().map(|m| m.rows).collect();
        let mut b = Batch::zeros(&lens, 2);
        assert_eq!(b.stride, 3);
        assert_eq!(b.data.rows, 9);
        for (s, m) in seqs.iter().enumerate() {
            let (lo, hi) = b.seq_rows(s);
            assert_eq!(hi - lo, m.rows);
            for i in 0..m.rows {
                b.data.row_mut(lo + i).copy_from_slice(m.row(i));
            }
        }
        assert_eq!(b.seq_rows(1), (3, 4));
        for (s, m) in seqs.iter().enumerate() {
            assert_eq!(b.seq_mat(s).data, m.data);
        }
        // padding rows stay zero
        assert_eq!(b.data.row(4), &[0.0, 0.0]);
        assert_eq!(b.data.row(8), &[0.0, 0.0]);
    }

    #[test]
    fn batch_zeros_empty_and_uniform() {
        let b = Batch::zeros(&[], 4);
        assert_eq!(b.data.rows, 0);
        assert_eq!(b.stride, 0);
        let u = Batch::zeros(&[5, 5], 3);
        assert_eq!(u.stride, 5);
        assert_eq!(u.data.rows, 10);
        assert_eq!(u.seq_rows(1), (5, 10));
    }
}
