//! Autotuned matmul blocking: picks the depth tile (`K_TILE`) for the
//! ikj kernel from a handful of candidates measured on the actual
//! machine, once per process, replacing the old fixed constant.
//!
//! Safe to tune freely: in the ikj kernel the tile loop is outermost
//! and each output row accumulates over k in globally ascending order
//! whatever the tile size, so *every* candidate produces bitwise
//! identical results (pinned by `k_tile_choice_is_bitwise_invariant`).
//! The sweep therefore only affects speed, never values.
//!
//! Overrides: `PERFORMER_K_TILE=<n>` pins the tile without measuring;
//! `PERFORMER_AUTOTUNE=off` skips the sweep and uses the default.

use std::sync::OnceLock;
use std::time::Instant;

use super::{matmul_rows_tiled, Mat};

/// The pre-autotune default depth tile (also used when the sweep is
/// disabled): keeps the streamed B-row working set inside L1/L2 while C
/// rows accumulate.
pub const DEFAULT_K_TILE: usize = 256;

/// Tile candidates the sweep measures, smallest first.
const CANDIDATES: [usize; 4] = [64, 128, 256, 512];

/// Probe shape: deep enough in k (3 × the largest candidate) that the
/// tiling actually matters, small enough that the one-off sweep costs
/// single-digit milliseconds.
const PROBE_M: usize = 48;
const PROBE_K: usize = 1536;
const PROBE_N: usize = 96;
const PROBE_REPS: usize = 3;

fn sweep() -> usize {
    let a = Mat::from_fn(PROBE_M, PROBE_K, |i, j| ((i * 31 + j * 7) % 17) as f32 * 0.25 - 2.0);
    let b = Mat::from_fn(PROBE_K, PROBE_N, |i, j| ((i + 3 * j) % 13) as f32 * 0.5 - 3.0);
    let mut out = vec![0.0f32; PROBE_M * PROBE_N];
    let mut best = (DEFAULT_K_TILE, f64::INFINITY);
    for &tile in &CANDIDATES {
        let mut t_min = f64::INFINITY;
        for _ in 0..PROBE_REPS {
            out.fill(0.0);
            let t0 = Instant::now();
            matmul_rows_tiled(&a, 0, PROBE_M, &b, &mut out, tile);
            t_min = t_min.min(t0.elapsed().as_secs_f64());
        }
        if t_min < best.1 {
            best = (tile, t_min);
        }
    }
    best.0
}

/// The depth tile every matmul kernel blocks by: `PERFORMER_K_TILE` if
/// set, else the measured best candidate (or [`DEFAULT_K_TILE`] under
/// `PERFORMER_AUTOTUNE=off`). Swept once per process, then cached.
pub fn k_tile() -> usize {
    static TILE: OnceLock<usize> = OnceLock::new();
    *TILE.get_or_init(|| {
        if let Some(n) = std::env::var("PERFORMER_K_TILE")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            return n;
        }
        if std::env::var("PERFORMER_AUTOTUNE").map(|v| v == "off").unwrap_or(false) {
            return DEFAULT_K_TILE;
        }
        sweep()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_tile_is_a_candidate_or_override() {
        let t = k_tile();
        assert!(t > 0);
        // stable across calls (cached)
        assert_eq!(t, k_tile());
    }

    #[test]
    fn k_tile_choice_is_bitwise_invariant() {
        // the autotune safety property: every candidate tile (and the
        // degenerate 1/huge tiles) yields bit-identical products
        let a = Mat::from_fn(5, 700, |i, j| ((i * 13 + j * 5) % 23) as f32 * 0.37 - 3.1);
        let b = Mat::from_fn(700, 6, |i, j| ((i * 3 + j) % 19) as f32 * 0.21 - 1.7);
        let mut want = vec![0.0f32; 5 * 6];
        matmul_rows_tiled(&a, 0, 5, &b, &mut want, DEFAULT_K_TILE);
        for tile in [1usize, 64, 128, 512, 10_000] {
            let mut got = vec![0.0f32; 5 * 6];
            matmul_rows_tiled(&a, 0, 5, &b, &mut got, tile);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "tile={tile} changed bits");
        }
    }
}
