//! PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles them on
//! the CPU PJRT client, and executes them with typed host values.
//!
//! One `Engine` owns the PJRT client and a compile cache keyed by
//! artifact name; `Executable` pairs the compiled module with its
//! metadata contract so callers address inputs by role, not position.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::artifact::{ArtifactMeta, Dtype, Role};

// Without the `xla` feature the PJRT bindings resolve to the in-tree
// stub, which fails at `PjRtClient::cpu()` with a clear message.
#[cfg(not(feature = "xla"))]
use super::xla_stub as xla;

/// A typed host-side value fed to / read from an executable.
#[derive(Clone, Debug)]
pub enum HostValue {
    /// packed f32 tensor data
    F32(Vec<f32>),
    /// packed i32 tensor data
    I32(Vec<i32>),
}

impl HostValue {
    /// The f32 data, or an error for i32 values.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32(v) => Ok(v),
            _ => bail!("expected f32 value"),
        }
    }

    /// The i32 data, or an error for f32 values.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32(v) => Ok(v),
            _ => bail!("expected i32 value"),
        }
    }

    /// First f32 element (scalar outputs).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first().copied().ok_or_else(|| anyhow!("empty value"))
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostValue::F32(v) => v.len(),
            HostValue::I32(v) => v.len(),
        }
    }

    /// Whether the value holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Compiled artifact + its metadata contract.
pub struct Executable {
    /// the artifact's I/O contract
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative execute statistics (wall time, call count)
    stats: Mutex<ExecStats>,
}

#[derive(Clone, Copy, Debug, Default)]
/// Cumulative execution statistics of one executable.
pub struct ExecStats {
    /// executions performed
    pub calls: u64,
    /// total wall time inside PJRT execute
    pub total_secs: f64,
}

impl Executable {
    /// Run with host inputs in artifact order. Returns host outputs in
    /// artifact order (the AOT modules are lowered with return_tuple).
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (slot, val) in self.meta.inputs.iter().zip(inputs) {
            if slot.elements() != val.len() {
                bail!(
                    "{}: input '{}' expects {} elements, got {}",
                    self.meta.name, slot.name, slot.elements(), val.len()
                );
            }
            let dims: Vec<i64> = slot.shape.iter().map(|&d| d as i64).collect();
            let lit = match (slot.dtype, val) {
                (Dtype::F32, HostValue::F32(v)) => {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                (Dtype::I32, HostValue::I32(v)) => {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                _ => bail!("{}: dtype mismatch for '{}'", self.meta.name, slot.name),
            };
            literals.push(lit);
        }

        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        {
            let mut st = self.stats.lock().unwrap();
            st.calls += 1;
            st.total_secs += t0.elapsed().as_secs_f64();
        }

        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, lit) in parts.into_iter().enumerate() {
            let dtype = self.meta.outputs.get(i).map(|s| s.dtype).unwrap_or(Dtype::F32);
            out.push(match dtype {
                Dtype::F32 => HostValue::F32(lit.to_vec::<f32>()?),
                Dtype::I32 => HostValue::I32(lit.to_vec::<i32>()?),
            });
        }
        Ok(out)
    }

    /// Snapshot of the execution statistics.
    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// The engine: PJRT client + compiled-artifact cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Engine {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifacts directory this engine loads from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = ArtifactMeta::load(&self.dir, name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", meta.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let compiled = Arc::new(Executable {
            meta,
            exe,
            stats: Mutex::new(ExecStats::default()),
        });
        eprintln!(
            "[engine] compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Compile-time check that an artifact exists without compiling it.
    pub fn exists(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }
}

/// Helper: build the full input vector for an executable from role-keyed
/// parts, filling `Role::Param`-like slots from an ordered list.
pub struct InputBuilder<'a> {
    meta: &'a ArtifactMeta,
    values: Vec<Option<HostValue>>,
}

impl<'a> InputBuilder<'a> {
    /// Empty builder over an artifact's input slots.
    pub fn new(meta: &'a ArtifactMeta) -> Self {
        InputBuilder { meta, values: vec![None; meta.inputs.len()] }
    }

    /// Fill all slots of a role from an ordered iterator of values.
    pub fn fill_role(mut self, role: Role, vals: impl IntoIterator<Item = HostValue>) -> Result<Self> {
        let idx = self.meta.input_indices(role);
        let mut it = vals.into_iter();
        for i in &idx {
            self.values[*i] = Some(
                it.next()
                    .ok_or_else(|| anyhow!("not enough values for role {role:?}"))?,
            );
        }
        if it.next().is_some() {
            bail!("too many values for role {role:?} (expected {})", idx.len());
        }
        Ok(self)
    }

    /// Fill the single slot of a role.
    pub fn set(mut self, role: Role, val: HostValue) -> Result<Self> {
        let i = self.meta.input_index(role)?;
        self.values[i] = Some(val);
        Ok(self)
    }

    /// The complete input vector; any unfilled slot is an error.
    pub fn finish(self) -> Result<Vec<HostValue>> {
        let mut out = Vec::with_capacity(self.values.len());
        for (i, v) in self.values.into_iter().enumerate() {
            out.push(v.ok_or_else(|| {
                anyhow!(
                    "input '{}' (role {:?}) not provided",
                    self.meta.inputs[i].name,
                    self.meta.inputs[i].role
                )
            })?);
        }
        Ok(out)
    }
}
