//! Engine actor: the `xla` crate's PJRT handles are `!Send` (Rc + raw
//! pointers), so all PJRT compilation/execution lives on one dedicated
//! thread. Other threads (serving workers, the router, benches) talk to
//! it through a cloneable `EngineHandle` exchanging plain host data.
//!
//! On this single-core testbed the serialization this imposes is free —
//! PJRT CPU execution is the bottleneck either way.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::artifact::ArtifactMeta;
use super::engine::{Engine, HostValue};

enum Msg {
    Exec {
        artifact: String,
        inputs: Vec<HostValue>,
        reply: Sender<Result<Vec<HostValue>>>,
    },
    /// Pre-compile an artifact without running it.
    Warm {
        artifact: String,
        reply: Sender<Result<()>>,
    },
}

/// Cloneable, Send handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Msg>,
    dir: PathBuf,
}

impl EngineHandle {
    /// Execute an artifact with host inputs; blocks for the result.
    pub fn exec(&self, artifact: &str, inputs: Vec<HostValue>) -> Result<Vec<HostValue>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Exec { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    /// Compile an artifact ahead of serving.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    /// Artifact metadata (parsed from disk; no PJRT involved).
    pub fn meta(&self, artifact: &str) -> Result<ArtifactMeta> {
        ArtifactMeta::load(&self.dir, artifact)
    }

    /// A handle backed by no engine thread: `exec`/`warm` fail cleanly.
    /// Lets a `Coordinator` host native streaming pools (which never
    /// touch PJRT) without spawning an engine actor — e.g. in builds
    /// where the PJRT backend is stubbed out.
    pub fn disconnected(artifacts_dir: impl AsRef<Path>) -> EngineHandle {
        let (tx, _rx) = channel();
        EngineHandle { tx, dir: artifacts_dir.as_ref().to_path_buf() }
    }

    /// The artifacts directory this handle resolves names against.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}

/// A running engine actor; dropping it (after all handles) stops the
/// thread.
pub struct EngineActor {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl EngineActor {
    /// Spawn the engine thread over an artifacts directory.
    pub fn spawn(artifacts_dir: impl AsRef<Path>) -> Result<EngineActor> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let dir2 = dir.clone();
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::new(&dir2) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Exec { artifact, inputs, reply } => {
                            let res = engine
                                .load(&artifact)
                                .and_then(|exe| exe.run(&inputs));
                            let _ = reply.send(res);
                        }
                        Msg::Warm { artifact, reply } => {
                            let _ = reply.send(engine.load(&artifact).map(|_| ()));
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(EngineActor { handle: EngineHandle { tx, dir }, join: Some(join) })
    }

    /// A new handle to the running engine thread.
    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for EngineActor {
    fn drop(&mut self) {
        // Detach rather than join: other EngineHandles (e.g. inside a
        // Coordinator that outlives this actor) keep the channel open, so
        // joining here could deadlock. The engine thread exits when the
        // last handle drops; at process exit it is reaped either way.
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.handle.tx, tx);
        drop(old);
        if let Some(j) = self.join.take() {
            drop(j); // detach
        }
    }
}
