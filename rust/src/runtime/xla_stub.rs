//! Offline stub of the `xla` PJRT binding surface `engine.rs` compiles
//! against when the `xla` cargo feature is disabled (the bindings crate
//! is not in the offline registry image).
//!
//! Every entry point fails fast with a descriptive error, so anything
//! that genuinely needs compiled artifacts (serving pools, the training
//! driver, the HLO bench series) reports "backend unavailable" cleanly
//! at runtime instead of failing the build. All native paths — FAVOR,
//! the streaming session subsystem, analysis and benches — never touch
//! this module.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built without the `xla` feature (offline image); \
     native FAVOR and streaming paths remain fully functional";

/// Error type standing in for the binding crate's error.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

/// Stub PJRT client; construction always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: no PJRT in stub builds.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    /// Stub platform marker.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Always fails in stub builds.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Stub compiled executable (never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails in stub builds.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Stub device buffer (never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails in stub builds.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    /// Constructs an inert literal (execution fails later, loudly).
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Always fails in stub builds.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Always fails in stub builds.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    /// Always fails in stub builds.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails in stub builds.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// Stub computation handle.
pub struct XlaComputation;

impl XlaComputation {
    /// Constructs an inert computation handle.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
