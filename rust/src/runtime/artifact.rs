//! Artifact metadata: the I/O contract between `python/compile/aot.py`
//! and the rust runtime. Each `<name>.hlo.txt` is paired with a
//! `<name>.meta.json` describing inputs (name/role/shape/dtype), outputs
//! and the model configuration it was lowered with.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::Json;

/// What an input/output slot means to the training/serving driver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// model parameter
    Param,
    /// Adam first-moment slot
    OptM,
    /// Adam second-moment slot
    OptV,
    /// optimizer step counter
    OptStep,
    /// FAVOR random-feature draw
    Feature,
    /// input token ids
    Tokens,
    /// prediction targets
    Targets,
    /// per-position loss weights
    Weights,
    /// generic input
    Input,
    /// scalar loss output
    Loss,
    /// scalar accuracy output
    Acc,
    /// unrecognized role
    Other,
}

impl Role {
    fn parse(s: &str) -> Role {
        match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "opt_step" => Role::OptStep,
            "feature" => Role::Feature,
            "tokens" => Role::Tokens,
            "targets" => Role::Targets,
            "weights" => Role::Weights,
            "input" => Role::Input,
            "loss" => Role::Loss,
            "acc" => Role::Acc,
            _ => Role::Other,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Element type of a slot.
pub enum Dtype {
    /// 32-bit float
    F32,
    /// 32-bit integer
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unsupported dtype {s}"),
        }
    }
}

/// One input or output slot.
#[derive(Clone, Debug)]
pub struct Slot {
    /// slot name (as lowered by aot.py)
    pub name: String,
    /// what the slot means to the driver
    pub role: Role,
    /// tensor shape
    pub shape: Vec<usize>,
    /// element type
    pub dtype: Dtype,
}

impl Slot {
    /// Number of elements the slot holds.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(j: &Json) -> Result<Slot> {
        let shape = j
            .req("shape")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(Slot {
            name: j.str_or("name", "?"),
            role: Role::parse(&j.str_or("role", "other")),
            shape,
            dtype: Dtype::parse(&j.str_or("dtype", "f32"))?,
        })
    }
}

/// Model configuration echoed into the metadata by aot.py.
#[derive(Clone, Debug, Default)]
pub struct ArtifactConfig {
    /// model width
    pub d_model: usize,
    /// attention heads per layer
    pub n_heads: usize,
    /// number of transformer layers
    pub n_layers: usize,
    /// feed-forward hidden width
    pub d_ff: usize,
    /// compiled sequence length
    pub max_len: usize,
    /// FAVOR feature count M
    pub n_features: usize,
    /// compiled batch size
    pub batch: usize,
    /// vocabulary size
    pub vocab_size: usize,
    /// attention family ("favor-relu", "exact", ...)
    pub attention: String,
    /// causal (true) vs bidirectional (false)
    pub unidirectional: bool,
    /// total trainable parameters
    pub param_count: usize,
    /// extra numeric config echoed by aot.py
    pub extra: BTreeMap<String, f64>,
}

/// A parsed artifact contract.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// artifact name (file stem)
    pub name: String,
    /// artifact kind ("fwd", "train", "eval")
    pub kind: String,
    /// the model configuration it was lowered with
    pub config: ArtifactConfig,
    /// input slots in call order
    pub inputs: Vec<Slot>,
    /// output slots in return order
    pub outputs: Vec<Slot>,
    /// path to the HLO text module
    pub hlo_path: PathBuf,
}

impl ArtifactMeta {
    /// Read `<dir>/<name>.meta.json`.
    pub fn load(dir: &Path, name: &str) -> Result<ArtifactMeta> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", meta_path.display()))?;

        let cfg_j = j.get("config").cloned().unwrap_or(Json::Obj(Default::default()));
        let config = ArtifactConfig {
            d_model: cfg_j.usize_or("d_model", 0),
            n_heads: cfg_j.usize_or("n_heads", 0),
            n_layers: cfg_j.usize_or("n_layers", 0),
            d_ff: cfg_j.usize_or("d_ff", 0),
            max_len: cfg_j.usize_or("max_len", cfg_j.usize_or("l", 0)),
            n_features: cfg_j.usize_or("n_features", cfg_j.usize_or("m", 0)),
            batch: cfg_j.usize_or("batch", cfg_j.usize_or("bh", 1)),
            vocab_size: cfg_j.usize_or("vocab_size", 0),
            attention: cfg_j.str_or("attention", cfg_j.str_or("mech", "").as_str()),
            unidirectional: cfg_j.bool_or("unidirectional", cfg_j.bool_or("causal", false)),
            param_count: cfg_j.usize_or("param_count", 0),
            extra: BTreeMap::new(),
        };

        let inputs = j
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(Slot::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .map(|o| o.as_arr().map(|a| a.iter().map(Slot::parse).collect::<Result<Vec<_>>>()))
            .transpose()?
            .transpose()?
            .unwrap_or_default();

        Ok(ArtifactMeta {
            name: name.to_string(),
            kind: j.str_or("kind", "unknown"),
            config,
            inputs,
            outputs,
            hlo_path: dir.join(format!("{name}.hlo.txt")),
        })
    }

    /// Indices of input slots with the given role, in artifact order.
    pub fn input_indices(&self, role: Role) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.role == role)
            .map(|(i, _)| i)
            .collect()
    }

    /// Index of the single input slot with the given role.
    pub fn input_index(&self, role: Role) -> Result<usize> {
        let idx = self.input_indices(role);
        match idx.as_slice() {
            [i] => Ok(*i),
            [] => Err(anyhow!("{}: no input with role {role:?}", self.name)),
            _ => Err(anyhow!("{}: multiple inputs with role {role:?}", self.name)),
        }
    }

    /// Index of an output slot by name.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no output named {name}", self.name))
    }
}

/// The artifact directory index written by aot.py.
pub fn list_artifacts(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let p = entry?.path();
        if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
            if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                names.push(stem.to_string());
            }
        }
    }
    names.sort();
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("t_fwd.meta.json"),
            r#"{
              "kind": "fwd",
              "config": {"d_model": 64, "batch": 4, "max_len": 64,
                         "attention": "favor-relu", "unidirectional": false,
                         "param_count": 1000},
              "inputs": [
                {"name": "embed", "role": "param", "shape": [30, 64], "dtype": "f32"},
                {"name": "w", "role": "feature", "shape": [32, 32], "dtype": "f32"},
                {"name": "tokens", "role": "tokens", "shape": [4, 64], "dtype": "i32"}
              ],
              "outputs": [
                {"name": "logits", "shape": [4, 64, 30], "dtype": "f32"}
              ]
            }"#,
        )
        .unwrap();
        std::fs::write(dir.join("t_fwd.hlo.txt"), "HloModule t\n").unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join("performer_meta_test");
        write_fixture(&dir);
        let m = ArtifactMeta::load(&dir, "t_fwd").unwrap();
        assert_eq!(m.kind, "fwd");
        assert_eq!(m.config.d_model, 64);
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.input_indices(Role::Param), vec![0]);
        assert_eq!(m.input_index(Role::Tokens).unwrap(), 2);
        assert_eq!(m.inputs[2].dtype, Dtype::I32);
        assert_eq!(m.inputs[0].elements(), 30 * 64);
        assert_eq!(m.output_index("logits").unwrap(), 0);
        assert!(m.input_index(Role::Targets).is_err());
        let names = list_artifacts(&dir).unwrap();
        assert!(names.contains(&"t_fwd".to_string()));
    }
}
