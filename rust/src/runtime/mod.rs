//! Runtime: the PJRT bridge between the rust coordinator and the AOT
//! artifacts produced by `python/compile/aot.py`. Python never runs at
//! serving/training time — the HLO text is compiled once by the CPU PJRT
//! client and executed from the rust hot path.

pub mod actor;
pub mod artifact;
pub mod engine;
pub mod tensorfile;
#[cfg(not(feature = "xla"))]
pub(crate) mod xla_stub;

pub use actor::{EngineActor, EngineHandle};
pub use artifact::{ArtifactMeta, Dtype, Role, Slot};
pub use engine::{Engine, Executable, HostValue, InputBuilder};
pub use tensorfile::TensorFile;
