//! The PFRMTENS tensor container: how python hands rust the initial
//! parameter/feature values, and how rust checkpoints training state.
//!
//! Layout: b"PFRMTENS" | u32 LE header length | JSON header | raw payload.
//! Header: [{"name", "shape", "dtype": "f32", "offset"}] with offsets into
//! the payload region (bytes). f32 little-endian only.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonx::{arr, num, obj, s, Json};

const MAGIC: &[u8; 8] = b"PFRMTENS";

/// A named collection of f32 tensors (order preserved).
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    /// (name, shape, data) tensors in file order
    pub entries: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl TensorFile {
    /// Read a PFRMTENS container from disk.
    pub fn read(path: &Path) -> Result<TensorFile> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    /// Decode a PFRMTENS container from memory — the embedded form used
    /// by the session-snapshot format (`persist/snapshot.rs`), which
    /// wraps these bytes in its own versioned, checksummed envelope.
    pub fn from_bytes(bytes: &[u8]) -> Result<TensorFile> {
        if bytes.len() < 12 || &bytes[..8] != MAGIC {
            bail!("not a PFRMTENS container");
        }
        let hlen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let header_end = 12usize.checked_add(hlen).ok_or_else(|| anyhow::anyhow!("header length overflow"))?;
        if bytes.len() < header_end {
            bail!("truncated header");
        }
        let header = Json::parse(std::str::from_utf8(&bytes[12..header_end])?)?;
        let payload = &bytes[header_end..];

        let mut entries = Vec::new();
        for e in header.as_arr()? {
            let name = e.str_or("name", "?");
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?;
            let offset = e.usize_or("offset", 0);
            // checked arithmetic: a corrupt header must bail, not wrap
            // into a bogus in-bounds range (or panic on a slice)
            let n = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| anyhow::anyhow!("tensor {name}: shape overflows"))?
                .max(1);
            let end = n
                .checked_mul(4)
                .and_then(|b| offset.checked_add(b))
                .filter(|&e| e <= payload.len())
                .ok_or_else(|| anyhow::anyhow!("tensor {name} overruns payload"))?;
            let data: Vec<f32> = payload[offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            entries.push((name, shape, data));
        }
        Ok(TensorFile { entries })
    }

    /// Encode as a PFRMTENS container in memory (see [`Self::from_bytes`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut header = Vec::new();
        let mut offset = 0usize;
        for (name, shape, data) in &self.entries {
            header.push(obj(vec![
                ("name", s(name)),
                ("shape", arr(shape.iter().map(|&d| num(d as f64)))),
                ("dtype", s("f32")),
                ("offset", num(offset as f64)),
            ]));
            offset += data.len() * 4;
        }
        let hjson = Json::Arr(header).to_string();
        let mut out = Vec::with_capacity(12 + hjson.len() + offset);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(hjson.len() as u32).to_le_bytes());
        out.extend_from_slice(hjson.as_bytes());
        for (_, _, data) in &self.entries {
            // safe little-endian serialization
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Write the container to disk (not atomic — the persist layer
    /// wraps its copies in temp-file-then-rename).
    pub fn write(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Look up one tensor by name.
    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.entries
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, shape, data)| (shape.as_slice(), data.as_slice()))
    }

    /// Entries with the given prefix (e.g. "param:"), prefix stripped,
    /// as a name -> (shape, data) map preserving artifact order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<(String, &[usize], &[f32])> {
        self.entries
            .iter()
            .filter_map(|(n, shape, data)| {
                n.strip_prefix(prefix).map(|rest| (rest.to_string(), shape.as_slice(), data.as_slice()))
            })
            .collect()
    }

    /// Clone the entries into a name-keyed map.
    pub fn to_map(&self) -> BTreeMap<String, (Vec<usize>, Vec<f32>)> {
        self.entries
            .iter()
            .map(|(n, s, d)| (n.clone(), (s.clone(), d.clone())))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tf = TensorFile {
            entries: vec![
                ("param:a".into(), vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                ("feature:w".into(), vec![4], vec![-1.0, 0.5, 0.0, 9.0]),
                ("scalar".into(), vec![], vec![7.5]),
            ],
        };
        let path = std::env::temp_dir().join("pfrm_tensorfile_test.bin");
        tf.write(&path).unwrap();
        let back = TensorFile::read(&path).unwrap();
        assert_eq!(back.entries.len(), 3);
        let (shape, data) = back.get("param:a").unwrap();
        assert_eq!(shape, &[2, 3]);
        assert_eq!(data, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let (shape, data) = back.get("scalar").unwrap();
        assert!(shape.is_empty());
        assert_eq!(data, &[7.5]);
        let params = back.with_prefix("param:");
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].0, "a");
    }

    #[test]
    fn bytes_roundtrip_without_touching_disk() {
        let tf = TensorFile {
            entries: vec![("x".into(), vec![3], vec![1.5, -2.5, 3.25])],
        };
        let bytes = tf.to_bytes();
        let back = TensorFile::from_bytes(&bytes).unwrap();
        let (shape, data) = back.get("x").unwrap();
        assert_eq!(shape, &[3]);
        assert_eq!(data, &[1.5, -2.5, 3.25]);
        // every truncation of a valid container must fail, not misparse
        for cut in [0, 4, 11, bytes.len() - 1] {
            assert!(TensorFile::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("pfrm_badmagic.bin");
        std::fs::write(&path, b"NOTMAGIC....").unwrap();
        assert!(TensorFile::read(&path).is_err());
    }

    #[test]
    fn rejects_overrun() {
        // header declares more data than the payload holds
        let path = std::env::temp_dir().join("pfrm_overrun.bin");
        let hdr = r#"[{"name":"x","shape":[100],"dtype":"f32","offset":0}]"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"PFRMTENS");
        bytes.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        bytes.extend_from_slice(hdr.as_bytes());
        bytes.extend_from_slice(&[0u8; 16]); // only 4 floats
        std::fs::write(&path, &bytes).unwrap();
        assert!(TensorFile::read(&path).is_err());
    }
}
