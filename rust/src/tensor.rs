//! Minimal dense f32 tensor substrate.
//!
//! The native FAVOR implementation, the exact/LSH attention baselines and
//! the analysis benches (Figs. 1, 2, 11, Thm. 1 checks) run on this — a
//! row-major, heap-backed matrix with the handful of BLAS-1/3 operations
//! attention needs. Hot paths (matmul) are written cache-blocked so the
//! paper's timing *shape* (linear vs quadratic in L) is measured on a
//! reasonable baseline, not an artificially slow one.

use std::fmt;

/// Row-major 2-D matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// C = A @ B, cache-blocked ikj loop.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut out = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut out);
        out
    }

    /// y = A @ x for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    pub fn scale(&mut self, s: f32) -> &mut Self {
        for v in &mut self.data {
            *v *= s;
        }
        self
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|&v| f(v)).collect())
    }

    /// Row-wise softmax in place (numerically stable).
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let row = self.row_mut(i);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }

    /// Sum over each row -> length-`rows` vector.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Mean absolute difference to another matrix.
    pub fn mean_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Max absolute difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }

    /// Slice of rows [lo, hi).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled accumulation: lets LLVM vectorize without fast-math
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// out = A @ B accumulated into a preallocated buffer (ikj order: streams
/// B rows, writes C rows — cache-friendly for row-major data).
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((out.rows, out.cols), (a.rows, b.cols));
    out.data.fill(0.0);
    for i in 0..a.rows {
        let arow = a.row(i);
        let orow = &mut out.data[i * b.cols..(i + 1) * b.cols];
        for (k, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                axpy(aik, b.row(k), orow);
            }
        }
    }
}

/// C = A^T @ B without materializing A^T.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let mut out = Mat::zeros(a.cols, b.cols);
    for r in 0..a.rows {
        let arow = a.row(r);
        let brow = b.row(r);
        for (i, &ari) in arow.iter().enumerate() {
            if ari != 0.0 {
                axpy(ari, brow, &mut out.data[i * b.cols..(i + 1) * b.cols]);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(5, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.matmul(&Mat::eye(5)).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 7, |i, j| (i * 11 + j * 3) as f32);
        assert_eq!(a.t().t().data, a.data);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let a = Mat::from_fn(4, 3, |i, j| (i + 2 * j) as f32);
        let b = Mat::from_fn(4, 5, |i, j| (i * j) as f32 + 1.0);
        assert_eq!(matmul_at_b(&a, &b).data, a.t().matmul(&b).data);
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut a = Mat::from_fn(3, 4, |i, j| (i * j) as f32);
        a.softmax_rows();
        for i in 0..3 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_stable_for_large_values() {
        let mut a = Mat::from_vec(1, 3, vec![1000.0, 1001.0, 1002.0]);
        a.softmax_rows();
        assert!(a.data.iter().all(|v| v.is_finite()));
        assert!((a.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-4);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let x = vec![1.0, -1.0, 2.0, 0.5];
        let via_mat = a.matmul(&Mat::from_vec(4, 1, x.clone()));
        assert_eq!(a.matvec(&x), via_mat.data);
    }

    #[test]
    fn rows_slice_contents() {
        let a = Mat::from_fn(5, 2, |i, j| (i * 2 + j) as f32);
        let s = a.rows_slice(1, 3);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
    }
}
