//! Streaming request path: long-context sessions served through the
//! coordinator. Clients submit chunks tagged with a session id; a
//! dedicated worker thread owns the `stream::SessionManager` (per-model)
//! and answers each chunk incrementally, so a stream's total length is
//! unbounded while its resident footprint stays constant.
//!
//!   clients ──submit_chunk()──▶ stream worker ──▶ SessionManager
//!                                (fused drain)      (budget + LRU)
//!
//! The worker drains up to [`STREAM_MAX_BATCH`] requests arriving in
//! the same [`STREAM_MAX_WAIT`] window and hands them to
//! `SessionManager::advance_batch` in one call, which fuses them into
//! length-compatible batched forwards
//! (`NativeModel::forward_chunk_batch`), padding the remainder inside
//! the fused `Batch` — the cross-chunk session batching the roadmap
//! called for. Per-session submission order is preserved even when one
//! session's chunks repeat within a drain window (duplicates advance in
//! ordered fused waves), and none of the window's sessions can be
//! LRU-evicted while the window is being served.
//!
//! This path runs the native Performer stack — it never touches PJRT,
//! so it works in stub builds and scales past any compiled artifact
//! length.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::stream::{ChunkScores, SessionConfig, SessionManager};
use crate::train::NativeModel;

use super::batcher::collect_batch;

/// Most chunk submissions one drain fuses into a batched forward.
pub const STREAM_MAX_BATCH: usize = 8;

/// How long the worker waits to fill a batch after the first request.
pub const STREAM_MAX_WAIT: Duration = Duration::from_millis(2);

/// One streaming request: the next chunk of a session's token stream,
/// or a close notice (empty `tokens` + `close`).
pub struct StreamRequest {
    pub session: String,
    pub tokens: Vec<u8>,
    /// release the session's state after processing this request
    pub close: bool,
    pub respond: Sender<StreamResponse>,
    pub submitted: Instant,
}

/// Incremental answer for one chunk.
#[derive(Clone, Debug)]
pub struct StreamResponse {
    pub session: String,
    /// per-token scores for this chunk (None for a close-only request
    /// or an error)
    pub scores: Option<ChunkScores>,
    pub error: Option<String>,
    pub latency: Duration,
    /// sessions resident after this request
    pub resident_sessions: usize,
    /// carried-state bytes resident after this request
    pub resident_bytes: usize,
}

impl StreamResponse {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A running stream pool: worker thread + its request queue.
pub(crate) struct StreamPool {
    pub(crate) tx: Sender<StreamRequest>,
    pub(crate) worker: Option<JoinHandle<()>>,
}

impl StreamPool {
    /// Spawn the worker owning a session manager over `model`, fusing
    /// up to `max_batch` same-window submissions per forward.
    pub(crate) fn spawn(
        name: &str,
        model: Arc<NativeModel>,
        cfg: SessionConfig,
        max_batch: usize,
        max_wait: Duration,
    ) -> Result<StreamPool> {
        // validate streamability up front, on the caller's thread
        let mut mgr = SessionManager::new(model, cfg)?;
        let (tx, rx) = channel::<StreamRequest>();
        let max_batch = max_batch.max(1);
        let worker = std::thread::Builder::new()
            .name(format!("stream-{name}"))
            .spawn(move || stream_loop(&rx, &mut mgr, max_batch, max_wait))?;
        Ok(StreamPool { tx, worker: Some(worker) })
    }

    pub(crate) fn shutdown(mut self) {
        drop(self.tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn stream_loop(
    rx: &Receiver<StreamRequest>,
    mgr: &mut SessionManager,
    max_batch: usize,
    max_wait: Duration,
) {
    while let Some(batch) = collect_batch(rx, max_batch, max_wait) {
        serve_stream_batch(batch, mgr);
    }
}

/// Answer one drained batch: control requests (close-only / empty) are
/// answered individually; everything scoreable goes to
/// `SessionManager::advance_batch` in one call, which fuses it into
/// length-compatible waves, advances repeated session ids in submission
/// order, and never evicts any of the window's sessions while serving
/// it. A request's `close` takes effect after the batch's scoring — a
/// chunk for the same session queued behind a close-carrying chunk in
/// one drain window continues the stream rather than racing the
/// teardown.
fn serve_stream_batch(batch: Vec<StreamRequest>, mgr: &mut SessionManager) {
    let mut outcomes: Vec<Option<Result<ChunkScores>>> =
        (0..batch.len()).map(|_| None).collect();

    let scoreable: Vec<usize> =
        (0..batch.len()).filter(|&i| !batch[i].tokens.is_empty()).collect();
    let ids: Vec<&str> = scoreable.iter().map(|&i| batch[i].session.as_str()).collect();
    let chunks: Vec<&[u8]> = scoreable.iter().map(|&i| batch[i].tokens.as_slice()).collect();
    for (&i, res) in scoreable.iter().zip(mgr.advance_batch(&ids, &chunks)) {
        outcomes[i] = Some(res);
    }

    for (req, outcome) in batch.into_iter().zip(outcomes) {
        let (scores, error) = match outcome {
            Some(Ok(s)) => (Some(s), None),
            Some(Err(e)) => (None, Some(format!("{e:#}"))),
            None if req.close => (None, None), // close-only ack
            None => (None, Some("empty chunk (and close not requested)".to_string())),
        };
        if req.close {
            mgr.close(&req.session);
        }
        // receiver may have hung up; that's fine
        let _ = req.respond.send(StreamResponse {
            session: req.session,
            scores,
            error,
            latency: req.submitted.elapsed(),
            resident_sessions: mgr.len(),
            resident_bytes: mgr.resident_bytes(),
        });
    }
}

/// Turn a worker's possibly-failed response into a `Result`.
pub fn into_result(resp: StreamResponse) -> Result<StreamResponse> {
    match &resp.error {
        Some(e) => Err(anyhow!("stream session '{}': {e}", resp.session)),
        None => Ok(resp),
    }
}
