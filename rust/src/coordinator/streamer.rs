//! Streaming request path: long-context sessions served through the
//! coordinator. Clients submit chunks tagged with a session id; a
//! dedicated worker thread owns the `stream::SessionManager` (per-model)
//! and answers each chunk incrementally, so a stream's total length is
//! unbounded while its resident footprint stays constant.
//!
//!   clients ──submit_chunk()──▶ stream worker ──▶ SessionManager
//!                                (fused drain)      (budget + LRU)
//!
//! The worker drains up to [`STREAM_MAX_BATCH`] requests arriving in
//! the same [`STREAM_MAX_WAIT`] window and hands them to
//! `SessionManager::advance_batch` in one call, which fuses them into
//! length-compatible batched forwards
//! (`NativeModel::forward_chunk_batch`), padding the remainder inside
//! the fused `Batch` — the cross-chunk session batching the roadmap
//! called for. Per-session submission order is preserved even when one
//! session's chunks repeat within a drain window (duplicates advance in
//! ordered fused waves), and none of the window's sessions can be
//! LRU-evicted while the window is being served.
//!
//! This path runs the native Performer stack — it never touches PJRT,
//! so it works in stub builds and scales past any compiled artifact
//! length.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::{trace, MetricsRegistry};
use crate::stream::{ChunkScores, SessionConfig, SessionManager};
use crate::train::NativeModel;

use super::batcher::collect_batch;
use super::metrics::{Metrics, PersistMetrics};

/// Most chunk submissions one drain fuses into a batched forward.
pub const STREAM_MAX_BATCH: usize = 8;

/// Longest the worker waits to fill a batch after the first request —
/// the actual window is adaptive (`batcher::adaptive_wait`): it shrinks
/// as the drain fills and collapses to zero at a full batch.
pub const STREAM_MAX_WAIT: Duration = Duration::from_millis(2);

/// What a [`StreamRequest`] asks the worker to do.
#[derive(Clone, Debug)]
pub enum StreamOp {
    /// score the request's `tokens` as the session's next chunk
    Chunk,
    /// snapshot every live session into the directory (migration
    /// export); acts as a barrier, capturing exactly the chunks
    /// submitted before it
    CheckpointAll(PathBuf),
    /// incremental export: re-snapshot only the sessions dirty since
    /// the directory's previous export, retain the rest (same barrier
    /// semantics as [`Self::CheckpointAll`])
    CheckpointDelta(PathBuf),
    /// adopt every session checkpointed in the directory
    RestoreFrom(PathBuf),
    /// evacuate: snapshot every live session into the directory, then
    /// close them all (same barrier semantics as
    /// [`Self::CheckpointAll`]) — the migration hand-off the networked
    /// router's live rebalance is built on
    Drain(PathBuf),
}

/// One streaming request: the next chunk of a session's token stream, a
/// close notice (empty `tokens` + `close`), or a persistence control op.
pub struct StreamRequest {
    /// session id the request addresses (empty for control ops)
    pub session: String,
    /// the session's next chunk of tokens (empty for close/control)
    pub tokens: Vec<u8>,
    /// release the session's state after processing this request
    pub close: bool,
    /// what to do (score a chunk, checkpoint, restore)
    pub op: StreamOp,
    /// where the worker sends the [`StreamResponse`]
    pub respond: Sender<StreamResponse>,
    /// submission time, for end-to-end latency accounting
    pub submitted: Instant,
}

/// Incremental answer for one chunk.
#[derive(Clone, Debug)]
pub struct StreamResponse {
    /// session id the response belongs to
    pub session: String,
    /// per-token scores for this chunk (None for a close-only request,
    /// a control op, or an error)
    pub scores: Option<ChunkScores>,
    /// error message when the request failed (None on success)
    pub error: Option<String>,
    /// sessions written/adopted by a control op (0 for chunk requests)
    pub affected: usize,
    /// end-to-end latency from submission to response
    pub latency: Duration,
    /// sessions resident after this request
    pub resident_sessions: usize,
    /// carried-state bytes resident after this request
    pub resident_bytes: usize,
}

impl StreamResponse {
    /// Whether the request succeeded.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A running stream pool: worker thread + its request queue.
pub(crate) struct StreamPool {
    pub(crate) tx: Sender<StreamRequest>,
    pub(crate) worker: Option<JoinHandle<()>>,
    /// durability gauges, mirrored from the worker's session manager
    pub(crate) persist: Arc<PersistMetrics>,
    /// serving metrics: chunk requests, fused-window sizes, latency
    pub(crate) metrics: Arc<Metrics>,
}

impl StreamPool {
    /// Spawn the worker owning a session manager over `model`, fusing
    /// up to `max_batch` same-window submissions per forward. The
    /// pool's instruments are registered under `stream_{name}_*` /
    /// `persist_{name}_*` in `reg`.
    pub(crate) fn spawn(
        name: &str,
        model: Arc<NativeModel>,
        cfg: SessionConfig,
        max_batch: usize,
        max_wait: Duration,
        reg: &MetricsRegistry,
    ) -> Result<StreamPool> {
        // validate streamability up front, on the caller's thread
        let mut mgr = SessionManager::new(model, cfg)?;
        let (tx, rx) = channel::<StreamRequest>();
        let max_batch = max_batch.max(1);
        let persist = Arc::new(PersistMetrics::registered(reg, &format!("persist_{name}")));
        let metrics = Arc::new(Metrics::registered(reg, &format!("stream_{name}")));
        let persist2 = persist.clone();
        let metrics2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name(format!("stream-{name}"))
            .spawn(move || stream_loop(&rx, &mut mgr, max_batch, max_wait, &persist2, &metrics2))?;
        Ok(StreamPool { tx, worker: Some(worker), persist, metrics })
    }

    pub(crate) fn shutdown(mut self) {
        drop(self.tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn stream_loop(
    rx: &Receiver<StreamRequest>,
    mgr: &mut SessionManager,
    max_batch: usize,
    max_wait: Duration,
    persist: &PersistMetrics,
    metrics: &Metrics,
) {
    while let Some(batch) = collect_batch(rx, max_batch, max_wait) {
        let _window = trace::span_n("serve_window", batch.len() as u64);
        serve_stream_batch(batch, mgr, metrics);
        persist.record(&mgr.stats());
    }
}

/// Per-request result of serving one drained window.
enum Outcome {
    /// close-only or empty request — nothing was scored
    Nothing,
    Scores(Result<ChunkScores>),
    /// a persistence control op, carrying the session count it touched
    Control(Result<usize>),
}

/// Advance one run of scoreable requests as a single fused
/// `advance_batch` call.
fn flush_run(
    run: &mut Vec<usize>,
    batch: &[StreamRequest],
    mgr: &mut SessionManager,
    outcomes: &mut [Outcome],
) {
    if run.is_empty() {
        return;
    }
    let ids: Vec<&str> = run.iter().map(|&i| batch[i].session.as_str()).collect();
    let chunks: Vec<&[u8]> = run.iter().map(|&i| batch[i].tokens.as_slice()).collect();
    for (&i, res) in run.iter().zip(mgr.advance_batch(&ids, &chunks)) {
        outcomes[i] = Outcome::Scores(res);
    }
    run.clear();
}

/// Answer one drained batch: everything scoreable goes to
/// `SessionManager::advance_batch` in fused runs, which split into
/// length-compatible waves, advance repeated session ids in submission
/// order, and never evict any of the window's sessions while serving
/// it. Persistence control ops (checkpoint/restore) are barriers within
/// the window: chunks submitted before a checkpoint are scored before
/// the snapshot is taken, chunks after it continue on the
/// checkpointed-then-advanced state. A request's `close` takes effect
/// after the whole window's scoring — a chunk for the same session
/// queued behind a close-carrying chunk in one drain window continues
/// the stream rather than racing the teardown.
fn serve_stream_batch(batch: Vec<StreamRequest>, mgr: &mut SessionManager, metrics: &Metrics) {
    let tokens: usize = batch.iter().map(|r| r.tokens.len()).sum();
    metrics.observe_batch(batch.len(), tokens);
    let mut outcomes: Vec<Outcome> = (0..batch.len()).map(|_| Outcome::Nothing).collect();

    let mut run: Vec<usize> = Vec::new();
    for i in 0..batch.len() {
        match &batch[i].op {
            StreamOp::Chunk => {
                if !batch[i].tokens.is_empty() {
                    run.push(i);
                }
            }
            StreamOp::CheckpointAll(dir) => {
                flush_run(&mut run, &batch, mgr, &mut outcomes);
                outcomes[i] = Outcome::Control(mgr.checkpoint_all(dir));
            }
            StreamOp::CheckpointDelta(dir) => {
                flush_run(&mut run, &batch, mgr, &mut outcomes);
                outcomes[i] = Outcome::Control(mgr.checkpoint_delta(dir).map(|d| d.written));
            }
            StreamOp::RestoreFrom(dir) => {
                flush_run(&mut run, &batch, mgr, &mut outcomes);
                outcomes[i] = Outcome::Control(mgr.restore_from(dir));
            }
            StreamOp::Drain(dir) => {
                flush_run(&mut run, &batch, mgr, &mut outcomes);
                outcomes[i] = Outcome::Control(mgr.drain_to(dir));
            }
        }
    }
    flush_run(&mut run, &batch, mgr, &mut outcomes);

    for (req, outcome) in batch.into_iter().zip(outcomes) {
        let (scores, error, affected) = match outcome {
            Outcome::Scores(Ok(s)) => (Some(s), None, 0),
            Outcome::Scores(Err(e)) => (None, Some(format!("{e:#}")), 0),
            Outcome::Control(Ok(n)) => (None, None, n),
            Outcome::Control(Err(e)) => (None, Some(format!("{e:#}")), 0),
            Outcome::Nothing if req.close => (None, None, 0), // close-only ack
            Outcome::Nothing => {
                (None, Some("empty chunk (and close not requested)".to_string()), 0)
            }
        };
        if error.is_some() {
            metrics.errors.inc();
        }
        metrics.observe_latency(req.submitted.elapsed());
        if req.close {
            mgr.close(&req.session);
        }
        // receiver may have hung up; that's fine
        let _ = req.respond.send(StreamResponse {
            session: req.session,
            scores,
            error,
            affected,
            latency: req.submitted.elapsed(),
            resident_sessions: mgr.len(),
            resident_bytes: mgr.resident_bytes(),
        });
    }
}

/// Turn a worker's possibly-failed response into a `Result`.
pub fn into_result(resp: StreamResponse) -> Result<StreamResponse> {
    match &resp.error {
        Some(e) => Err(anyhow!("stream session '{}': {e}", resp.session)),
        None => Ok(resp),
    }
}
