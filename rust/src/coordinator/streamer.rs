//! Streaming request path: long-context sessions served through the
//! coordinator. Clients submit chunks tagged with a session id; a
//! dedicated worker thread owns the `stream::SessionManager` (per-model)
//! and answers each chunk incrementally, so a stream's total length is
//! unbounded while its resident footprint stays constant.
//!
//!   clients ──submit_chunk()──▶ stream worker ──▶ SessionManager
//!                                                   (budget + LRU)
//!
//! This path runs the native Performer stack — it never touches PJRT,
//! so it works in stub builds and scales past any compiled artifact
//! length.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::stream::{ChunkScores, SessionConfig, SessionManager};
use crate::train::NativeModel;

/// One streaming request: the next chunk of a session's token stream,
/// or a close notice (empty `tokens` + `close`).
pub struct StreamRequest {
    pub session: String,
    pub tokens: Vec<u8>,
    /// release the session's state after processing this request
    pub close: bool,
    pub respond: Sender<StreamResponse>,
    pub submitted: Instant,
}

/// Incremental answer for one chunk.
#[derive(Clone, Debug)]
pub struct StreamResponse {
    pub session: String,
    /// per-token scores for this chunk (None for a close-only request
    /// or an error)
    pub scores: Option<ChunkScores>,
    pub error: Option<String>,
    pub latency: Duration,
    /// sessions resident after this request
    pub resident_sessions: usize,
    /// carried-state bytes resident after this request
    pub resident_bytes: usize,
}

impl StreamResponse {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A running stream pool: worker thread + its request queue.
pub(crate) struct StreamPool {
    pub(crate) tx: Sender<StreamRequest>,
    pub(crate) worker: Option<JoinHandle<()>>,
}

impl StreamPool {
    /// Spawn the worker owning a session manager over `model`.
    pub(crate) fn spawn(
        name: &str,
        model: Arc<NativeModel>,
        cfg: SessionConfig,
    ) -> Result<StreamPool> {
        // validate streamability up front, on the caller's thread
        let mut mgr = SessionManager::new(model, cfg)?;
        let (tx, rx) = channel::<StreamRequest>();
        let worker = std::thread::Builder::new()
            .name(format!("stream-{name}"))
            .spawn(move || stream_loop(&rx, &mut mgr))?;
        Ok(StreamPool { tx, worker: Some(worker) })
    }

    pub(crate) fn shutdown(mut self) {
        drop(self.tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn stream_loop(rx: &Receiver<StreamRequest>, mgr: &mut SessionManager) {
    while let Ok(req) = rx.recv() {
        let (scores, error) = if req.tokens.is_empty() {
            if req.close {
                (None, None) // close-only ack
            } else {
                (None, Some("empty chunk (and close not requested)".to_string()))
            }
        } else {
            match mgr.advance(&req.session, &req.tokens) {
                Ok(s) => (Some(s), None),
                Err(e) => (None, Some(format!("{e:#}"))),
            }
        };
        if req.close {
            mgr.close(&req.session);
        }
        // receiver may have hung up; that's fine
        let _ = req.respond.send(StreamResponse {
            session: req.session,
            scores,
            error,
            latency: req.submitted.elapsed(),
            resident_sessions: mgr.len(),
            resident_bytes: mgr.resident_bytes(),
        });
    }
}

/// Turn a worker's possibly-failed response into a `Result`.
pub fn into_result(resp: StreamResponse) -> Result<StreamResponse> {
    match &resp.error {
        Some(e) => Err(anyhow!("stream session '{}': {e}", resp.session)),
        None => Ok(resp),
    }
}
