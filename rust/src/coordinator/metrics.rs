//! Serving metrics: request counters, latency histogram, batch-size
//! distribution — what the paper's throughput claims are measured with
//! on this testbed — plus the durability gauges of a streaming pool's
//! spill/checkpoint tier.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::stream::SessionStats;

/// Lock-free latency histogram with exponential buckets (µs scale).
pub struct Metrics {
    /// requests answered
    pub requests: AtomicU64,
    /// batches executed
    pub batches: AtomicU64,
    /// tokens processed
    pub tokens: AtomicU64,
    /// failed batches
    pub errors: AtomicU64,
    /// bucket i counts latencies in [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 32],
    total_latency_us: AtomicU64,
    batch_size_sum: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            total_latency_us: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    /// Record one request's end-to-end latency.
    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch (its request count and token count).
    pub fn observe_batch(&self, size: usize, tokens: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    /// Mean request latency over every observation.
    pub fn mean_latency(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_micros(self.total_latency_us.load(Ordering::Relaxed) / n)
    }

    /// Mean requests fused per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency quantile from the histogram (upper bound of
    /// the bucket containing the q-quantile).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1 << 31)
    }

    /// One-line human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} mean_latency={:?} p50<={:?} p99<={:?} errors={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.errors.load(Ordering::Relaxed),
        )
    }
}

/// Durability gauges for one streaming pool's persistence tier: spill
/// write-back progress, rehydrations, checkpoint bytes, delta-export
/// retention and kernel-redraw churn. The stream worker mirrors its
/// `SessionManager` counters in here after every drain window, so
/// readers on other threads (the `xp stream` report, ops tooling) see
/// them without touching the worker's state; background spill commits
/// land on the *next* mirror after they complete.
#[derive(Default)]
pub struct PersistMetrics {
    /// sessions currently demoted to the spill tier (in flight + on disk)
    pub spilled_sessions: AtomicU64,
    /// cumulative demote-to-spill events (enqueues)
    pub spills: AtomicU64,
    /// cumulative spill-to-RAM promotions
    pub rehydrations: AtomicU64,
    /// cumulative snapshot bytes written (spills + checkpoint exports)
    pub checkpoint_bytes: AtomicU64,
    /// cumulative wall time spent rehydrating, nanoseconds
    pub rehydrate_nanos: AtomicU64,
    /// spills parked awaiting their background write (gauge)
    pub pending_spills: AtomicU64,
    /// background spill writes committed to the spill manifest
    pub spill_commits: AtomicU64,
    /// queued spill writes canceled by a take-back or close
    pub spill_cancels: AtomicU64,
    /// background spill writes that failed (sessions stay resident-readable)
    pub spill_write_failures: AtomicU64,
    /// serving-thread nanoseconds spent enqueueing spills
    pub spill_enqueue_nanos: AtomicU64,
    /// writer-thread nanoseconds spent writing + committing spills
    pub spill_write_nanos: AtomicU64,
    /// advances that crossed ≥1 kernel-redraw epoch boundary
    pub epoch_crossings: AtomicU64,
    /// per-(layer, head) state resets caused by redraw crossings
    pub state_resets: AtomicU64,
    /// snapshot records written by delta exports
    pub delta_written: AtomicU64,
    /// clean records retained (no snapshot IO) by delta exports
    pub delta_retained: AtomicU64,
}

impl PersistMetrics {
    /// Mirror the manager's counters (gauge semantics: last write wins).
    pub fn record(&self, st: &SessionStats) {
        self.spilled_sessions.store(st.spilled as u64, Ordering::Relaxed);
        self.spills.store(st.spills, Ordering::Relaxed);
        self.rehydrations.store(st.rehydrations, Ordering::Relaxed);
        self.checkpoint_bytes.store(st.checkpoint_bytes, Ordering::Relaxed);
        self.rehydrate_nanos.store(st.rehydrate_nanos, Ordering::Relaxed);
        self.pending_spills.store(st.pending_spills as u64, Ordering::Relaxed);
        self.spill_commits.store(st.spill_commits, Ordering::Relaxed);
        self.spill_cancels.store(st.spill_cancels, Ordering::Relaxed);
        self.spill_write_failures.store(st.spill_write_failures, Ordering::Relaxed);
        self.spill_enqueue_nanos.store(st.spill_enqueue_nanos, Ordering::Relaxed);
        self.spill_write_nanos.store(st.spill_write_nanos, Ordering::Relaxed);
        self.epoch_crossings.store(st.epoch_crossings, Ordering::Relaxed);
        self.state_resets.store(st.state_resets, Ordering::Relaxed);
        self.delta_written.store(st.delta_written, Ordering::Relaxed);
        self.delta_retained.store(st.delta_retained, Ordering::Relaxed);
    }

    /// Mean wall time of one spill-to-RAM promotion.
    pub fn mean_rehydrate_latency(&self) -> Duration {
        let n = self.rehydrations.load(Ordering::Relaxed);
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.rehydrate_nanos.load(Ordering::Relaxed) / n)
    }

    /// Mean serving-thread cost of enqueueing one spill — what eviction
    /// pays now that the write itself runs on the background thread.
    pub fn mean_spill_enqueue_latency(&self) -> Duration {
        let n = self.spills.load(Ordering::Relaxed);
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.spill_enqueue_nanos.load(Ordering::Relaxed) / n)
    }

    /// Mean writer-thread cost of one committed background spill write.
    pub fn mean_spill_write_latency(&self) -> Duration {
        let n = self.spill_commits.load(Ordering::Relaxed);
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.spill_write_nanos.load(Ordering::Relaxed) / n)
    }

    /// One-line human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "spilled={} spills={} pending={} commits={} cancels={} rehydrations={} \
             checkpoint_bytes={} mean_enqueue={:?} mean_write={:?} mean_rehydrate={:?} \
             epoch_crossings={} state_resets={} delta_written={} delta_retained={}",
            self.spilled_sessions.load(Ordering::Relaxed),
            self.spills.load(Ordering::Relaxed),
            self.pending_spills.load(Ordering::Relaxed),
            self.spill_commits.load(Ordering::Relaxed),
            self.spill_cancels.load(Ordering::Relaxed),
            self.rehydrations.load(Ordering::Relaxed),
            self.checkpoint_bytes.load(Ordering::Relaxed),
            self.mean_spill_enqueue_latency(),
            self.mean_spill_write_latency(),
            self.mean_rehydrate_latency(),
            self.epoch_crossings.load(Ordering::Relaxed),
            self.state_resets.load(Ordering::Relaxed),
            self.delta_written.load(Ordering::Relaxed),
            self.delta_retained.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accumulates() {
        let m = Metrics::default();
        m.observe_latency(Duration::from_micros(100));
        m.observe_latency(Duration::from_micros(300));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        let mean = m.mean_latency();
        assert!(mean >= Duration::from_micros(190) && mean <= Duration::from_micros(210));
    }

    #[test]
    fn quantile_ordering() {
        let m = Metrics::default();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(10_000));
        }
        assert!(m.latency_quantile(0.5) < m.latency_quantile(0.99));
        assert!(m.latency_quantile(0.99) >= Duration::from_micros(8_000));
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.observe_batch(4, 512);
        m.observe_batch(8, 1024);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.tokens.load(Ordering::Relaxed), 1536);
    }

    #[test]
    fn persist_gauges_mirror_session_stats() {
        let p = PersistMetrics::default();
        assert_eq!(p.mean_rehydrate_latency(), Duration::ZERO);
        assert_eq!(p.mean_spill_enqueue_latency(), Duration::ZERO);
        assert_eq!(p.mean_spill_write_latency(), Duration::ZERO);
        let st = SessionStats {
            spilled: 3,
            spills: 7,
            rehydrations: 4,
            checkpoint_bytes: 9000,
            rehydrate_nanos: 8_000_000,
            pending_spills: 2,
            spill_commits: 5,
            spill_cancels: 1,
            spill_enqueue_nanos: 700,
            spill_write_nanos: 10_000,
            epoch_crossings: 6,
            state_resets: 24,
            delta_written: 3,
            delta_retained: 9,
            ..Default::default()
        };
        p.record(&st);
        assert_eq!(p.spills.load(Ordering::Relaxed), 7);
        assert_eq!(p.mean_rehydrate_latency(), Duration::from_nanos(2_000_000));
        assert_eq!(p.mean_spill_enqueue_latency(), Duration::from_nanos(100));
        assert_eq!(p.mean_spill_write_latency(), Duration::from_nanos(2_000));
        let s = p.summary();
        assert!(s.contains("spills=7") && s.contains("checkpoint_bytes=9000"), "{s}");
        assert!(s.contains("pending=2") && s.contains("commits=5"), "{s}");
        assert!(s.contains("epoch_crossings=6") && s.contains("delta_retained=9"), "{s}");
    }
}
