//! Serving metrics: request counters, latency histogram, batch-size
//! distribution — what the paper's throughput claims are measured with
//! on this testbed — plus the durability gauges of a streaming pool's
//! spill/checkpoint tier.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::stream::SessionStats;

/// Lock-free latency histogram with exponential buckets (µs scale).
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    /// bucket i counts latencies in [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 32],
    total_latency_us: AtomicU64,
    batch_size_sum: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            total_latency_us: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, size: usize, tokens: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn mean_latency(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_micros(self.total_latency_us.load(Ordering::Relaxed) / n)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency quantile from the histogram (upper bound of
    /// the bucket containing the q-quantile).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1 << 31)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} mean_latency={:?} p50<={:?} p99<={:?} errors={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.errors.load(Ordering::Relaxed),
        )
    }
}

/// Durability gauges for one streaming pool's persistence tier: spills,
/// rehydrations, checkpoint bytes written and rehydration latency. The
/// stream worker mirrors its `SessionManager` counters in here after
/// every drain window, so readers on other threads (the `xp stream`
/// report, ops tooling) see them without touching the worker's state.
#[derive(Default)]
pub struct PersistMetrics {
    /// sessions currently demoted to the spill tier
    pub spilled_sessions: AtomicU64,
    /// cumulative demote-to-disk events
    pub spills: AtomicU64,
    /// cumulative disk-to-RAM promotions
    pub rehydrations: AtomicU64,
    /// cumulative snapshot bytes written (spills + checkpoint exports)
    pub checkpoint_bytes: AtomicU64,
    /// cumulative wall time spent rehydrating, nanoseconds
    pub rehydrate_nanos: AtomicU64,
}

impl PersistMetrics {
    /// Mirror the manager's counters (gauge semantics: last write wins).
    pub fn record(&self, st: &SessionStats) {
        self.spilled_sessions.store(st.spilled as u64, Ordering::Relaxed);
        self.spills.store(st.spills, Ordering::Relaxed);
        self.rehydrations.store(st.rehydrations, Ordering::Relaxed);
        self.checkpoint_bytes.store(st.checkpoint_bytes, Ordering::Relaxed);
        self.rehydrate_nanos.store(st.rehydrate_nanos, Ordering::Relaxed);
    }

    /// Mean wall time of one disk-to-RAM promotion.
    pub fn mean_rehydrate_latency(&self) -> Duration {
        let n = self.rehydrations.load(Ordering::Relaxed);
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.rehydrate_nanos.load(Ordering::Relaxed) / n)
    }

    pub fn summary(&self) -> String {
        format!(
            "spilled={} spills={} rehydrations={} checkpoint_bytes={} mean_rehydrate={:?}",
            self.spilled_sessions.load(Ordering::Relaxed),
            self.spills.load(Ordering::Relaxed),
            self.rehydrations.load(Ordering::Relaxed),
            self.checkpoint_bytes.load(Ordering::Relaxed),
            self.mean_rehydrate_latency(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accumulates() {
        let m = Metrics::default();
        m.observe_latency(Duration::from_micros(100));
        m.observe_latency(Duration::from_micros(300));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        let mean = m.mean_latency();
        assert!(mean >= Duration::from_micros(190) && mean <= Duration::from_micros(210));
    }

    #[test]
    fn quantile_ordering() {
        let m = Metrics::default();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(10_000));
        }
        assert!(m.latency_quantile(0.5) < m.latency_quantile(0.99));
        assert!(m.latency_quantile(0.99) >= Duration::from_micros(8_000));
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.observe_batch(4, 512);
        m.observe_batch(8, 1024);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.tokens.load(Ordering::Relaxed), 1536);
    }

    #[test]
    fn persist_gauges_mirror_session_stats() {
        let p = PersistMetrics::default();
        assert_eq!(p.mean_rehydrate_latency(), Duration::ZERO);
        let st = SessionStats {
            spilled: 3,
            spills: 7,
            rehydrations: 4,
            checkpoint_bytes: 9000,
            rehydrate_nanos: 8_000_000,
            ..Default::default()
        };
        p.record(&st);
        assert_eq!(p.spills.load(Ordering::Relaxed), 7);
        assert_eq!(p.mean_rehydrate_latency(), Duration::from_nanos(2_000_000));
        let s = p.summary();
        assert!(s.contains("spills=7") && s.contains("checkpoint_bytes=9000"), "{s}");
    }
}
