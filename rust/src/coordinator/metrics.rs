//! Serving metrics: request counters, latency histogram, batch-size
//! distribution — what the paper's throughput claims are measured with
//! on this testbed — plus the durability gauges of a streaming pool's
//! spill/checkpoint tier.
//!
//! Both structs are built on the `obs` registry types ([`Counter`],
//! [`Gauge`], [`Histogram`]): every field is a lock-free handle with
//! bounded memory (the latency distribution lives in 32 fixed log2
//! buckets, never a sample vector), and the `registered` constructors
//! publish the same handles into a [`MetricsRegistry`] so one
//! Prometheus dump covers every pool.

use std::time::Duration;

use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::stream::SessionStats;

/// Lock-free serving counters + a bounded log2 latency histogram.
#[derive(Default)]
pub struct Metrics {
    /// requests answered
    pub requests: Counter,
    /// batches executed
    pub batches: Counter,
    /// tokens processed
    pub tokens: Counter,
    /// failed batches
    pub errors: Counter,
    /// request latency distribution, µs log2 buckets (O(1) memory in
    /// the request count)
    latency_us: Histogram,
    batch_size_sum: Counter,
}

impl Metrics {
    /// Metrics whose instruments are registered under `prefix_*` in
    /// `reg` — the registry's Prometheus dump then exposes them; the
    /// returned struct records through the very same atomics.
    pub fn registered(reg: &MetricsRegistry, prefix: &str) -> Metrics {
        Metrics {
            requests: reg.counter(&format!("{prefix}_requests_total")),
            batches: reg.counter(&format!("{prefix}_batches_total")),
            tokens: reg.counter(&format!("{prefix}_tokens_total")),
            errors: reg.counter(&format!("{prefix}_errors_total")),
            latency_us: reg.histogram(&format!("{prefix}_latency_us")),
            batch_size_sum: reg.counter(&format!("{prefix}_batch_size_sum")),
        }
    }

    /// Record one request's end-to-end latency.
    pub fn observe_latency(&self, d: Duration) {
        self.latency_us.observe_duration(d);
        self.requests.inc();
    }

    /// Record one executed batch (its request count and token count).
    pub fn observe_batch(&self, size: usize, tokens: usize) {
        self.batches.inc();
        self.batch_size_sum.add(size as u64);
        self.tokens.add(tokens as u64);
    }

    /// Mean request latency over every observation.
    pub fn mean_latency(&self) -> Duration {
        let n = self.latency_us.count().max(1);
        Duration::from_micros(self.latency_us.sum() / n)
    }

    /// Mean requests fused per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get().max(1);
        self.batch_size_sum.get() as f64 / b as f64
    }

    /// Approximate latency quantile from the histogram (upper bound of
    /// the bucket containing the q-quantile).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        self.latency_us.quantile_duration(q)
    }

    /// The latency distribution itself (for exports and tests).
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latency_us
    }

    /// One-line human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} mean_latency={:?} p50<={:?} p99<={:?} errors={}",
            self.requests.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.errors.get(),
        )
    }
}

/// Durability gauges for one streaming pool's persistence tier: spill
/// write-back progress, rehydrations, checkpoint bytes, delta-export
/// retention, write-back staging pressure and kernel-redraw churn. The
/// stream worker mirrors its `SessionManager` counters in here after
/// every drain window, so readers on other threads (the `xp stream`
/// report, ops tooling) see them without touching the worker's state;
/// background spill commits land on the *next* mirror after they
/// complete.
#[derive(Default)]
pub struct PersistMetrics {
    /// total resident carried-state bytes across the pool's sessions
    pub resident_bytes: Gauge,
    /// steady-state resident bytes one session costs under the pool's
    /// configured state precision — bf16 mode reads ~2× lower than f32,
    /// which is the whole point of the reduced-precision state
    pub per_session_bytes: Gauge,
    /// sessions currently demoted to the spill tier (in flight + on disk)
    pub spilled_sessions: Gauge,
    /// cumulative demote-to-spill events (enqueues)
    pub spills: Gauge,
    /// cumulative spill-to-RAM promotions
    pub rehydrations: Gauge,
    /// cumulative snapshot bytes written (spills + checkpoint exports)
    pub checkpoint_bytes: Gauge,
    /// cumulative wall time spent rehydrating, nanoseconds
    pub rehydrate_nanos: Gauge,
    /// spills parked awaiting their background write (gauge)
    pub pending_spills: Gauge,
    /// bytes of encoded snapshots parked awaiting their background
    /// write — the write-back staging footprint the high-water mark
    /// bounds
    pub pending_spill_bytes: Gauge,
    /// spills refused at the pending-byte high-water mark (each degraded
    /// to a loud eviction)
    pub spill_sheds: Gauge,
    /// background spill writes committed to the spill manifest
    pub spill_commits: Gauge,
    /// queued spill writes canceled by a take-back or close
    pub spill_cancels: Gauge,
    /// background spill writes that failed (sessions stay resident-readable)
    pub spill_write_failures: Gauge,
    /// serving-thread nanoseconds spent enqueueing spills
    pub spill_enqueue_nanos: Gauge,
    /// writer-thread nanoseconds spent writing + committing spills
    pub spill_write_nanos: Gauge,
    /// advances that crossed ≥1 kernel-redraw epoch boundary
    pub epoch_crossings: Gauge,
    /// per-(layer, head) state resets caused by redraw crossings
    pub state_resets: Gauge,
    /// snapshot records written by delta exports
    pub delta_written: Gauge,
    /// clean records retained (no snapshot IO) by delta exports
    pub delta_retained: Gauge,
}

impl PersistMetrics {
    /// PersistMetrics whose gauges are registered under `prefix_*` in
    /// `reg`, for the registry's Prometheus dump.
    pub fn registered(reg: &MetricsRegistry, prefix: &str) -> PersistMetrics {
        let g = |name: &str| reg.gauge(&format!("{prefix}_{name}"));
        PersistMetrics {
            resident_bytes: g("resident_bytes"),
            per_session_bytes: g("per_session_bytes"),
            spilled_sessions: g("spilled_sessions"),
            spills: g("spills_total"),
            rehydrations: g("rehydrations_total"),
            checkpoint_bytes: g("checkpoint_bytes_total"),
            rehydrate_nanos: g("rehydrate_nanos_total"),
            pending_spills: g("pending_spills"),
            pending_spill_bytes: g("pending_spill_bytes"),
            spill_sheds: g("spill_sheds_total"),
            spill_commits: g("spill_commits_total"),
            spill_cancels: g("spill_cancels_total"),
            spill_write_failures: g("spill_write_failures_total"),
            spill_enqueue_nanos: g("spill_enqueue_nanos_total"),
            spill_write_nanos: g("spill_write_nanos_total"),
            epoch_crossings: g("epoch_crossings_total"),
            state_resets: g("state_resets_total"),
            delta_written: g("delta_written_total"),
            delta_retained: g("delta_retained_total"),
        }
    }

    /// Mirror the manager's counters (gauge semantics: last write wins).
    pub fn record(&self, st: &SessionStats) {
        self.resident_bytes.set(st.resident_bytes as u64);
        self.per_session_bytes.set(st.per_session_bytes as u64);
        self.spilled_sessions.set(st.spilled as u64);
        self.spills.set(st.spills);
        self.rehydrations.set(st.rehydrations);
        self.checkpoint_bytes.set(st.checkpoint_bytes);
        self.rehydrate_nanos.set(st.rehydrate_nanos);
        self.pending_spills.set(st.pending_spills as u64);
        self.pending_spill_bytes.set(st.spill_pending_bytes);
        self.spill_sheds.set(st.spill_sheds);
        self.spill_commits.set(st.spill_commits);
        self.spill_cancels.set(st.spill_cancels);
        self.spill_write_failures.set(st.spill_write_failures);
        self.spill_enqueue_nanos.set(st.spill_enqueue_nanos);
        self.spill_write_nanos.set(st.spill_write_nanos);
        self.epoch_crossings.set(st.epoch_crossings);
        self.state_resets.set(st.state_resets);
        self.delta_written.set(st.delta_written);
        self.delta_retained.set(st.delta_retained);
    }

    /// Mean wall time of one spill-to-RAM promotion.
    pub fn mean_rehydrate_latency(&self) -> Duration {
        let n = self.rehydrations.get();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.rehydrate_nanos.get() / n)
    }

    /// Mean serving-thread cost of enqueueing one spill — what eviction
    /// pays now that the write itself runs on the background thread.
    pub fn mean_spill_enqueue_latency(&self) -> Duration {
        let n = self.spills.get();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.spill_enqueue_nanos.get() / n)
    }

    /// Mean writer-thread cost of one committed background spill write.
    pub fn mean_spill_write_latency(&self) -> Duration {
        let n = self.spill_commits.get();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.spill_write_nanos.get() / n)
    }

    /// One-line human-readable summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "resident_bytes={} per_session_bytes={} spilled={} spills={} pending={} \
             pending_bytes={} sheds={} commits={} \
             cancels={} rehydrations={} checkpoint_bytes={} mean_enqueue={:?} \
             mean_write={:?} mean_rehydrate={:?} epoch_crossings={} state_resets={} \
             delta_written={} delta_retained={}",
            self.resident_bytes.get(),
            self.per_session_bytes.get(),
            self.spilled_sessions.get(),
            self.spills.get(),
            self.pending_spills.get(),
            self.pending_spill_bytes.get(),
            self.spill_sheds.get(),
            self.spill_commits.get(),
            self.spill_cancels.get(),
            self.rehydrations.get(),
            self.checkpoint_bytes.get(),
            self.mean_spill_enqueue_latency(),
            self.mean_spill_write_latency(),
            self.mean_rehydrate_latency(),
            self.epoch_crossings.get(),
            self.state_resets.get(),
            self.delta_written.get(),
            self.delta_retained.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::HISTOGRAM_BUCKETS;

    #[test]
    fn latency_accumulates() {
        let m = Metrics::default();
        m.observe_latency(Duration::from_micros(100));
        m.observe_latency(Duration::from_micros(300));
        assert_eq!(m.requests.get(), 2);
        let mean = m.mean_latency();
        assert!(mean >= Duration::from_micros(190) && mean <= Duration::from_micros(210));
    }

    #[test]
    fn quantile_ordering() {
        let m = Metrics::default();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(10_000));
        }
        assert!(m.latency_quantile(0.5) < m.latency_quantile(0.99));
        assert!(m.latency_quantile(0.99) >= Duration::from_micros(8_000));
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.observe_batch(4, 512);
        m.observe_batch(8, 1024);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.tokens.get(), 1536);
    }

    #[test]
    fn latency_memory_is_bounded_in_requests() {
        // regression for the unbounded-sample-vector failure mode: the
        // distribution must stay a fixed bucket array however many
        // requests are observed
        let m = Metrics::default();
        assert_eq!(m.latency_histogram().bucket_counts().len(), HISTOGRAM_BUCKETS);
        for i in 0..50_000u64 {
            m.observe_latency(Duration::from_micros(1 + i % 4096));
        }
        assert_eq!(m.latency_histogram().bucket_counts().len(), HISTOGRAM_BUCKETS);
        assert_eq!(m.latency_histogram().count(), 50_000);
        assert_eq!(m.requests.get(), 50_000);
    }

    #[test]
    fn registered_metrics_share_the_registry_series() {
        let reg = MetricsRegistry::new();
        let m = Metrics::registered(&reg, "serve_test");
        m.observe_latency(Duration::from_micros(10));
        assert_eq!(reg.counter("serve_test_requests_total").get(), 1);
        assert_eq!(reg.histogram("serve_test_latency_us").count(), 1);
    }

    #[test]
    fn persist_gauges_mirror_session_stats() {
        let p = PersistMetrics::default();
        assert_eq!(p.mean_rehydrate_latency(), Duration::ZERO);
        assert_eq!(p.mean_spill_enqueue_latency(), Duration::ZERO);
        assert_eq!(p.mean_spill_write_latency(), Duration::ZERO);
        let st = SessionStats {
            resident_bytes: 4096,
            per_session_bytes: 2048,
            spilled: 3,
            spills: 7,
            rehydrations: 4,
            checkpoint_bytes: 9000,
            rehydrate_nanos: 8_000_000,
            pending_spills: 2,
            spill_pending_bytes: 1234,
            spill_sheds: 1,
            spill_commits: 5,
            spill_cancels: 1,
            spill_enqueue_nanos: 700,
            spill_write_nanos: 10_000,
            epoch_crossings: 6,
            state_resets: 24,
            delta_written: 3,
            delta_retained: 9,
            ..Default::default()
        };
        p.record(&st);
        assert_eq!(p.spills.get(), 7);
        assert_eq!(p.mean_rehydrate_latency(), Duration::from_nanos(2_000_000));
        assert_eq!(p.mean_spill_enqueue_latency(), Duration::from_nanos(100));
        assert_eq!(p.mean_spill_write_latency(), Duration::from_nanos(2_000));
        let s = p.summary();
        assert!(s.contains("spills=7") && s.contains("checkpoint_bytes=9000"), "{s}");
        assert!(s.contains("pending=2") && s.contains("commits=5"), "{s}");
        assert!(s.contains("pending_bytes=1234") && s.contains("sheds=1"), "{s}");
        assert!(s.contains("epoch_crossings=6") && s.contains("delta_retained=9"), "{s}");
        assert!(
            s.contains("resident_bytes=4096") && s.contains("per_session_bytes=2048"),
            "{s}"
        );
    }
}
