//! Serving metrics: request counters, latency histogram, batch-size
//! distribution — what the paper's throughput claims are measured with
//! on this testbed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free latency histogram with exponential buckets (µs scale).
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
    /// bucket i counts latencies in [2^i, 2^{i+1}) microseconds
    buckets: [AtomicU64; 32],
    total_latency_us: AtomicU64,
    batch_size_sum: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            total_latency_us: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn observe_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, size: usize, tokens: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(size as u64, Ordering::Relaxed);
        self.tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn mean_latency(&self) -> Duration {
        let n = self.requests.load(Ordering::Relaxed).max(1);
        Duration::from_micros(self.total_latency_us.load(Ordering::Relaxed) / n)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batch_size_sum.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency quantile from the histogram (upper bound of
    /// the bucket containing the q-quantile).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let total: u64 = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1 << 31)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} mean_batch={:.2} mean_latency={:?} p50<={:?} p99<={:?} errors={}",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            self.errors.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accumulates() {
        let m = Metrics::default();
        m.observe_latency(Duration::from_micros(100));
        m.observe_latency(Duration::from_micros(300));
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        let mean = m.mean_latency();
        assert!(mean >= Duration::from_micros(190) && mean <= Duration::from_micros(210));
    }

    #[test]
    fn quantile_ordering() {
        let m = Metrics::default();
        for _ in 0..90 {
            m.observe_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.observe_latency(Duration::from_micros(10_000));
        }
        assert!(m.latency_quantile(0.5) < m.latency_quantile(0.99));
        assert!(m.latency_quantile(0.99) >= Duration::from_micros(8_000));
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::default();
        m.observe_batch(4, 512);
        m.observe_batch(8, 1024);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.tokens.load(Ordering::Relaxed), 1536);
    }
}
