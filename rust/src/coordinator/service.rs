//! The serving coordinator: a router in front of per-model worker
//! threads, all executing through the single PJRT engine actor.
//!
//! Architecture (std threads; the registry has no tokio):
//!
//!   clients ──submit()──▶ router ──mpsc──▶ worker(model A) ─┐
//!                                 └─mpsc──▶ worker(model B) ─┼─▶ engine
//!                                                            │   actor
//!                                                            └──▶ PJRT
//!
//! Each worker runs the dynamic batcher loop: block on first request,
//! drain up to max_batch within max_wait, pad, execute, respond.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::configx::ServeConfig;
use crate::obs::MetricsRegistry;
use crate::runtime::{EngineHandle, Role, TensorFile};
use crate::stream::SessionConfig;
use crate::train::NativeModel;

use super::batcher::{collect_batch, serve_batch, ModelState, Request, Response};
use super::metrics::{Metrics, PersistMetrics};
use super::streamer::{
    into_result, StreamOp, StreamPool, StreamRequest, StreamResponse, STREAM_MAX_BATCH,
    STREAM_MAX_WAIT,
};

/// Handle to a running model pool.
struct Pool {
    tx: Sender<Request>,
    metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

/// The coordinator: owns the engine handle, all batched model pools,
/// all streaming session pools, and the metrics registry every pool's
/// instruments are published in.
pub struct Coordinator {
    engine: EngineHandle,
    pools: HashMap<String, Pool>,
    streams: HashMap<String, StreamPool>,
    registry: Arc<MetricsRegistry>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// A coordinator over one engine handle, with no pools started yet.
    pub fn new(engine: EngineHandle) -> Coordinator {
        Coordinator {
            engine,
            pools: HashMap::new(),
            streams: HashMap::new(),
            registry: Arc::new(MetricsRegistry::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// The metrics registry all of this coordinator's pools register
    /// their instruments in — snapshot it (e.g. via
    /// [`crate::obs::export::prometheus`]) for a full metrics dump.
    pub fn registry(&self) -> Arc<MetricsRegistry> {
        self.registry.clone()
    }

    /// Start a model pool serving `{artifact}_fwd` with weights from
    /// `{artifact}_init.bin`, optionally overlaid with a checkpoint.
    pub fn start_pool(&mut self, cfg: &ServeConfig, checkpoint: Option<&str>) -> Result<()> {
        let tag = cfg.artifact.clone();
        let fwd_name = format!("{tag}_fwd");
        let meta = self.engine.meta(&fwd_name)?;
        self.engine.warm(&fwd_name)?; // compile before serving traffic

        // load weights: init.bin, then optionally overlay a checkpoint
        let init = TensorFile::read(
            &self.engine.artifacts_dir().join(format!("{tag}_init.bin")),
        )
        .with_context(|| format!("weights for {tag}"))?;
        let overlay = match checkpoint {
            Some(p) => Some(TensorFile::read(std::path::Path::new(p))?),
            None => None,
        };
        let fetch = |prefix: &str, name: &str, elements: usize| -> Result<Vec<f32>> {
            let key = format!("{prefix}:{name}");
            let data = overlay
                .as_ref()
                .and_then(|tf| tf.get(&key))
                .or_else(|| init.get(&key))
                .map(|(_, d)| d.to_vec())
                .ok_or_else(|| anyhow!("missing weight {key}"))?;
            anyhow::ensure!(data.len() == elements, "weight {key} wrong size");
            Ok(data)
        };
        let mut params = Vec::new();
        let mut features = Vec::new();
        for slot in &meta.inputs {
            match slot.role {
                Role::Param => params.push(fetch("param", &slot.name, slot.elements())?),
                Role::Feature => features.push(fetch("feature", &slot.name, slot.elements())?),
                _ => {}
            }
        }

        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let metrics = Arc::new(Metrics::registered(&self.registry, &format!("serve_{tag}")));
        let max_batch = cfg.max_batch.min(meta.config.batch.max(1));
        let max_wait = Duration::from_millis(cfg.max_wait_ms);

        let state = Arc::new(ModelState {
            engine: self.engine.clone(),
            artifact: fwd_name,
            meta,
            params,
            features,
        });
        let mut workers = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let state = state.clone();
            let metrics = metrics.clone();
            let tag2 = tag.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("serve-{tag}-{w}"))
                    .spawn(move || {
                        worker_loop(rx, state, metrics, max_batch, max_wait, &tag2);
                    })?,
            );
        }
        self.pools.insert(tag, Pool { tx, metrics, workers });
        Ok(())
    }

    /// Submit a fill-mask request; returns the receiver for the response.
    pub fn submit(&self, model: &str, tokens: Vec<u8>) -> Result<Receiver<Response>> {
        let pool = self.pools.get(model).ok_or_else(|| anyhow!("no pool '{model}'"))?;
        let (rtx, rrx) = channel();
        pool.tx
            .send(Request {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                tokens,
                respond: rtx,
                submitted: Instant::now(),
            })
            .map_err(|_| anyhow!("pool '{model}' shut down"))?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn fill_mask(&self, model: &str, tokens: Vec<u8>) -> Result<Response> {
        let rx = self.submit(model, tokens)?;
        rx.recv().map_err(|_| anyhow!("worker dropped response"))
    }

    /// Submit and wait at most `deadline` — a wedged worker yields a
    /// timeout error instead of blocking the client forever.
    pub fn fill_mask_timeout(
        &self,
        model: &str,
        tokens: Vec<u8>,
        deadline: Duration,
    ) -> Result<Response> {
        let rx = self.submit(model, tokens)?;
        match rx.recv_timeout(deadline) {
            Ok(resp) => Ok(resp),
            Err(RecvTimeoutError::Timeout) => Err(anyhow!(
                "fill_mask on '{model}' timed out after {deadline:?}"
            )),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow!("worker dropped response")),
        }
    }

    /// Start a streaming session pool under `name`, serving chunked
    /// long-context inference over the native model (no artifacts/PJRT
    /// involved) with the default fused-batching window
    /// ([`STREAM_MAX_BATCH`]/[`STREAM_MAX_WAIT`]). Errors if the model
    /// is not streamable.
    pub fn start_stream_pool(
        &mut self,
        name: &str,
        model: Arc<NativeModel>,
        cfg: SessionConfig,
    ) -> Result<()> {
        self.start_stream_pool_batched(name, model, cfg, STREAM_MAX_BATCH, STREAM_MAX_WAIT)
    }

    /// [`Self::start_stream_pool`] with explicit batching knobs: the
    /// worker fuses up to `max_batch` chunk submissions arriving within
    /// `max_wait` of each other into one batched forward.
    pub fn start_stream_pool_batched(
        &mut self,
        name: &str,
        model: Arc<NativeModel>,
        cfg: SessionConfig,
        max_batch: usize,
        max_wait: Duration,
    ) -> Result<()> {
        let pool = StreamPool::spawn(name, model, cfg, max_batch, max_wait, &self.registry)?;
        self.streams.insert(name.to_string(), pool);
        Ok(())
    }

    /// Submit the next chunk of stream `session` to pool `pool`;
    /// returns the receiver for the incremental response.
    pub fn submit_chunk(
        &self,
        pool: &str,
        session: &str,
        tokens: Vec<u8>,
    ) -> Result<Receiver<StreamResponse>> {
        self.submit_stream_request(pool, session, tokens, false)
    }

    /// Submit many `(session, tokens)` chunk requests in one call — they
    /// land in the worker's queue together, so requests for distinct
    /// sessions fuse into batched forwards. Returns one receiver per
    /// request, in submission order.
    pub fn submit_chunks(
        &self,
        pool: &str,
        reqs: Vec<(String, Vec<u8>)>,
    ) -> Result<Vec<Receiver<StreamResponse>>> {
        reqs.into_iter()
            .map(|(session, tokens)| self.submit_chunk(pool, &session, tokens))
            .collect()
    }

    /// Submit many chunk requests as one wave and wait for all of them —
    /// the in-process analogue of the wire's `SubmitBatch`: the requests
    /// land in the queue together, fuse into batched forwards, and the
    /// responses come back in submission order. One failed request fails
    /// the call (use [`Self::submit_chunks`] for per-request status).
    pub fn stream_chunks(
        &self,
        pool: &str,
        reqs: Vec<(String, Vec<u8>)>,
    ) -> Result<Vec<StreamResponse>> {
        let rxs = self.submit_chunks(pool, reqs)?;
        rxs.into_iter()
            .map(|rx| {
                into_result(rx.recv().map_err(|_| anyhow!("stream worker dropped response"))?)
            })
            .collect()
    }

    /// Submit a chunk and wait for its scores.
    pub fn stream_chunk(
        &self,
        pool: &str,
        session: &str,
        tokens: Vec<u8>,
    ) -> Result<StreamResponse> {
        let rx = self.submit_chunk(pool, session, tokens)?;
        into_result(rx.recv().map_err(|_| anyhow!("stream worker dropped response"))?)
    }

    /// Close a stream, releasing its carried state; waits for the ack.
    pub fn close_stream(&self, pool: &str, session: &str) -> Result<()> {
        let rx = self.submit_stream_request(pool, session, Vec::new(), true)?;
        rx.recv().map_err(|_| anyhow!("stream worker dropped response"))?;
        Ok(())
    }

    /// Export every live session of a stream pool — resident and
    /// spilled — as verified snapshots in `dir` (the migration export:
    /// a warm replica, or this process after a restart, adopts them via
    /// [`Self::restore_from`]). The export is a barrier in the worker's
    /// queue: it captures exactly the chunks submitted before it.
    /// Returns the number of sessions written.
    pub fn checkpoint_all(&self, pool: &str, dir: &std::path::Path) -> Result<usize> {
        self.stream_control(pool, StreamOp::CheckpointAll(dir.to_path_buf()))
    }

    /// Incremental export of a stream pool: bring `dir` (a previous
    /// export target) up to date, re-snapshotting **only the sessions
    /// that advanced** since the last export and retaining the rest —
    /// the hot-checkpoint path: cost scales with the write rate, not the
    /// session count. Same queue-barrier semantics as
    /// [`Self::checkpoint_all`]; restoring from the resulting directory
    /// is bitwise identical to restoring from a full export. Returns the
    /// number of sessions re-snapshotted.
    pub fn checkpoint_delta(&self, pool: &str, dir: &std::path::Path) -> Result<usize> {
        self.stream_control(pool, StreamOp::CheckpointDelta(dir.to_path_buf()))
    }

    /// Evacuate a stream pool: export every live session into `dir`
    /// (exactly [`Self::checkpoint_all`]'s barrier semantics) and then
    /// close them all, leaving the pool empty but running. After a
    /// successful drain the sessions exist *only* in the export — the
    /// peer that adopts it via [`Self::restore_from`] becomes their
    /// sole owner, which is what makes the networked router's live
    /// rebalance (and drain-on-shutdown) safe. Returns the number of
    /// sessions exported.
    pub fn drain_stream(&self, pool: &str, dir: &std::path::Path) -> Result<usize> {
        self.stream_control(pool, StreamOp::Drain(dir.to_path_buf()))
    }

    /// Adopt every session checkpointed in `dir` into a stream pool.
    /// All-or-nothing, and an id collision with a live session is an
    /// error. Returns the number of sessions adopted.
    pub fn restore_from(&self, pool: &str, dir: &std::path::Path) -> Result<usize> {
        self.stream_control(pool, StreamOp::RestoreFrom(dir.to_path_buf()))
    }

    /// Durability gauges of a stream pool (spills, rehydrations,
    /// checkpoint bytes, rehydration latency).
    pub fn stream_persist_metrics(&self, pool: &str) -> Option<Arc<PersistMetrics>> {
        self.streams.get(pool).map(|p| p.persist.clone())
    }

    /// Serving metrics of a stream pool (chunk requests, fused-window
    /// sizes, chunk latency histogram).
    pub fn stream_metrics(&self, pool: &str) -> Option<Arc<Metrics>> {
        self.streams.get(pool).map(|p| p.metrics.clone())
    }

    /// Names of the running stream pools.
    pub fn stream_pools(&self) -> Vec<String> {
        self.streams.keys().cloned().collect()
    }

    fn stream_control(&self, pool: &str, op: StreamOp) -> Result<usize> {
        let p = self
            .streams
            .get(pool)
            .ok_or_else(|| anyhow!("no stream pool '{pool}'"))?;
        let (rtx, rrx) = channel();
        p.tx.send(StreamRequest {
            session: String::new(),
            tokens: Vec::new(),
            close: false,
            op,
            respond: rtx,
            submitted: Instant::now(),
        })
        .map_err(|_| anyhow!("stream pool '{pool}' shut down"))?;
        let resp = rrx.recv().map_err(|_| anyhow!("stream worker dropped response"))?;
        match resp.error {
            Some(e) => Err(anyhow!("{e}")),
            None => Ok(resp.affected),
        }
    }

    fn submit_stream_request(
        &self,
        pool: &str,
        session: &str,
        tokens: Vec<u8>,
        close: bool,
    ) -> Result<Receiver<StreamResponse>> {
        let p = self
            .streams
            .get(pool)
            .ok_or_else(|| anyhow!("no stream pool '{pool}'"))?;
        let (rtx, rrx) = channel();
        p.tx.send(StreamRequest {
            session: session.to_string(),
            tokens,
            close,
            op: StreamOp::Chunk,
            respond: rtx,
            submitted: Instant::now(),
        })
        .map_err(|_| anyhow!("stream pool '{pool}' shut down"))?;
        Ok(rrx)
    }

    /// Serving metrics of a batched fill-mask pool.
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.pools.get(model).map(|p| p.metrics.clone())
    }

    /// Names of the running fill-mask model pools.
    pub fn models(&self) -> Vec<String> {
        self.pools.keys().cloned().collect()
    }

    /// Shut down all pools and join workers.
    pub fn shutdown(&mut self) {
        let pools = std::mem::take(&mut self.pools);
        for (_, pool) in pools {
            drop(pool.tx);
            for w in pool.workers {
                let _ = w.join();
            }
        }
        for (_, stream) in std::mem::take(&mut self.streams) {
            stream.shutdown();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<Request>>>,
    state: Arc<ModelState>,
    metrics: Arc<Metrics>,
    max_batch: usize,
    max_wait: Duration,
    tag: &str,
) {
    loop {
        // one worker drains at a time per pool; execution is serialized
        // on the engine actor anyway on this single-core testbed
        let batch = {
            let guard = rx.lock().unwrap();
            collect_batch(&guard, max_batch, max_wait)
        };
        let Some(batch) = batch else { break };
        if let Err(e) = serve_batch(&state, batch, &metrics) {
            metrics.errors.inc();
            eprintln!("[serve-{tag}] batch failed: {e:#}");
        }
    }
}
