//! Dynamic batcher: fuses concurrent fill-mask requests into fixed-shape
//! executable calls (the compiled artifacts are shape-static, so the
//! batcher pads to the compiled batch size).
//!
//! Policy: block for the first request, then greedily drain the queue up
//! to `max_batch` or until `max_wait` elapses — the standard
//! latency/throughput knob in serving systems (vLLM-style). A lone
//! request with nothing else queued ships immediately rather than
//! waiting out the window.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs::trace;
use crate::protein::vocab::{self, MASK, PAD};
use crate::runtime::{ArtifactMeta, EngineHandle, HostValue, Role};

use super::metrics::Metrics;

/// A fill-mask request: a token sequence containing MASK tokens.
pub struct Request {
    /// request id (unique per coordinator)
    pub id: u64,
    /// token sequence containing MASK positions
    pub tokens: Vec<u8>,
    /// where the worker sends the response
    pub respond: Sender<Response>,
    /// submission time, for latency accounting
    pub submitted: Instant,
}

/// The response: predictions + probabilities at each masked position.
#[derive(Clone, Debug)]
pub struct Response {
    /// id of the request this answers
    pub id: u64,
    /// (position, predicted_token, probability)
    pub predictions: Vec<(usize, u8, f32)>,
    /// full filled sequence
    pub filled: Vec<u8>,
    /// mask positions beyond the compiled window (`max_len`) that this
    /// artifact could not answer — explicitly reported rather than
    /// silently dropped; route these through the streaming path or a
    /// longer-window artifact
    pub truncated: Vec<usize>,
    /// end-to-end latency from submission to response
    pub latency: Duration,
}

impl Response {
    /// Whether every masked position in the request was answered.
    pub fn complete(&self) -> bool {
        self.truncated.is_empty()
    }
}

/// Mask positions at or beyond the compiled window `max_len`: the
/// shape-static artifact never sees these tokens, so they can't be
/// predicted — callers learn about them via [`Response::truncated`].
pub fn truncated_masks(tokens: &[u8], max_len: usize) -> Vec<usize> {
    tokens
        .iter()
        .enumerate()
        .skip(max_len)
        .filter(|&(_, &t)| t == MASK)
        .map(|(i, _)| i)
        .collect()
}

/// Model state the batcher serves (params/features in artifact order).
/// Execution goes through the engine actor handle, so this is Send.
pub struct ModelState {
    /// engine actor handle executions go through
    pub engine: EngineHandle,
    /// compiled forward-artifact name
    pub artifact: String,
    /// the artifact's I/O contract
    pub meta: ArtifactMeta,
    /// model parameters in artifact slot order
    pub params: Vec<Vec<f32>>,
    /// FAVOR feature draws in artifact slot order
    pub features: Vec<Vec<f32>>,
}

impl ModelState {
    /// Assemble the fwd input vector for a padded token batch.
    fn build_inputs(&self, tokens: &[i32]) -> Result<Vec<HostValue>> {
        let meta = &self.meta;
        let mut p_it = self.params.iter();
        let mut f_it = self.features.iter();
        let mut inputs = Vec::with_capacity(meta.inputs.len());
        for slot in &meta.inputs {
            inputs.push(match slot.role {
                Role::Param => HostValue::F32(p_it.next().unwrap().clone()),
                Role::Feature => HostValue::F32(f_it.next().unwrap().clone()),
                Role::Tokens => HostValue::I32(tokens.to_vec()),
                other => anyhow::bail!("unexpected fwd input role {other:?}"),
            });
        }
        Ok(inputs)
    }
}

/// Adaptive drain window: how much longer a partially-filled batch
/// waits for more traffic. The window shrinks linearly with fill — a
/// lone straggler pair gets the full `max_wait`, a nearly-full batch
/// ships almost immediately, and a full batch never waits at all — so
/// under a deep queue the worker drains back-to-back instead of
/// sleeping out a fixed window it no longer needs.
pub fn adaptive_wait(max_wait: Duration, filled: usize, max_batch: usize) -> Duration {
    if max_batch <= 1 || filled >= max_batch {
        return Duration::ZERO;
    }
    let frac = (max_batch - filled) as f64 / (max_batch - 1) as f64;
    max_wait.mul_f64(frac.min(1.0))
}

/// Drain policy output: the requests fused into one batch. Generic over
/// the request type — the fill-mask worker and the stream worker share
/// this one latency/throughput knob.
///
/// A lone request ships immediately: a wait window is only opened when
/// the non-blocking drain finds concurrent traffic already queued, so a
/// single interactive client pays no batching latency while bursty
/// submitters still fuse. The window itself is adaptive
/// ([`adaptive_wait`]): it shrinks as the batch fills, collapsing to
/// zero at `max_batch`, so queue depth directly tunes the
/// latency/throughput trade instead of every batch paying `max_wait`.
pub fn collect_batch<T>(
    rx: &Receiver<T>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<T>> {
    // the span covers idle blocking too: in a trace, batch_wait is
    // "time this worker was not serving", and its tail is the drain
    // window actually spent waiting for traffic to fuse
    let _wait = trace::span("batch_wait");
    // block for the first request (queue closed -> shut down)
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    // greedily take everything already queued, without waiting
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(req) => batch.push(req),
            Err(_) => break,
        }
    }
    if batch.len() == 1 {
        return Some(batch);
    }
    let start = Instant::now();
    while batch.len() < max_batch {
        // re-derived after every arrival: the fuller the batch, the
        // sooner it ships
        let deadline = start + adaptive_wait(max_wait, batch.len(), max_batch);
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Run one fused batch through the model and answer every request.
pub fn serve_batch(model: &ModelState, batch: Vec<Request>, metrics: &Metrics) -> Result<()> {
    let _span = trace::span_n("serve_batch", batch.len() as u64);
    let meta = &model.meta;
    let (b, l) = (meta.config.batch, meta.config.max_len);
    let vocab_size = meta.outputs[0].shape[2];
    assert!(batch.len() <= b, "batcher overfilled: {} > {b}", batch.len());

    // pad into the compiled (b, l) token grid
    let mut tokens = vec![PAD as i32; b * l];
    for (row, req) in batch.iter().enumerate() {
        for (col, &t) in req.tokens.iter().take(l).enumerate() {
            tokens[row * l + col] = t as i32;
        }
    }

    let inputs = model.build_inputs(&tokens)?;
    let outputs = model.engine.exec(&model.artifact, inputs)?;
    let logits = outputs[0].as_f32()?;
    metrics.observe_batch(batch.len(), batch.iter().map(|r| r.tokens.len()).sum());

    for (row, req) in batch.into_iter().enumerate() {
        let mut predictions = Vec::new();
        let mut filled = req.tokens.clone();
        let truncated = truncated_masks(&req.tokens, l);
        for (col, &t) in req.tokens.iter().enumerate().take(l) {
            if t == MASK {
                let base = (row * l + col) * vocab_size;
                let row_logits = &logits[base..base + vocab_size];
                // softmax argmax over amino-acid tokens only
                let mx = row_logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut denom = 0.0f32;
                for &v in row_logits {
                    denom += (v - mx).exp();
                }
                let (best, best_logit) = row_logits
                    .iter()
                    .enumerate()
                    .skip(vocab::AA_BASE as usize)
                    .take(vocab::N_AA)
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                let prob = (best_logit - mx).exp() / denom;
                predictions.push((col, best as u8, prob));
                filled[col] = best as u8;
            }
        }
        let latency = req.submitted.elapsed();
        metrics.observe_latency(latency);
        // receiver may have hung up; that's fine
        let _ = req.respond.send(Response { id: req.id, predictions, filled, truncated, latency });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collect_batch_respects_max() {
        let (tx, rx) = channel();
        for i in 0..5u64 {
            let (rtx, _rrx) = channel();
            tx.send(Request { id: i, tokens: vec![MASK], respond: rtx, submitted: Instant::now() })
                .unwrap();
        }
        let batch = collect_batch(&rx, 3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = collect_batch(&rx, 3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn lone_request_ships_without_waiting_out_the_window() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(Request { id: 0, tokens: vec![MASK], respond: rtx, submitted: Instant::now() })
            .unwrap();
        let t0 = Instant::now();
        let batch = collect_batch(&rx, 8, Duration::from_secs(10)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "a lone request must not wait out max_wait"
        );
    }

    #[test]
    fn collect_batch_times_out_quickly() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(Request { id: 0, tokens: vec![MASK], respond: rtx, submitted: Instant::now() })
            .unwrap();
        let t0 = Instant::now();
        let batch = collect_batch(&rx, 8, Duration::from_millis(5)).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn adaptive_window_shrinks_with_fill() {
        let w = Duration::from_millis(8);
        // a two-element batch in an 8-slot window waits the full window
        assert_eq!(adaptive_wait(w, 1, 8), w);
        // ...and the wait collapses to zero as the batch fills
        let mid = adaptive_wait(w, 4, 8);
        assert!(mid < w && mid > Duration::ZERO);
        assert!(adaptive_wait(w, 7, 8) < mid);
        assert_eq!(adaptive_wait(w, 8, 8), Duration::ZERO);
        // degenerate shapes never wait
        assert_eq!(adaptive_wait(w, 1, 1), Duration::ZERO);
        assert_eq!(adaptive_wait(w, 9, 8), Duration::ZERO);
    }

    #[test]
    fn nearly_full_batch_ships_before_the_full_window() {
        let (tx, rx) = channel();
        for i in 0..3u64 {
            let (rtx, _rrx) = channel();
            tx.send(Request { id: i, tokens: vec![MASK], respond: rtx, submitted: Instant::now() })
                .unwrap();
        }
        // 3 of 4 slots filled: the adaptive window is max_wait/3, so the
        // drain must return far sooner than the fixed 900ms window would
        let t0 = Instant::now();
        let batch = collect_batch(&rx, 4, Duration::from_millis(900)).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(
            t0.elapsed() < Duration::from_millis(700),
            "deep queue must shrink the drain wait (took {:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn truncated_masks_reports_only_dropped_positions() {
        use crate::protein::vocab::AA_BASE;
        // masks at 1 and 5, window of 4: only position 5 is dropped
        let tokens = vec![AA_BASE, MASK, AA_BASE, AA_BASE, AA_BASE, MASK, AA_BASE];
        assert_eq!(truncated_masks(&tokens, 4), vec![5]);
        assert_eq!(truncated_masks(&tokens, 7), Vec::<usize>::new());
        assert_eq!(truncated_masks(&tokens, 0), vec![1, 5]);
        assert!(truncated_masks(&[], 4).is_empty());
    }

    #[test]
    fn collect_batch_none_when_closed() {
        let (tx, rx) = channel::<Request>();
        drop(tx);
        assert!(collect_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }
}
