//! L3 serving coordinator: request router, dynamic batcher and metrics
//! in front of the AOT-compiled Performer executables. Python is never
//! on this path — requests hit compiled HLO through PJRT directly.

pub mod batcher;
pub mod metrics;
pub mod service;

pub use batcher::{Request, Response};
pub use metrics::Metrics;
pub use service::Coordinator;
