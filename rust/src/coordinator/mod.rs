//! L3 serving coordinator: request router, dynamic batcher and metrics
//! in front of the AOT-compiled Performer executables (Python is never
//! on this path — requests hit compiled HLO through PJRT directly),
//! plus the streaming session path for chunked long-context inference
//! over the native Performer stack.

pub mod batcher;
pub mod metrics;
pub mod service;
pub mod streamer;

pub use batcher::{adaptive_wait, Request, Response};
pub use metrics::{Metrics, PersistMetrics};
pub use service::Coordinator;
pub use streamer::{
    StreamOp, StreamRequest, StreamResponse, STREAM_MAX_BATCH, STREAM_MAX_WAIT,
};
