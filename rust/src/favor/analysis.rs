//! Attention-matrix reconstruction and approximation-error metrics.
//!
//! Powers Fig. 2 (error vs M, iid vs ORF), Fig. 11 (error propagation
//! through layers), Figs. 7–10 (attention visualization via the one-hot-V
//! trick described in Appendix C.4) and the empirical Thm. 1 check.

use crate::tensor::Mat;

use super::exact::raw_attention_matrix;
use super::kernel::Featurizer;
use super::linear::STABILIZER;
use super::Direction;

/// Exact renormalized attention matrix D⁻¹A (L×L) — what the Transformer
/// materializes.
pub fn attention_matrix_exact(q: &Mat, k: &Mat, dir: Direction) -> Mat {
    let mut a = raw_attention_matrix(q, k, dir);
    let sums = a.row_sums();
    for i in 0..a.rows {
        let s = sums[i].max(1e-30);
        for v in a.row_mut(i) {
            *v /= s;
        }
    }
    a
}

/// FAVOR's implied attention matrix, reconstructed via the Appendix C.4
/// one-hot-V probe: running the mechanism with V° = I returns exactly the
/// renormalized D̂⁻¹Â row by row. O(L²) — analysis only. Generic over
/// [`Featurizer`]: a raw draw or a kernel handle.
pub fn attention_matrix_favor<F: Featurizer + ?Sized>(fm: &F, q: &Mat, k: &Mat, dir: Direction) -> Mat {
    let qp = fm.phi(q);
    let kp = fm.phi(k);
    let l = q.rows;
    let mut a = qp.matmul(&kp.t());
    if dir == Direction::Unidirectional {
        for i in 0..l {
            for j in i + 1..l {
                *a.at_mut(i, j) = 0.0;
            }
        }
    }
    let sums = a.row_sums();
    for i in 0..l {
        let s = sums[i] + STABILIZER;
        for v in a.row_mut(i) {
            *v /= s;
        }
    }
    a
}

/// FAVOR's *unnormalized* estimate Â = Q'(K')ᵀ of A — the quantity
/// Theorem 1 bounds in L1 norm.
pub fn raw_attention_matrix_favor<F: Featurizer + ?Sized>(fm: &F, q: &Mat, k: &Mat, dir: Direction) -> Mat {
    let qp = fm.phi(q);
    let kp = fm.phi(k);
    let l = q.rows;
    let mut a = qp.matmul(&kp.t());
    if dir == Direction::Unidirectional {
        for i in 0..l {
            for j in i + 1..l {
                *a.at_mut(i, j) = 0.0;
            }
        }
    }
    a
}

/// Mean-squared error between two matrices (Fig. 2's metric).
pub fn output_error(a: &Mat, b: &Mat) -> f64 {
    let diff = a.sub(b);
    let n = diff.data.len() as f64;
    diff.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n
}

/// Entrywise L1 error ||Â − A||₁ normalized by entries (Thm. 1's norm).
pub fn l1_error(a: &Mat, b: &Mat) -> f64 {
    a.mean_abs_diff(b)
}

/// Amino-acid similarity matrix from attention (Vig et al. [50], used for
/// Fig. 10): S[a][b] = mean attention weight from tokens of type a to
/// tokens of type b, aggregated over sequences.
pub struct AaSimilarity {
    /// pair observation counts per (row token, col token)
    pub counts: Mat,
    /// accumulated attention mass per (row token, col token)
    pub weights: Mat,
}

impl AaSimilarity {
    /// Empty accumulator over a vocab × vocab grid.
    pub fn new(vocab: usize) -> Self {
        AaSimilarity { counts: Mat::zeros(vocab, vocab), weights: Mat::zeros(vocab, vocab) }
    }

    /// Accumulate one sequence's attention matrix (L×L) with token ids.
    pub fn accumulate(&mut self, attn: &Mat, tokens: &[usize]) {
        assert_eq!(attn.rows, tokens.len());
        for i in 0..attn.rows {
            for j in 0..attn.cols {
                let (a, b) = (tokens[i], tokens[j]);
                *self.weights.at_mut(a, b) += attn.at(i, j);
                *self.counts.at_mut(a, b) += 1.0;
            }
        }
    }

    /// Normalized similarity matrix (mean attention weight per AA pair),
    /// symmetrized, with zero diagonal for visualization parity with the
    /// normalized-BLOSUM presentation of Fig. 10.
    pub fn finish(&self) -> Mat {
        let n = self.weights.rows;
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let c = self.counts.at(i, j);
                if c > 0.0 {
                    *s.at_mut(i, j) = self.weights.at(i, j) / c;
                }
            }
        }
        // symmetrize
        for i in 0..n {
            for j in 0..i {
                let m = 0.5 * (s.at(i, j) + s.at(j, i));
                *s.at_mut(i, j) = m;
                *s.at_mut(j, i) = m;
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::favor::features::{FeatureKind, FeatureMap};
    use crate::linalg::OrfMechanism;
    use crate::rng::Pcg64;

    fn qk(l: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        (
            Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect()),
            Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * 0.5).collect()),
        )
    }

    #[test]
    fn exact_matrix_rows_sum_to_one() {
        let (q, k) = qk(16, 8, 0);
        let a = attention_matrix_exact(&q, &k, Direction::Bidirectional);
        for i in 0..16 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn favor_matrix_converges_with_m() {
        let (q, k) = qk(16, 8, 1);
        let exact = attention_matrix_exact(&q, &k, Direction::Bidirectional);
        let mut rng = Pcg64::new(2);
        let err_at = |m: usize, rng: &mut Pcg64| {
            // average over a few feature draws
            let mut e = 0.0;
            for t in 0..5 {
                let fm = FeatureMap::sample(
                    FeatureKind::Softmax, m, 8, OrfMechanism::Regular, &mut rng.fork(t));
                e += output_error(&attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional), &exact);
            }
            e / 5.0
        };
        let e_small = err_at(8, &mut rng);
        let e_big = err_at(256, &mut rng);
        assert!(e_big < e_small, "error must shrink with M: {e_small} -> {e_big}");
    }

    #[test]
    fn one_hot_probe_equals_direct_reconstruction() {
        // Appendix C.4: attention applied to V° = I gives the matrix.
        let (q, k) = qk(10, 4, 3);
        let mut rng = Pcg64::new(4);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 4, OrfMechanism::Regular, &mut rng);
        let direct = attention_matrix_favor(&fm, &q, &k, Direction::Bidirectional);
        let probe = crate::favor::linear::favor_attention(
            &fm, &q, &k, &Mat::eye(10), Direction::Bidirectional);
        assert!(direct.max_abs_diff(&probe) < 1e-4);
    }

    #[test]
    fn causal_matrix_is_lower_triangular() {
        let (q, k) = qk(12, 4, 5);
        let mut rng = Pcg64::new(6);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 4, OrfMechanism::Regular, &mut rng);
        let a = attention_matrix_favor(&fm, &q, &k, Direction::Unidirectional);
        for i in 0..12 {
            for j in i + 1..12 {
                assert_eq!(a.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn similarity_accumulator_symmetric() {
        let mut sim = AaSimilarity::new(4);
        let attn = Mat::from_fn(3, 3, |i, j| ((i + 1) * (j + 1)) as f32 * 0.1);
        sim.accumulate(&attn, &[0, 1, 2]);
        sim.accumulate(&attn, &[2, 1, 0]);
        let s = sim.finish();
        for i in 0..4 {
            for j in 0..4 {
                assert!((s.at(i, j) - s.at(j, i)).abs() < 1e-6);
            }
        }
    }
}
