//! Linear-time FAVOR attention (Algorithm 1).
//!
//! Bidirectional (Eq. 13):  D̂⁻¹ (Q′ ((K′)ᵀ C)) with C = [V 1] — the
//! bracketing is the whole point: never materialize the L×L matrix.
//!
//! Unidirectional (Eq. 14): prefix sums over G_j = K′_j C_jᵀ. We use the
//! paper's Sec. 2.6 streaming aggregation: the running M×(d+1) state is
//! updated row by row in O(M(d+1)) memory instead of storing the full
//! L×M×(d+1) tensor G^PS.

use crate::tensor::{axpy, dot, Mat};

use super::kernel::Featurizer;
use super::Direction;

/// Numerical stabilizer added to the denominator (paper Appendix B.2).
pub const STABILIZER: f32 = 1e-6;

/// Bidirectional FAVOR: qp, kp are the mapped features (L×M), v is (L×d).
/// Time O(LM(d+1)), space O(M(d+1)) beyond inputs/outputs.
pub fn favor_bidirectional(qp: &Mat, kp: &Mat, v: &Mat) -> Mat {
    let (l, m) = (qp.rows, qp.cols);
    let d = v.cols;
    assert_eq!(kp.rows, l);
    assert_eq!(kp.cols, m);
    assert_eq!(v.rows, l);

    // KV = (K')^T C, with the ones-column folded in as an extra column.
    let mut kv = Mat::zeros(m, d + 1);
    for j in 0..l {
        let krow = kp.row(j);
        let vrow = v.row(j);
        for (i, &kji) in krow.iter().enumerate() {
            if kji != 0.0 {
                let out = &mut kv.data[i * (d + 1)..i * (d + 1) + d];
                axpy(kji, vrow, out);
                kv.data[i * (d + 1) + d] += kji;
            }
        }
    }

    let mut out = Mat::zeros(l, d);
    let mut buf = vec![0.0f32; d + 1];
    for i in 0..l {
        buf.fill(0.0);
        let qrow = qp.row(i);
        for (j, &qij) in qrow.iter().enumerate() {
            if qij != 0.0 {
                axpy(qij, &kv.data[j * (d + 1)..(j + 1) * (d + 1)], &mut buf);
            }
        }
        let denom = buf[d] + STABILIZER;
        let orow = out.row_mut(i);
        for (o, &b) in orow.iter_mut().zip(&buf[..d]) {
            *o = b / denom;
        }
    }
    out
}

/// Unidirectional FAVOR with the streaming prefix-sum state (Alg. 1,
/// Sec. 2.5.1). Row i's output uses the running sum of K'_j C_j^T for
/// j <= i — causality by construction, no L×L matrix.
///
/// This is a thin wrapper over [`crate::stream::StreamState`] — the
/// single source of truth for the recurrence — run as one chunk covering
/// the whole sequence. The streaming form consumes the same sequence
/// split into arbitrary chunks and produces identical outputs.
pub fn favor_unidirectional(qp: &Mat, kp: &Mat, v: &Mat) -> Mat {
    let mut state = crate::stream::StreamState::new(qp.cols, v.cols);
    state.advance(qp, kp, v)
}

/// Full FAVOR attention: map q/k through the kernel's feature map, then
/// apply the direction-appropriate linear attention. Generic over
/// [`Featurizer`], so it runs the same for a raw [`FeatureMap`] draw and
/// for an [`crate::favor::AttentionKernel`] handle.
pub fn favor_attention<F: Featurizer + ?Sized>(fm: &F, q: &Mat, k: &Mat, v: &Mat, dir: Direction) -> Mat {
    let qp = fm.phi(q);
    let kp = fm.phi(k);
    match dir {
        Direction::Bidirectional => favor_bidirectional(&qp, &kp, v),
        Direction::Unidirectional => favor_unidirectional(&qp, &kp, v),
    }
}

/// O(L²) reference for the same estimator: materialize Â = Q'(K')ᵀ and
/// renormalize. Used by tests and by the attention-matrix analyses.
pub fn favor_attention_quadratic(qp: &Mat, kp: &Mat, v: &Mat, dir: Direction) -> Mat {
    let l = qp.rows;
    let mut a = qp.matmul(&kp.t());
    if dir == Direction::Unidirectional {
        for i in 0..l {
            for j in i + 1..l {
                *a.at_mut(i, j) = 0.0;
            }
        }
    }
    let sums = a.row_sums();
    let mut out = a.matmul(v);
    for i in 0..l {
        let denom = sums[i] + STABILIZER;
        for x in out.row_mut(i) {
            *x /= denom;
        }
    }
    out
}

/// Convexity diagnostic: the rows of the implied attention matrix after
/// renormalization sum to ~1 when features are nonnegative (ReLU/softmax
/// kinds), so outputs are convex combinations of value vectors.
pub fn row_mass(qp: &Mat, kp: &Mat) -> Vec<f32> {
    let ksum: Vec<f32> = {
        let mut s = vec![0.0f32; kp.cols];
        for i in 0..kp.rows {
            axpy(1.0, kp.row(i), &mut s);
        }
        s
    };
    (0..qp.rows).map(|i| dot(qp.row(i), &ksum)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::favor::features::{FeatureKind, FeatureMap};
    use crate::linalg::OrfMechanism;
    use crate::rng::Pcg64;

    fn random_qkv(l: usize, d: usize, seed: u64, scale: f32) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let gen = |rng: &mut Pcg64| {
            Mat::from_vec(l, d, rng.gaussian_vec(l * d).iter().map(|v| v * scale).collect())
        };
        (gen(&mut rng), gen(&mut rng), gen(&mut rng))
    }

    #[test]
    fn linear_matches_quadratic_bidirectional() {
        let (q, k, v) = random_qkv(32, 8, 0, 0.5);
        let mut rng = Pcg64::new(1);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 8, OrfMechanism::Regular, &mut rng);
        let qp = fm.apply(&q);
        let kp = fm.apply(&k);
        let lin = favor_bidirectional(&qp, &kp, &v);
        let quad = favor_attention_quadratic(&qp, &kp, &v, Direction::Bidirectional);
        assert!(lin.max_abs_diff(&quad) < 1e-4, "diff {}", lin.max_abs_diff(&quad));
    }

    #[test]
    fn linear_matches_quadratic_unidirectional() {
        let (q, k, v) = random_qkv(32, 8, 2, 0.5);
        let mut rng = Pcg64::new(3);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 8, OrfMechanism::Regular, &mut rng);
        let qp = fm.apply(&q);
        let kp = fm.apply(&k);
        let lin = favor_unidirectional(&qp, &kp, &v);
        let quad = favor_attention_quadratic(&qp, &kp, &v, Direction::Unidirectional);
        assert!(lin.max_abs_diff(&quad) < 1e-4, "diff {}", lin.max_abs_diff(&quad));
    }

    #[test]
    fn unidirectional_is_causal() {
        // Changing a future key/value must not change past outputs.
        let (q, k, v) = random_qkv(16, 4, 4, 0.5);
        let mut rng = Pcg64::new(5);
        let fm = FeatureMap::sample(FeatureKind::Relu, 8, 4, OrfMechanism::Regular, &mut rng);
        let out1 = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);

        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for c in 0..4 {
            *k2.at_mut(15, c) = 9.0;
            *v2.at_mut(15, c) = -9.0;
        }
        let out2 = favor_attention(&fm, &q, &k2, &v2, Direction::Unidirectional);
        let head1 = out1.rows_slice(0, 15);
        let head2 = out2.rows_slice(0, 15);
        assert!(head1.max_abs_diff(&head2) < 1e-6);
        // ...but the last row must change
        assert!(
            out1.rows_slice(15, 16).max_abs_diff(&out2.rows_slice(15, 16)) > 1e-4
        );
    }

    #[test]
    fn bidirectional_approximates_softmax_attention() {
        // The headline claim: FAVOR-softmax estimates exact attention.
        let (q, k, v) = random_qkv(24, 8, 6, 0.4);
        let exact = crate::favor::exact::exact_attention(&q, &k, &v, Direction::Bidirectional);
        let mut rng = Pcg64::new(7);
        let fm = FeatureMap::sample(FeatureKind::Softmax, 1024, 8, OrfMechanism::Regular, &mut rng);
        let approx = favor_attention(&fm, &q, &k, &v, Direction::Bidirectional);
        let err = exact.mean_abs_diff(&approx);
        assert!(err < 0.05, "approximation error {err}");
    }

    #[test]
    fn unidirectional_approximates_causal_softmax() {
        let (q, k, v) = random_qkv(24, 8, 8, 0.4);
        let exact = crate::favor::exact::exact_attention(&q, &k, &v, Direction::Unidirectional);
        let mut rng = Pcg64::new(9);
        let fm = FeatureMap::sample(FeatureKind::Softmax, 1024, 8, OrfMechanism::Regular, &mut rng);
        let approx = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);
        let err = exact.mean_abs_diff(&approx);
        assert!(err < 0.08, "approximation error {err}");
    }

    #[test]
    fn outputs_in_value_convex_hull_for_nonneg_features() {
        // With ReLU features every output coordinate lies within the range
        // spanned by the value vectors (convex combination property).
        let (q, k, v) = random_qkv(20, 6, 10, 0.8);
        let mut rng = Pcg64::new(11);
        let fm = FeatureMap::sample(FeatureKind::Relu, 32, 6, OrfMechanism::Regular, &mut rng);
        let out = favor_attention(&fm, &q, &k, &v, Direction::Bidirectional);
        for c in 0..6 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..20 {
                lo = lo.min(v.at(r, c));
                hi = hi.max(v.at(r, c));
            }
            for r in 0..20 {
                let x = out.at(r, c);
                assert!(x >= lo - 1e-3 && x <= hi + 1e-3, "out[{r},{c}]={x} outside [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn first_token_attends_only_to_itself_causally() {
        let (q, k, v) = random_qkv(8, 4, 12, 0.5);
        let mut rng = Pcg64::new(13);
        let fm = FeatureMap::sample(FeatureKind::Relu, 64, 4, OrfMechanism::Regular, &mut rng);
        let out = favor_attention(&fm, &q, &k, &v, Direction::Unidirectional);
        // row 0 denominator only includes k_0 -> output == v_0 exactly
        for c in 0..4 {
            assert!((out.at(0, c) - v.at(0, c)).abs() < 1e-3);
        }
    }
}
