//! Random-feature maps phi: R^d -> R^M (Sec. 2.3).
//!
//! Softmax features (Eq. 10 + the D_Q/D_K renormalizers of Eq. 5-6):
//!   phi'(x) = exp(||x||²/r) · sqrt(2/M) · cos(Wx + b),  r = 2√d,
//!   W rows ~ N(0, I/√d)  (Gaussian kernel bandwidth σ_B = d^{1/4}),
//!   so that E[phi'(q)·phi'(k)] = exp(q·k/√d) = A_ij exactly.
//!
//! FAVOR+ positive features ("Rethinking Attention with Performers",
//! Lemma 1 — strictly positive, bounded-variance softmax estimator):
//!   phi(x) = exp(wᵀx̃ − ‖x̃‖²/2 − max_stabilizer) / √M,  x̃ = x/d^{1/4},
//!   max_stabilizer = max(0, t − EXP_CLAMP) per feature, i.e. the
//!   running max-subtraction restricted to its own row: inactive on any
//!   typical exponent (the estimator stays exactly unbiased,
//!   E[phi(q)·phi(k)] = exp(q·k/√d)), it caps adversarial exponents at
//!   EXP_CLAMP so features can never overflow. A data-global running
//!   max (the batch formulation in the Performers reference code) would
//!   make phi depend on what else streamed through the chunk — breaking
//!   the chunked == single-shot invariant — which is why the stabilizer
//!   here is row-local. Trig features have unbounded relative variance
//!   exactly where attention scores are large; positive features do not.
//!
//! Generalized-attention features (Sec. 2.2, Appendix B.3):
//!   phi(x) = f(Wx)/√M + ε,  W rows ~ N(0, I), f ∈ {ReLU, sigmoid, ...}.

use crate::linalg::{projection_matrix, OrfMechanism};
use crate::rng::Pcg64;
use crate::tensor::{matmul_block, simd, Mat};

/// `exp` generalized-attention clamp: exp(30) ≈ 1.1e13 preserves the
/// ordering of any plausible projection while keeping feature products
/// and prefix sums finite in f32 (1e13² ≈ 1e26 ≪ f32::MAX ≈ 3.4e38).
/// Unclamped, one large projection overflows to +inf and poisons the
/// whole attention row through the shared normalizer.
pub const EXP_CLAMP: f32 = 30.0;

/// The nonlinearity f in phi(x) = c/sqrt(M) f(Wx + b) (Eq. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// cos features + exp renormalizers: unbiased softmax-attention
    /// estimator (the paper's "Performer-SOFTMAX" trig features).
    Softmax,
    /// FAVOR+ positive features: unbiased softmax estimator with
    /// strictly positive features and bounded relative variance.
    Positive,
    /// Generalized attention with the given f (paper default: ReLU).
    Relu,
    /// generalized attention with a sigmoid f
    Sigmoid,
    /// generalized attention with a clamped exp f (see [`EXP_CLAMP`])
    Exp,
    /// generalized attention with f(x) = |x|
    Abs,
    /// generalized attention with GELU
    Gelu,
    /// generalized attention with cos (no softmax renormalizers)
    Cos,
    /// generalized attention with tanh
    Tanh,
    /// linear (identity f) attention
    Identity,
}

impl FeatureKind {
    /// Every kind, in the order surfaced by error messages and sweeps.
    pub const ALL: [FeatureKind; 10] = [
        Self::Softmax,
        Self::Positive,
        Self::Relu,
        Self::Sigmoid,
        Self::Exp,
        Self::Abs,
        Self::Gelu,
        Self::Cos,
        Self::Tanh,
        Self::Identity,
    ];

    /// Parse a kind name (as printed by [`Self::name`]); None if unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "softmax" => Self::Softmax,
            "favor+" | "positive" => Self::Positive,
            "relu" => Self::Relu,
            "sigmoid" => Self::Sigmoid,
            "exp" => Self::Exp,
            "abs" => Self::Abs,
            "gelu" => Self::Gelu,
            "cos" => Self::Cos,
            "tanh" => Self::Tanh,
            "identity" => Self::Identity,
            _ => return None,
        })
    }

    /// Like [`Self::parse`], but an unknown kind names every valid one —
    /// a config/CLI typo gets a menu, not a silent default.
    pub fn parse_or_err(s: &str) -> anyhow::Result<Self> {
        Self::parse(s).ok_or_else(|| {
            let valid: Vec<&str> = Self::ALL.iter().map(FeatureKind::name).collect();
            anyhow::anyhow!("unknown feature kind '{s}' (valid kinds: {})", valid.join(", "))
        })
    }

    /// Canonical name (CLI/report spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Softmax => "softmax",
            Self::Positive => "favor+",
            Self::Relu => "relu",
            Self::Sigmoid => "sigmoid",
            Self::Exp => "exp",
            Self::Abs => "abs",
            Self::Gelu => "gelu",
            Self::Cos => "cos",
            Self::Tanh => "tanh",
            Self::Identity => "identity",
        }
    }

    fn apply(&self, t: f32) -> f32 {
        match self {
            Self::Softmax | Self::Cos => t.cos(),
            // Positive is row-wise (needs ‖x‖²); handled in `activate`
            Self::Positive | Self::Exp => t.min(EXP_CLAMP).exp(),
            Self::Relu => t.max(0.0),
            Self::Sigmoid => 1.0 / (1.0 + (-t).exp()),
            Self::Abs => t.abs(),
            Self::Gelu => 0.5 * t * (1.0 + (0.7978845608 * (t + 0.044715 * t * t * t)).tanh()),
            Self::Tanh => t.tanh(),
            Self::Identity => t,
        }
    }
}

/// A sampled feature map: projection W (M×d), bias b (M), and the scaling
/// conventions for the chosen kind.
#[derive(Clone, Debug)]
pub struct FeatureMap {
    /// the nonlinearity family this map was sampled for
    pub kind: FeatureKind,
    /// projection matrix W (M×d)
    pub w: Mat,
    /// bias b (length M; zero except for trig features)
    pub b: Vec<f32>,
    /// additive stabilizer ε keeping features/denominators positive
    pub kernel_eps: f32,
    d: usize,
}

impl FeatureMap {
    /// Sample a feature map. `d` is the head dimension, `m` the number of
    /// random features M, `mech` the ORF mechanism of Sec. 2.4.
    pub fn sample(kind: FeatureKind, m: usize, d: usize, mech: OrfMechanism, rng: &mut Pcg64) -> Self {
        match kind {
            FeatureKind::Softmax => {
                let sigma = 1.0 / (d as f32).powf(0.25);
                let w = projection_matrix(m, d, mech, sigma, true, rng);
                let b: Vec<f32> =
                    (0..m).map(|_| rng.uniform_in(0.0, std::f64::consts::TAU) as f32).collect();
                FeatureMap { kind, w, b, kernel_eps: 0.0, d }
            }
            FeatureKind::Positive => {
                let sigma = 1.0 / (d as f32).powf(0.25);
                let w = projection_matrix(m, d, mech, sigma, true, rng);
                // strictly positive floor: the normalizer D of a FAVOR+
                // row can underflow toward 0 but never reach or cross it
                FeatureMap { kind, w, b: vec![0.0; m], kernel_eps: 1e-6, d }
            }
            _ => {
                let w = projection_matrix(m, d, mech, 1.0, true, rng);
                FeatureMap { kind, w, b: vec![0.0; m], kernel_eps: 1e-3, d }
            }
        }
    }

    /// Number of random features M.
    pub fn m(&self) -> usize {
        self.w.rows
    }

    /// Input (head) dimension d.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Construct from raw parts (e.g. weights loaded from a checkpoint);
    /// w is M×d, b has length M.
    pub fn from_parts(kind: FeatureKind, w: Mat, b: Vec<f32>, kernel_eps: f32) -> FeatureMap {
        assert_eq!(w.rows, b.len(), "W rows must match b length");
        let d = w.cols;
        FeatureMap { kind, w, b, kernel_eps, d }
    }

    /// Resample W and b in place (the paper's periodic feature-redrawing
    /// strategy, Sec. 4.2) keeping kind/M/d fixed.
    pub fn resample(&mut self, mech: OrfMechanism, rng: &mut Pcg64) {
        *self = FeatureMap::sample(self.kind, self.m(), self.d, mech, rng);
    }

    /// phi'(X) for all rows of X (L×d) -> (L×M).
    pub fn apply(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.d);
        let mut z = x.matmul(&self.w.t()); // (L, M)
        self.activate(x, 0, 0, &mut z);
        z
    }

    /// phi over the column block `[col_lo, col_lo+d)` of x's rows
    /// `[row_lo, row_hi)`, reading the block in place — no `slice_head`
    /// memcpy, no temporary input matrix. Bitwise-identical to
    /// `apply(&copied_block)` (same matmul kernel, same activation
    /// pass); this is the fused path the batched model forward uses on
    /// the stacked QKV matrix.
    pub fn apply_block(&self, x: &Mat, row_lo: usize, row_hi: usize, col_lo: usize) -> Mat {
        assert!(col_lo + self.d <= x.cols, "column block exceeds input width");
        let wt = self.w.t();
        let mut z = Mat::zeros(row_hi - row_lo, self.m());
        matmul_block(x, row_lo, row_hi, col_lo, &wt, &mut z);
        self.activate(x, row_lo, col_lo, &mut z);
        z
    }

    /// Reverse-mode gradient of [`Self::apply_block`]: given the
    /// cotangent `dphi` (block_rows × M) of the features the forward
    /// produced for rows `[row_lo, row_hi)` / columns
    /// `[col_lo, col_lo+d)` of `x`, *accumulate* `dL/dx` into the same
    /// block of `dx` (which must share `x`'s shape).
    ///
    /// The pre-activations `z = X_block·Wᵀ` are recomputed with the
    /// forward's own kernel ([`crate::tensor::matmul_block`]) rather
    /// than taped, so the chunk backward only stores features it needs
    /// for the attention recurrence. W and b are kernel draws, not
    /// trained parameters — there is no dW/db output. Clamped regions
    /// ([`EXP_CLAMP`] in Positive/Exp) get the exact zero subgradient of
    /// the clamp, and the row-level ‖x‖² terms of the Softmax/Positive
    /// renormalizers contribute their `x`-direction component.
    pub fn vjp_block(
        &self,
        x: &Mat,
        row_lo: usize,
        row_hi: usize,
        col_lo: usize,
        dphi: &Mat,
        dx: &mut Mat,
    ) {
        let m = self.m();
        let rows = row_hi - row_lo;
        assert_eq!((dphi.rows, dphi.cols), (rows, m), "dphi shape mismatch");
        assert_eq!((dx.rows, dx.cols), (x.rows, x.cols), "dx must mirror x");
        assert!(col_lo + self.d <= x.cols, "column block exceeds input width");

        // recompute the pre-activations exactly as the forward did
        let wt = self.w.t();
        let mut z = Mat::zeros(rows, m);
        matmul_block(x, row_lo, row_hi, col_lo, &wt, &mut z);

        // turn z into dz in place; xcoef[i] scales the extra x-direction
        // term the row-level renormalizers contribute
        let mut xcoef = vec![0.0f32; rows];
        match self.kind {
            FeatureKind::Softmax => {
                let scale = (2.0 / m as f32).sqrt();
                let r = 2.0 * (self.d as f32).sqrt();
                for i in 0..rows {
                    let xr = &x.row(row_lo + i)[col_lo..col_lo + self.d];
                    let norm_sq: f32 = xr.iter().map(|v| v * v).sum();
                    let diag = (norm_sq / r).exp();
                    let mut csum = 0.0f32;
                    for j in 0..m {
                        let v = z.at(i, j) + self.b[j];
                        let dp = dphi.at(i, j);
                        csum += dp * scale * v.cos();
                        *z.at_mut(i, j) = -dp * diag * scale * v.sin();
                    }
                    // phi = D·s·cos(v), D = exp(‖x‖²/r) ⇒ the D path
                    // adds (2D/r)·Σ_j dphi_j·s·cos(v_j) in the x direction
                    xcoef[i] = 2.0 * diag / r * csum;
                }
            }
            FeatureKind::Positive => {
                let scale = 1.0 / (m as f32).sqrt();
                let r = 2.0 * (self.d as f32).sqrt();
                for i in 0..rows {
                    let xr = &x.row(row_lo + i)[col_lo..col_lo + self.d];
                    let norm_sq: f32 = xr.iter().map(|v| v * v).sum();
                    let diag = norm_sq / r;
                    let mut msum = 0.0f32;
                    for j in 0..m {
                        let g = z.at(i, j) - diag;
                        // exact subgradient of min(·, EXP_CLAMP): zero
                        // wherever the stabilizer clamp engaged
                        let dm = if g < EXP_CLAMP {
                            dphi.at(i, j) * scale * g.exp()
                        } else {
                            0.0
                        };
                        msum += dm;
                        *z.at_mut(i, j) = dm;
                    }
                    // g_j = z_j − ‖x‖²/r ⇒ the shared diag subtracts
                    // (2/r)·Σ_j dm_j in the x direction
                    xcoef[i] = -2.0 / r * msum;
                }
            }
            FeatureKind::Exp => {
                let scale = 1.0 / (m as f32).sqrt();
                for v in &mut z.data {
                    *v = if *v < EXP_CLAMP { scale * v.exp() } else { 0.0 };
                }
                for (zv, dp) in z.data.iter_mut().zip(&dphi.data) {
                    *zv *= dp;
                }
            }
            kind => {
                let scale = 1.0 / (m as f32).sqrt();
                for (zv, dp) in z.data.iter_mut().zip(&dphi.data) {
                    let t = *zv;
                    let fprime = match kind {
                        FeatureKind::Relu => {
                            if t > 0.0 {
                                1.0
                            } else {
                                0.0
                            }
                        }
                        FeatureKind::Sigmoid => {
                            let s = 1.0 / (1.0 + (-t).exp());
                            s * (1.0 - s)
                        }
                        FeatureKind::Abs => {
                            if t > 0.0 {
                                1.0
                            } else if t < 0.0 {
                                -1.0
                            } else {
                                0.0
                            }
                        }
                        FeatureKind::Gelu => {
                            let u = 0.7978845608 * (t + 0.044715 * t * t * t);
                            let th = u.tanh();
                            0.5 * (1.0 + th)
                                + 0.5
                                    * t
                                    * (1.0 - th * th)
                                    * 0.7978845608
                                    * (1.0 + 3.0 * 0.044715 * t * t)
                        }
                        FeatureKind::Cos => -t.sin(),
                        FeatureKind::Tanh => {
                            let th = t.tanh();
                            1.0 - th * th
                        }
                        FeatureKind::Identity => 1.0,
                        // handled above
                        FeatureKind::Softmax | FeatureKind::Positive | FeatureKind::Exp => {
                            unreachable!()
                        }
                    };
                    *zv = dp * scale * fprime;
                }
            }
        }

        // dx_block += dz·W (+ the renormalizer x terms)
        let dxb = z.matmul(&self.w);
        for i in 0..rows {
            let xr = x.row(row_lo + i)[col_lo..col_lo + self.d].to_vec();
            let dr = &mut dx.row_mut(row_lo + i)[col_lo..col_lo + self.d];
            for (j, g) in dr.iter_mut().enumerate() {
                *g += dxb.at(i, j) + xcoef[i] * xr[j];
            }
        }
    }

    /// The post-projection activation pass shared by [`Self::apply`] and
    /// [`Self::apply_block`]: z already holds X_block · Wᵀ; row i of z
    /// corresponds to `x.row(row_lo + i)[col_lo..col_lo+d]`.
    fn activate(&self, x: &Mat, row_lo: usize, col_lo: usize, z: &mut Mat) {
        let m = self.m();
        match self.kind {
            FeatureKind::Softmax => {
                let scale = (2.0 / m as f32).sqrt();
                let r = 2.0 * (self.d as f32).sqrt();
                for i in 0..z.rows {
                    let xr = &x.row(row_lo + i)[col_lo..col_lo + self.d];
                    let norm_sq: f32 = xr.iter().map(|v| v * v).sum();
                    let diag = (norm_sq / r).exp();
                    for j in 0..m {
                        let v = z.at(i, j) + self.b[j];
                        *z.at_mut(i, j) = diag * scale * v.cos() + self.kernel_eps;
                    }
                }
            }
            FeatureKind::Positive => {
                let scale = 1.0 / (m as f32).sqrt();
                let r = 2.0 * (self.d as f32).sqrt();
                // one vectorized-exp dispatch level for the whole pass,
                // so apply and apply_block stay bitwise-identical
                let level = simd::active_level();
                for i in 0..z.rows {
                    let xr = &x.row(row_lo + i)[col_lo..col_lo + self.d];
                    let norm_sq: f32 = xr.iter().map(|v| v * v).sum();
                    let diag = norm_sq / r; // = ‖x̃‖²/2
                    // per row: scale · exp(min(z − diag, EXP_CLAMP)) + ε.
                    // The row-local max-stabilizer min(·, EXP_CLAMP) is
                    // inactive on typical exponents (unbiased estimator),
                    // caps adversarial ones so the features can never
                    // overflow — fused into the vectorized exp kernel.
                    simd::fused_exp_scale_at(
                        level,
                        z.row_mut(i),
                        diag,
                        EXP_CLAMP,
                        scale,
                        self.kernel_eps,
                    );
                }
            }
            FeatureKind::Exp => {
                // exp(min(t, EXP_CLAMP)) with no diag term: the same
                // fused vectorized kernel with sub = 0
                let scale = 1.0 / (m as f32).sqrt();
                let level = simd::active_level();
                for i in 0..z.rows {
                    simd::fused_exp_scale_at(
                        level,
                        z.row_mut(i),
                        0.0,
                        EXP_CLAMP,
                        scale,
                        self.kernel_eps,
                    );
                }
            }
            kind => {
                let scale = 1.0 / (m as f32).sqrt();
                for v in &mut z.data {
                    *v = scale * kind.apply(*v) + self.kernel_eps;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Softmax features are an unbiased estimator of exp(q·k/√d):
    /// with many features the Monte-Carlo estimate concentrates.
    #[test]
    fn softmax_features_estimate_attention_kernel() {
        let d = 8;
        let mut rng = Pcg64::new(0);
        let q = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let k = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let exact = (crate::tensor::dot(q.row(0), k.row(0)) / (d as f32).sqrt()).exp();

        let mut est = 0.0f64;
        let trials = 40;
        for t in 0..trials {
            let fm = FeatureMap::sample(
                FeatureKind::Softmax, 512, d, OrfMechanism::Regular, &mut rng.fork(t as u64));
            let qp = fm.apply(&q);
            let kp = fm.apply(&k);
            est += crate::tensor::dot(qp.row(0), kp.row(0)) as f64;
        }
        est /= trials as f64;
        let rel = ((est - exact as f64) / exact as f64).abs();
        assert!(rel < 0.05, "estimate {est} vs exact {exact} (rel {rel})");
    }

    /// FAVOR+ positive features are an unbiased estimator of the same
    /// softmax kernel (the stabilizer clamp never engages on typical
    /// inputs, so no correction factor is needed).
    #[test]
    fn positive_features_estimate_attention_kernel() {
        let d = 8;
        let mut rng = Pcg64::new(5);
        let q = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let k = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let exact = (crate::tensor::dot(q.row(0), k.row(0)) / (d as f32).sqrt()).exp() as f64;

        let mut est = 0.0f64;
        let trials = 40;
        for t in 0..trials {
            let fm = FeatureMap::sample(
                FeatureKind::Positive, 512, d, OrfMechanism::Regular, &mut rng.fork(t as u64));
            let qp = fm.apply(&q);
            let kp = fm.apply(&k);
            est += crate::tensor::dot(qp.row(0), kp.row(0)) as f64;
        }
        est /= trials as f64;
        let rel = ((est - exact) / exact).abs();
        assert!(rel < 0.1, "estimate {est} vs exact {exact} (rel {rel})");
    }

    #[test]
    fn positive_features_strictly_positive_and_bounded() {
        let mut rng = Pcg64::new(6);
        let fm = FeatureMap::sample(FeatureKind::Positive, 32, 8, OrfMechanism::Regular, &mut rng);
        // adversarially large inputs included: the row-local stabilizer
        // caps the exponent at EXP_CLAMP, so phi stays finite, strictly
        // positive and bounded
        let hi = EXP_CLAMP.exp() / (32f32).sqrt() + fm.kernel_eps;
        for scale in [1.0f32, 10.0, 100.0, 1000.0] {
            let x = Mat::from_vec(
                6, 8, rng.gaussian_vec(48).iter().map(|v| v * scale).collect());
            let phi = fm.apply(&x);
            assert!(
                phi.data.iter().all(|&v| v.is_finite() && v > 0.0 && v <= hi * 1.001),
                "scale {scale}: features left (0, exp(EXP_CLAMP)/sqrt(M)]"
            );
        }
    }

    #[test]
    fn exp_features_clamped_not_poisoned() {
        // regression: unguarded t.exp() overflowed to inf for large
        // projections and turned the whole row non-finite
        let mut rng = Pcg64::new(7);
        let fm = FeatureMap::sample(FeatureKind::Exp, 16, 8, OrfMechanism::Regular, &mut rng);
        let x = Mat::from_vec(
            4, 8, rng.gaussian_vec(32).iter().map(|v| v * 1000.0).collect());
        let phi = fm.apply(&x);
        assert!(
            phi.data.iter().all(|v| v.is_finite() && *v > 0.0),
            "clamped exp features must stay finite and positive"
        );
        // the clamp is the documented ceiling
        let top = (EXP_CLAMP.exp()) / (16f32).sqrt() + fm.kernel_eps;
        assert!(phi.data.iter().all(|&v| v <= top * 1.001));
    }

    #[test]
    fn apply_block_matches_apply_on_copied_slice_bitwise() {
        let mut rng = Pcg64::new(8);
        for kind in [FeatureKind::Softmax, FeatureKind::Positive, FeatureKind::Relu] {
            let fm = FeatureMap::sample(kind, 24, 6, OrfMechanism::Regular, &mut rng);
            // a wide stacked matrix; the head block lives at columns 4..10
            let x = Mat::from_vec(9, 16, rng.gaussian_vec(144));
            let blk = fm.apply_block(&x, 2, 8, 4);
            let copied = Mat::from_fn(6, 6, |i, j| x.at(2 + i, 4 + j));
            let direct = fm.apply(&copied);
            assert_eq!(blk.data, direct.data, "{kind:?}: in-place block phi diverged");
        }
    }

    #[test]
    fn orf_lower_variance_than_iid() {
        // Sec. 3 / Fig. 2: orthogonal features reduce estimator variance.
        let d = 8;
        let m = 8;
        let mut rng = Pcg64::new(42);
        let q = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.6).collect());
        let k = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.6).collect());
        let exact = (crate::tensor::dot(q.row(0), k.row(0)) / (d as f32).sqrt()).exp() as f64;

        let var = |mech: OrfMechanism, rng: &mut Pcg64| -> f64 {
            let trials = 300;
            let mut sq = 0.0;
            for t in 0..trials {
                let fm = FeatureMap::sample(FeatureKind::Softmax, m, d, mech, &mut rng.fork(t));
                let e = crate::tensor::dot(fm.apply(&q).row(0), fm.apply(&k).row(0)) as f64;
                sq += (e - exact) * (e - exact);
            }
            sq / trials as f64
        };
        let v_iid = var(OrfMechanism::Iid, &mut rng);
        let v_orf = var(OrfMechanism::Regular, &mut rng);
        assert!(v_orf < v_iid, "ORF variance {v_orf} should beat iid {v_iid}");
    }

    #[test]
    fn relu_features_nonnegative() {
        let mut rng = Pcg64::new(1);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 8, OrfMechanism::Regular, &mut rng);
        let x = Mat::from_vec(4, 8, rng.gaussian_vec(32));
        let phi = fm.apply(&x);
        assert!(phi.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn feature_shapes() {
        let mut rng = Pcg64::new(2);
        for kind in [FeatureKind::Softmax, FeatureKind::Positive, FeatureKind::Relu, FeatureKind::Tanh] {
            let fm = FeatureMap::sample(kind, 24, 8, OrfMechanism::Iid, &mut rng);
            let x = Mat::from_vec(5, 8, rng.gaussian_vec(40));
            let phi = fm.apply(&x);
            assert_eq!((phi.rows, phi.cols), (5, 24));
            assert!(phi.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn resample_changes_projection() {
        let mut rng = Pcg64::new(3);
        let mut fm = FeatureMap::sample(FeatureKind::Relu, 8, 8, OrfMechanism::Regular, &mut rng);
        let w0 = fm.w.clone();
        fm.resample(OrfMechanism::Regular, &mut rng);
        assert!(w0.max_abs_diff(&fm.w) > 1e-3);
        assert_eq!((fm.w.rows, fm.w.cols), (8, 8));
    }

    #[test]
    fn parse_roundtrip() {
        for kind in FeatureKind::ALL {
            assert_eq!(FeatureKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(FeatureKind::parse("positive"), Some(FeatureKind::Positive));
        assert!(FeatureKind::parse("nope").is_none());
    }

    /// Finite-difference check of `vjp_block` for every feature kind.
    /// Inputs are resampled (deterministically) until every
    /// pre-activation sits away from the piecewise boundaries
    /// (ReLU/Abs kink at 0, the EXP_CLAMP ceiling), so the central
    /// difference never straddles a subgradient switch.
    #[test]
    fn vjp_block_matches_finite_differences() {
        let (l, d, m) = (5usize, 6usize, 16usize);
        let (row_lo, col_lo) = (1usize, 3usize);
        let eps = 1e-3f32;
        for (ki, &kind) in FeatureKind::ALL.iter().enumerate() {
            let mut rng = Pcg64::new(100 + ki as u64);
            let fm = FeatureMap::sample(kind, m, d, OrfMechanism::Regular, &mut rng);
            // a ±eps nudge of one input moves any z by at most eps·max|w|
            let wmax = fm.w.data.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let margin = 2.0 * eps * wmax + 1e-2;
            let mut x = None;
            for t in 0..200u64 {
                let cand = Mat::from_vec(
                    l + 2,
                    12,
                    rng.fork(t).gaussian_vec((l + 2) * 12).iter().map(|v| v * 0.5).collect(),
                );
                let blk = Mat::from_fn(l, d, |i, j| cand.at(row_lo + i, col_lo + j));
                let z = blk.matmul(&fm.w.t());
                if z.data.iter().all(|&v| v.abs() > margin && (EXP_CLAMP - v).abs() > margin) {
                    x = Some(cand);
                    break;
                }
            }
            let x = x.unwrap_or_else(|| panic!("{kind:?}: no boundary-free input in 200 draws"));
            let dphi = Mat::from_vec(l, m, rng.gaussian_vec(l * m));

            let mut dx = Mat::zeros(x.rows, x.cols);
            fm.vjp_block(&x, row_lo, row_lo + l, col_lo, &dphi, &mut dx);

            let probe = |xp: &Mat| -> f64 {
                let phi = fm.apply_block(xp, row_lo, row_lo + l, col_lo);
                phi.data.iter().zip(&dphi.data).map(|(&p, &d)| p as f64 * d as f64).sum()
            };
            for i in 0..l {
                for j in 0..d {
                    let mut hi = x.clone();
                    *hi.at_mut(row_lo + i, col_lo + j) += eps;
                    let mut lo = x.clone();
                    *lo.at_mut(row_lo + i, col_lo + j) -= eps;
                    let fd = (probe(&hi) - probe(&lo)) / (2.0 * eps as f64);
                    let an = dx.at(row_lo + i, col_lo + j) as f64;
                    let tol = 2e-3 + 2e-2 * fd.abs().max(an.abs());
                    assert!(
                        (fd - an).abs() <= tol,
                        "{kind:?} d x[{i}][{j}]: fd {fd} vs analytic {an}"
                    );
                }
            }
            // entries outside the block are never written
            for i in 0..x.rows {
                for j in 0..x.cols {
                    let inside =
                        (row_lo..row_lo + l).contains(&i) && (col_lo..col_lo + d).contains(&j);
                    assert!(inside || dx.at(i, j) == 0.0, "{kind:?}: wrote outside block");
                }
            }
            // vjp_block accumulates: a second pass doubles the block
            let mut dx2 = dx.clone();
            fm.vjp_block(&x, row_lo, row_lo + l, col_lo, &dphi, &mut dx2);
            for i in 0..l {
                for j in 0..d {
                    let once = dx.at(row_lo + i, col_lo + j);
                    let twice = dx2.at(row_lo + i, col_lo + j);
                    assert!((twice - 2.0 * once).abs() <= 1e-6 * once.abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn parse_or_err_lists_valid_kinds() {
        let err = FeatureKind::parse_or_err("reluu").unwrap_err().to_string();
        assert!(err.contains("reluu"), "{err}");
        for name in ["softmax", "favor+", "relu", "identity"] {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }
}
