//! Random-feature maps phi: R^d -> R^M (Sec. 2.3).
//!
//! Softmax features (Eq. 10 + the D_Q/D_K renormalizers of Eq. 5-6):
//!   phi'(x) = exp(||x||²/r) · sqrt(2/M) · cos(Wx + b),  r = 2√d,
//!   W rows ~ N(0, I/√d)  (Gaussian kernel bandwidth σ_B = d^{1/4}),
//!   so that E[phi'(q)·phi'(k)] = exp(q·k/√d) = A_ij exactly.
//!
//! Generalized-attention features (Sec. 2.2, Appendix B.3):
//!   phi(x) = f(Wx)/√M + ε,  W rows ~ N(0, I), f ∈ {ReLU, sigmoid, ...}.

use crate::linalg::{projection_matrix, OrfMechanism};
use crate::rng::Pcg64;
use crate::tensor::Mat;

/// The nonlinearity f in phi(x) = c/sqrt(M) f(Wx + b) (Eq. 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// cos features + exp renormalizers: unbiased softmax-attention
    /// estimator (the paper's "Performer-SOFTMAX").
    Softmax,
    /// Generalized attention with the given f (paper default: ReLU).
    Relu,
    Sigmoid,
    Exp,
    Abs,
    Gelu,
    Cos,
    Tanh,
    Identity,
}

impl FeatureKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "softmax" => Self::Softmax,
            "relu" => Self::Relu,
            "sigmoid" => Self::Sigmoid,
            "exp" => Self::Exp,
            "abs" => Self::Abs,
            "gelu" => Self::Gelu,
            "cos" => Self::Cos,
            "tanh" => Self::Tanh,
            "identity" => Self::Identity,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Softmax => "softmax",
            Self::Relu => "relu",
            Self::Sigmoid => "sigmoid",
            Self::Exp => "exp",
            Self::Abs => "abs",
            Self::Gelu => "gelu",
            Self::Cos => "cos",
            Self::Tanh => "tanh",
            Self::Identity => "identity",
        }
    }

    fn apply(&self, t: f32) -> f32 {
        match self {
            Self::Softmax | Self::Cos => t.cos(),
            Self::Relu => t.max(0.0),
            Self::Sigmoid => 1.0 / (1.0 + (-t).exp()),
            Self::Exp => t.exp(),
            Self::Abs => t.abs(),
            Self::Gelu => 0.5 * t * (1.0 + (0.7978845608 * (t + 0.044715 * t * t * t)).tanh()),
            Self::Tanh => t.tanh(),
            Self::Identity => t,
        }
    }
}

/// A sampled feature map: projection W (M×d), bias b (M), and the scaling
/// conventions for the chosen kind.
#[derive(Clone, Debug)]
pub struct FeatureMap {
    pub kind: FeatureKind,
    pub w: Mat,
    pub b: Vec<f32>,
    pub kernel_eps: f32,
    d: usize,
}

impl FeatureMap {
    /// Sample a feature map. `d` is the head dimension, `m` the number of
    /// random features M, `mech` the ORF mechanism of Sec. 2.4.
    pub fn sample(kind: FeatureKind, m: usize, d: usize, mech: OrfMechanism, rng: &mut Pcg64) -> Self {
        match kind {
            FeatureKind::Softmax => {
                let sigma = 1.0 / (d as f32).powf(0.25);
                let w = projection_matrix(m, d, mech, sigma, true, rng);
                let b: Vec<f32> =
                    (0..m).map(|_| rng.uniform_in(0.0, std::f64::consts::TAU) as f32).collect();
                FeatureMap { kind, w, b, kernel_eps: 0.0, d }
            }
            _ => {
                let w = projection_matrix(m, d, mech, 1.0, true, rng);
                FeatureMap { kind, w, b: vec![0.0; m], kernel_eps: 1e-3, d }
            }
        }
    }

    pub fn m(&self) -> usize {
        self.w.rows
    }

    /// Construct from raw parts (e.g. weights loaded from a checkpoint);
    /// w is M×d, b has length M.
    pub fn from_parts(kind: FeatureKind, w: Mat, b: Vec<f32>, kernel_eps: f32) -> FeatureMap {
        assert_eq!(w.rows, b.len(), "W rows must match b length");
        let d = w.cols;
        FeatureMap { kind, w, b, kernel_eps, d }
    }

    /// Resample W and b in place (the paper's periodic feature-redrawing
    /// strategy, Sec. 4.2) keeping kind/M/d fixed.
    pub fn resample(&mut self, mech: OrfMechanism, rng: &mut Pcg64) {
        *self = FeatureMap::sample(self.kind, self.m(), self.d, mech, rng);
    }

    /// phi'(X) for all rows of X (L×d) -> (L×M).
    pub fn apply(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.d);
        let m = self.m();
        let mut z = x.matmul(&self.w.t()); // (L, M)
        match self.kind {
            FeatureKind::Softmax => {
                let scale = (2.0 / m as f32).sqrt();
                let r = 2.0 * (self.d as f32).sqrt();
                for i in 0..x.rows {
                    let norm_sq: f32 = x.row(i).iter().map(|v| v * v).sum();
                    let diag = (norm_sq / r).exp();
                    for j in 0..m {
                        let v = z.at(i, j) + self.b[j];
                        *z.at_mut(i, j) = diag * scale * v.cos() + self.kernel_eps;
                    }
                }
            }
            kind => {
                let scale = 1.0 / (m as f32).sqrt();
                for v in &mut z.data {
                    *v = scale * kind.apply(*v) + self.kernel_eps;
                }
            }
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Softmax features are an unbiased estimator of exp(q·k/√d):
    /// with many features the Monte-Carlo estimate concentrates.
    #[test]
    fn softmax_features_estimate_attention_kernel() {
        let d = 8;
        let mut rng = Pcg64::new(0);
        let q = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let k = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.5).collect());
        let exact = (crate::tensor::dot(q.row(0), k.row(0)) / (d as f32).sqrt()).exp();

        let mut est = 0.0f64;
        let trials = 40;
        for t in 0..trials {
            let fm = FeatureMap::sample(
                FeatureKind::Softmax, 512, d, OrfMechanism::Regular, &mut rng.fork(t as u64));
            let qp = fm.apply(&q);
            let kp = fm.apply(&k);
            est += crate::tensor::dot(qp.row(0), kp.row(0)) as f64;
        }
        est /= trials as f64;
        let rel = ((est - exact as f64) / exact as f64).abs();
        assert!(rel < 0.05, "estimate {est} vs exact {exact} (rel {rel})");
    }

    #[test]
    fn orf_lower_variance_than_iid() {
        // Sec. 3 / Fig. 2: orthogonal features reduce estimator variance.
        let d = 8;
        let m = 8;
        let mut rng = Pcg64::new(42);
        let q = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.6).collect());
        let k = Mat::from_vec(1, d, rng.gaussian_vec(d).iter().map(|v| v * 0.6).collect());
        let exact = (crate::tensor::dot(q.row(0), k.row(0)) / (d as f32).sqrt()).exp() as f64;

        let var = |mech: OrfMechanism, rng: &mut Pcg64| -> f64 {
            let trials = 300;
            let mut sq = 0.0;
            for t in 0..trials {
                let fm = FeatureMap::sample(FeatureKind::Softmax, m, d, mech, &mut rng.fork(t));
                let e = crate::tensor::dot(fm.apply(&q).row(0), fm.apply(&k).row(0)) as f64;
                sq += (e - exact) * (e - exact);
            }
            sq / trials as f64
        };
        let v_iid = var(OrfMechanism::Iid, &mut rng);
        let v_orf = var(OrfMechanism::Regular, &mut rng);
        assert!(v_orf < v_iid, "ORF variance {v_orf} should beat iid {v_iid}");
    }

    #[test]
    fn relu_features_nonnegative() {
        let mut rng = Pcg64::new(1);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 8, OrfMechanism::Regular, &mut rng);
        let x = Mat::from_vec(4, 8, rng.gaussian_vec(32));
        let phi = fm.apply(&x);
        assert!(phi.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn feature_shapes() {
        let mut rng = Pcg64::new(2);
        for kind in [FeatureKind::Softmax, FeatureKind::Relu, FeatureKind::Tanh] {
            let fm = FeatureMap::sample(kind, 24, 8, OrfMechanism::Iid, &mut rng);
            let x = Mat::from_vec(5, 8, rng.gaussian_vec(40));
            let phi = fm.apply(&x);
            assert_eq!((phi.rows, phi.cols), (5, 24));
            assert!(phi.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn resample_changes_projection() {
        let mut rng = Pcg64::new(3);
        let mut fm = FeatureMap::sample(FeatureKind::Relu, 8, 8, OrfMechanism::Regular, &mut rng);
        let w0 = fm.w.clone();
        fm.resample(OrfMechanism::Regular, &mut rng);
        assert!(w0.max_abs_diff(&fm.w) > 1e-3);
        assert_eq!((fm.w.rows, fm.w.cols), (8, 8));
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["softmax", "relu", "sigmoid", "exp", "abs", "gelu", "cos", "tanh", "identity"] {
            assert_eq!(FeatureKind::parse(name).unwrap().name(), name);
        }
        assert!(FeatureKind::parse("nope").is_none());
    }
}
