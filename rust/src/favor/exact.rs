//! Exact softmax attention (Eq. 1/2) — the O(L²) baseline every figure
//! compares against — plus the "identity attention" used for the
//! "X (OPT)" line of Fig. 1 (attention simply returns V: the maximum
//! possible speedup any attention replacement could achieve).

use crate::tensor::Mat;

use super::Direction;

/// Att(Q,K,V) = D^{-1} A V with A = exp(QKᵀ/√d); `tril` applied for the
/// unidirectional case. Numerically-stable row softmax.
pub fn exact_attention(q: &Mat, k: &Mat, v: &Mat, dir: Direction) -> Mat {
    let (l, d) = (q.rows, q.cols);
    assert_eq!(k.rows, l);
    assert_eq!(v.rows, l);
    let scale = 1.0 / (d as f32).sqrt();
    let mut a = q.matmul(&k.t());
    a.scale(scale);
    if dir == Direction::Unidirectional {
        for i in 0..l {
            for j in i + 1..l {
                *a.at_mut(i, j) = f32::NEG_INFINITY;
            }
        }
    }
    a.softmax_rows();
    a.matmul(v)
}

/// The raw (un-normalized) attention matrix A = exp(QKᵀ/√d), optionally
/// lower-triangular. Exposed for the approximation-error analyses
/// (Fig. 2) which measure ||Â − A||.
pub fn raw_attention_matrix(q: &Mat, k: &Mat, dir: Direction) -> Mat {
    let (l, d) = (q.rows, q.cols);
    let scale = 1.0 / (d as f32).sqrt();
    let mut a = q.matmul(&k.t());
    for val in &mut a.data {
        *val = (*val * scale).exp();
    }
    if dir == Direction::Unidirectional {
        for i in 0..l {
            for j in i + 1..l {
                *a.at_mut(i, j) = 0.0;
            }
        }
    }
    a
}

/// Identity attention: returns V untouched — Fig. 1's "X (OPT)" line.
pub fn identity_attention(_q: &Mat, _k: &Mat, v: &Mat, _dir: Direction) -> Mat {
    v.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        (
            Mat::from_vec(l, d, rng.gaussian_vec(l * d)),
            Mat::from_vec(l, d, rng.gaussian_vec(l * d)),
            Mat::from_vec(l, d, rng.gaussian_vec(l * d)),
        )
    }

    #[test]
    fn rows_are_convex_combinations() {
        let (q, k, v) = qkv(12, 4, 0);
        let out = exact_attention(&q, &k, &v, Direction::Bidirectional);
        for c in 0..4 {
            let lo = (0..12).map(|r| v.at(r, c)).fold(f32::INFINITY, f32::min);
            let hi = (0..12).map(|r| v.at(r, c)).fold(f32::NEG_INFINITY, f32::max);
            for r in 0..12 {
                assert!(out.at(r, c) >= lo - 1e-5 && out.at(r, c) <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn causal_first_row_is_v0() {
        let (q, k, v) = qkv(6, 3, 1);
        let out = exact_attention(&q, &k, &v, Direction::Unidirectional);
        for c in 0..3 {
            assert!((out.at(0, c) - v.at(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_prefix_invariance() {
        let (q, k, mut v) = qkv(10, 4, 2);
        let before = exact_attention(&q, &k, &v, Direction::Unidirectional);
        *v.at_mut(9, 0) = 100.0;
        let after = exact_attention(&q, &k, &v, Direction::Unidirectional);
        assert!(before.rows_slice(0, 9).max_abs_diff(&after.rows_slice(0, 9)) < 1e-6);
    }

    #[test]
    fn uniform_keys_average_values() {
        // If all q.k products are equal, attention averages V uniformly.
        let q = Mat::zeros(5, 4);
        let k = Mat::from_fn(5, 4, |_, _| 1.0);
        let v = Mat::from_fn(5, 2, |i, _| i as f32);
        let out = exact_attention(&q, &k, &v, Direction::Bidirectional);
        for r in 0..5 {
            assert!((out.at(r, 0) - 2.0).abs() < 1e-5); // mean of 0..4
        }
    }

    #[test]
    fn raw_matrix_positive_and_causal() {
        let (q, k, _) = qkv(8, 4, 3);
        let a = raw_attention_matrix(&q, &k, Direction::Unidirectional);
        for i in 0..8 {
            for j in 0..8 {
                if j > i {
                    assert_eq!(a.at(i, j), 0.0);
                } else {
                    assert!(a.at(i, j) > 0.0);
                }
            }
        }
    }

    #[test]
    fn identity_returns_v() {
        let (q, k, v) = qkv(4, 2, 4);
        assert_eq!(identity_attention(&q, &k, &v, Direction::Bidirectional).data, v.data);
    }
}
