//! FAVOR — Fast Attention Via Orthogonal Random features (the paper's
//! contribution), implemented natively for the coordinator's analysis
//! path and for the baselines the evaluation section compares against.
//!
//! The AOT/Pallas implementation of the same math lives in
//! `python/compile/kernels/favor.py` and is what the model artifacts run;
//! this native version powers the L3-side experiments that need direct
//! access to attention matrices (Figs. 2, 7–11, Thm. 1 checks) and the
//! scaling benches (Fig. 1/14/15 native series). The two implementations
//! are cross-checked in `rust/tests/native_vs_hlo.rs` (native vs AOT HLO
//! on identical weights); the native math itself is property-tested in
//! `rust/tests/prop_favor.rs` and `rust/tests/prop_stream.rs`.

pub mod analysis;
pub mod exact;
pub mod features;
pub mod kernel;
pub mod linear;
pub mod lsh;

pub use analysis::{attention_matrix_exact, attention_matrix_favor, l1_error, output_error, raw_attention_matrix_favor};
pub use exact::{exact_attention, identity_attention};
pub use features::{FeatureKind, FeatureMap};
pub use kernel::{
    epoch_aligned_segments, stack_next_boundary, AttentionKernel, Featurizer, KernelConfig,
};
pub use linear::{favor_attention, favor_bidirectional, favor_unidirectional};
pub use lsh::{lsh_attention, LshConfig};

/// Direction of the attention mechanism (Eq. 1 vs Eq. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// every position attends to every position (MLM encoder)
    Bidirectional,
    /// causal: position i attends to positions ≤ i (LM / streaming)
    Unidirectional,
}
