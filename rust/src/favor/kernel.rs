//! The pluggable attention-kernel layer: [`AttentionKernel`] owns the
//! feature map, the feature count M, the ORF mechanism and a
//! *deterministic redraw schedule*, so every consumer — the FAVOR
//! estimators, the native model stack, the streaming scorer, snapshots —
//! holds a kernel handle instead of a baked-in feature formula.
//!
//! ## Redraw epochs
//!
//! The paper's Sec. 4.2 feature redrawing becomes a serving-side
//! schedule: token positions `[e·R, (e+1)·R)` form redraw epoch `e`
//! (`R = redraw_every`; `R = 0` disables redrawing, one eternal epoch).
//! The draw for epoch `e` is a pure function of `(seed, e)` —
//! `Pcg64::new(seed).fork(e)` feeds `FeatureMap::sample` — so any
//! process, any time, reproduces the exact projection for any epoch: a
//! restored snapshot or a migrated session lands on bit-identical
//! features without shipping them.
//!
//! Because the causal prefix sums live in one draw's feature space, an
//! epoch boundary *resets* the carried attention state (context restarts
//! there); the model forward splits chunks internally at boundaries so
//! chunked == single-shot stays an exact invariant for any chunking —
//! see `train::NativeModel::forward_chunk_batch`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::jsonx::{num, obj, s, Json};
use crate::linalg::OrfMechanism;
use crate::rng::{fnv1a64_extend, Pcg64};
use crate::tensor::Mat;

use super::features::{FeatureKind, FeatureMap};

/// Cached epoch draws per kernel. Sessions only move forward through
/// epochs, so a small window is enough; the oldest draw is evicted.
const DRAW_CACHE: usize = 8;

/// Anything that can featurize query/key rows: a raw draw
/// ([`FeatureMap`]) or the epoch-aware [`AttentionKernel`] handle. The
/// FAVOR estimators (`favor::linear`, `favor::analysis`) are generic
/// over this, which is what makes the kernel layer pluggable.
pub trait Featurizer {
    /// Number of random features M.
    fn features(&self) -> usize;
    /// phi(X): (L×d) -> (L×M).
    fn phi(&self, x: &Mat) -> Mat;
}

impl Featurizer for FeatureMap {
    fn features(&self) -> usize {
        self.m()
    }

    fn phi(&self, x: &Mat) -> Mat {
        self.apply(x)
    }
}

/// The full identity of an attention kernel: feature kind, feature
/// count, ORF mechanism, and the deterministic redraw schedule
/// (seed + epoch length). Two models whose kernels differ in *any* of
/// these fields carry incompatible stream state — [`Self::signature`]
/// and the snapshot fingerprint are built from exactly these fields.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// feature kind (nonlinearity family)
    pub kind: FeatureKind,
    /// number of random features M
    pub m: usize,
    /// ORF mechanism for the projection draws
    pub mech: OrfMechanism,
    /// base seed of the deterministic draw schedule
    pub seed: u64,
    /// tokens per redraw epoch; 0 = never redraw
    pub redraw_every: u64,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            kind: FeatureKind::Relu,
            m: 32,
            mech: OrfMechanism::Regular,
            seed: 0x5eed,
            redraw_every: 0,
        }
    }
}

impl KernelConfig {
    /// Canonical one-line identity, used in fingerprints and reports.
    pub fn signature(&self) -> String {
        format!(
            "{}:m{}:{}:seed{:016x}:redraw{}",
            self.kind.name(),
            self.m,
            self.mech.name(),
            self.seed,
            self.redraw_every
        )
    }

    /// JSON form (inverse of [`Self::from_json`]).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", s(self.kind.name())),
            ("m", num(self.m as f64)),
            ("mech", s(self.mech.name())),
            // hex string: a u64 seed does not fit losslessly in an f64
            ("seed", s(&format!("{:016x}", self.seed))),
            ("redraw", num(self.redraw_every as f64)),
        ])
    }

    /// Parse the JSON form produced by [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<KernelConfig> {
        Ok(KernelConfig {
            kind: FeatureKind::parse_or_err(j.req("kind")?.as_str()?)?,
            m: j.req("m")?.as_usize()?,
            mech: OrfMechanism::parse_or_err(j.req("mech")?.as_str()?)?,
            seed: u64::from_str_radix(j.req("seed")?.as_str()?, 16)
                .context("kernel seed is not hex")?,
            redraw_every: j.req("redraw")?.as_f64()? as u64,
        })
    }
}

/// A configured attention kernel: the [`KernelConfig`] identity plus the
/// materialized draws. Epoch 0 is held directly (the hot path takes no
/// lock); later epochs are drawn deterministically on demand and cached.
#[derive(Debug)]
pub struct AttentionKernel {
    cfg: KernelConfig,
    d: usize,
    /// the epoch-0 draw: either sampled from `cfg.seed` or supplied by
    /// [`Self::from_feature_map`] (checkpoint-loaded weights)
    epoch0: Arc<FeatureMap>,
    /// deterministic draws for epochs > 0, cached up to [`DRAW_CACHE`]
    draws: Mutex<HashMap<u64, Arc<FeatureMap>>>,
}

impl Clone for AttentionKernel {
    fn clone(&self) -> Self {
        AttentionKernel {
            cfg: self.cfg.clone(),
            d: self.d,
            epoch0: self.epoch0.clone(),
            draws: Mutex::new(HashMap::new()),
        }
    }
}

impl AttentionKernel {
    /// Build a kernel for head dimension `d`, sampling the epoch-0 draw
    /// from the config's seed.
    pub fn new(cfg: KernelConfig, d: usize) -> AttentionKernel {
        assert!(cfg.m > 0 && d > 0, "attention kernel needs M > 0 and d > 0");
        let epoch0 = Arc::new(Self::draw(&cfg, d, 0));
        AttentionKernel { cfg, d, epoch0, draws: Mutex::new(HashMap::new()) }
    }

    /// Wrap an existing draw (e.g. features loaded from a checkpoint) as
    /// the kernel's eternal epoch 0. Loaded features cannot be redrawn —
    /// the schedule could not reproduce them — so `redraw_every` must
    /// be 0.
    pub fn from_feature_map(fm: FeatureMap, cfg: KernelConfig) -> AttentionKernel {
        assert_eq!(fm.m(), cfg.m, "feature map M must match the kernel config");
        assert_eq!(fm.kind, cfg.kind, "feature kind must match the kernel config");
        assert_eq!(
            cfg.redraw_every, 0,
            "a checkpoint-loaded feature map cannot be redrawn"
        );
        let d = fm.d();
        AttentionKernel { cfg, d, epoch0: Arc::new(fm), draws: Mutex::new(HashMap::new()) }
    }

    /// The deterministic draw for one epoch: a pure function of
    /// (seed, epoch) — no process state, no draw history.
    fn draw(cfg: &KernelConfig, d: usize, epoch: u64) -> FeatureMap {
        let mut base = Pcg64::new(cfg.seed);
        let mut rng = base.fork(epoch);
        FeatureMap::sample(cfg.kind, cfg.m, d, cfg.mech, &mut rng)
    }

    /// The kernel's full identity.
    pub fn config(&self) -> &KernelConfig {
        &self.cfg
    }

    /// Feature-kind shorthand for [`Self::config`].
    pub fn kind(&self) -> FeatureKind {
        self.cfg.kind
    }

    /// Number of random features M.
    pub fn m(&self) -> usize {
        self.cfg.m
    }

    /// Head dimension d.
    pub fn d(&self) -> usize {
        self.d
    }

    /// The redraw epoch containing stream position `pos`.
    pub fn epoch_of(&self, pos: u64) -> u64 {
        if self.cfg.redraw_every == 0 { 0 } else { pos / self.cfg.redraw_every }
    }

    /// The next redraw boundary strictly after `pos` (None = never).
    pub fn next_boundary(&self, pos: u64) -> Option<u64> {
        if self.cfg.redraw_every == 0 {
            None
        } else {
            Some((pos / self.cfg.redraw_every + 1) * self.cfg.redraw_every)
        }
    }

    /// The feature map for a redraw epoch — bit-reproducible for any
    /// epoch in any process (see module docs).
    pub fn map_for_epoch(&self, epoch: u64) -> Arc<FeatureMap> {
        if epoch == 0 {
            return self.epoch0.clone();
        }
        let mut cache = self.draws.lock().expect("kernel draw cache poisoned");
        if let Some(fm) = cache.get(&epoch) {
            return fm.clone();
        }
        let _span = crate::obs::trace::span_n("kernel_redraw", epoch);
        let fm = Arc::new(Self::draw(&self.cfg, self.d, epoch));
        if cache.len() >= DRAW_CACHE {
            // sessions stream forward: the smallest epoch is the coldest
            let oldest = *cache.keys().min().expect("non-empty cache");
            cache.remove(&oldest);
        }
        cache.insert(epoch, fm.clone());
        fm
    }

    /// The epoch-0 draw (the kernel's identity draw for stateless uses:
    /// full-sequence estimators, attention-matrix capture, digests).
    pub fn feature_map(&self) -> &FeatureMap {
        &self.epoch0
    }

    /// Fold the kernel's full identity into a running FNV-1a digest:
    /// the config signature plus every byte of the epoch-0 draw, so two
    /// kernels that differ only in schedule (or only in the materialized
    /// features) digest differently.
    pub fn digest_into(&self, h: &mut u64) {
        *h = fnv1a64_extend(*h, self.cfg.signature().as_bytes());
        for v in &self.epoch0.w.data {
            *h = fnv1a64_extend(*h, &v.to_le_bytes());
        }
        for v in &self.epoch0.b {
            *h = fnv1a64_extend(*h, &v.to_le_bytes());
        }
    }
}

/// The next redraw boundary strictly after stream position `pos`
/// across a whole layer stack: the minimum over every kernel's own
/// [`AttentionKernel::next_boundary`] (None = no kernel redraws). Both
/// the streamed forward (`NativeModel::forward_chunk_batch`) and the
/// SLiM chunked trainer split their segments here, which is the
/// alignment rule that keeps chunked == single-shot exact under
/// redrawing.
pub fn stack_next_boundary(kernels: &[AttentionKernel], pos: u64) -> Option<u64> {
    kernels.iter().filter_map(|k| k.next_boundary(pos)).min()
}

/// Split the span `[pos, pos+len)` of stream positions into
/// epoch-aligned segments: maximal runs that no kernel's redraw
/// schedule cuts, returned as `(start, end)` offsets **relative to the
/// span**. Concatenated they cover the span exactly; every segment is
/// non-empty. An empty span yields no segments.
pub fn epoch_aligned_segments(
    kernels: &[AttentionKernel],
    pos: u64,
    len: usize,
) -> Vec<(usize, usize)> {
    let mut segs = Vec::new();
    let mut cur = 0usize;
    while cur < len {
        let end = match stack_next_boundary(kernels, pos + cur as u64) {
            Some(boundary) => ((boundary - pos) as usize).min(len),
            None => len,
        };
        segs.push((cur, end));
        cur = end;
    }
    segs
}

/// A kernel handle featurizes with its **epoch-0 draw**, always: the
/// generic estimators are stateless full-sequence views with no stream
/// position, so there is no epoch to select. On a kernel with a live
/// redraw schedule this means `favor_attention(&kernel, ...)` /
/// `attention_matrix_favor(&kernel, ...)` describe epoch 0 only — the
/// analysis semantics `NativeModel`'s attention capture documents — and
/// will diverge from a streamed forward past the first boundary. Use
/// [`AttentionKernel::map_for_epoch`] explicitly to featurize a
/// specific epoch.
impl Featurizer for AttentionKernel {
    fn features(&self) -> usize {
        self.cfg.m
    }

    fn phi(&self, x: &Mat) -> Mat {
        self.epoch0.apply(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(redraw: u64) -> KernelConfig {
        KernelConfig { kind: FeatureKind::Relu, m: 16, seed: 42, redraw_every: redraw, ..Default::default() }
    }

    #[test]
    fn epoch_arithmetic() {
        let k = AttentionKernel::new(cfg(64), 8);
        assert_eq!(k.epoch_of(0), 0);
        assert_eq!(k.epoch_of(63), 0);
        assert_eq!(k.epoch_of(64), 1);
        assert_eq!(k.next_boundary(0), Some(64));
        assert_eq!(k.next_boundary(63), Some(64));
        assert_eq!(k.next_boundary(64), Some(128));
        let never = AttentionKernel::new(cfg(0), 8);
        assert_eq!(never.epoch_of(1 << 40), 0);
        assert_eq!(never.next_boundary(1 << 40), None);
    }

    #[test]
    fn epoch_aligned_segments_cut_at_every_schedule() {
        // two schedules, 6 and 10: cuts land on multiples of either
        let kernels =
            vec![AttentionKernel::new(cfg(6), 8), AttentionKernel::new(cfg(10), 8)];
        let segs = epoch_aligned_segments(&kernels, 4, 20);
        // span [4, 24): boundaries at 6, 10, 12, 18, 20 → relative cuts
        assert_eq!(segs, vec![(0, 2), (2, 6), (6, 8), (8, 14), (14, 16), (16, 20)]);
        // segments tile the span exactly
        let mut cur = 0;
        for &(a, b) in &segs {
            assert_eq!(a, cur);
            assert!(b > a);
            cur = b;
        }
        assert_eq!(cur, 20);
        // no schedule → one segment; empty span → none
        let none = vec![AttentionKernel::new(cfg(0), 8)];
        assert_eq!(epoch_aligned_segments(&none, 7, 5), vec![(0, 5)]);
        assert!(epoch_aligned_segments(&kernels, 0, 0).is_empty());
    }

    #[test]
    fn redraws_are_deterministic_and_distinct() {
        let a = AttentionKernel::new(cfg(32), 8);
        let b = AttentionKernel::new(cfg(32), 8);
        for e in [0u64, 1, 2, 7] {
            // same config => bit-identical draw, in any process
            assert_eq!(a.map_for_epoch(e).w.data, b.map_for_epoch(e).w.data, "epoch {e}");
        }
        // distinct epochs => distinct projections
        assert!(a.map_for_epoch(0).w.max_abs_diff(&a.map_for_epoch(1).w) > 1e-3);
        // cached draws are stable across repeated lookups
        let first = a.map_for_epoch(3).w.data.clone();
        assert_eq!(first, a.map_for_epoch(3).w.data);
    }

    #[test]
    fn kernel_phi_equals_epoch0_feature_map() {
        let mut rng = Pcg64::new(9);
        let k = AttentionKernel::new(cfg(0), 8);
        let x = Mat::from_vec(5, 8, rng.gaussian_vec(40));
        assert_eq!(k.phi(&x).data, k.feature_map().apply(&x).data);
        assert_eq!(k.features(), 16);
    }

    #[test]
    fn config_json_roundtrip_and_signature() {
        let c = KernelConfig {
            kind: FeatureKind::Positive,
            m: 64,
            mech: OrfMechanism::Hadamard,
            seed: 0xdead_beef,
            redraw_every: 4096,
        };
        let back = KernelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        let sig = c.signature();
        assert!(sig.contains("favor+") && sig.contains("m64") && sig.contains("redraw4096"));
        assert_ne!(sig, KernelConfig { redraw_every: 0, ..c }.signature());
    }

    #[test]
    fn from_feature_map_pins_the_draw() {
        let mut rng = Pcg64::new(11);
        let fm = FeatureMap::sample(FeatureKind::Relu, 16, 4, OrfMechanism::Regular, &mut rng);
        let w = fm.w.clone();
        let k = AttentionKernel::from_feature_map(
            fm,
            KernelConfig { kind: FeatureKind::Relu, m: 16, seed: 0, redraw_every: 0, ..Default::default() },
        );
        assert_eq!(k.map_for_epoch(0).w.data, w.data);
        assert_eq!(k.d(), 4);
    }

    #[test]
    fn digest_separates_schedule_and_draw() {
        let a = AttentionKernel::new(cfg(0), 8);
        let b = AttentionKernel::new(cfg(64), 8); // same draw, different schedule
        let c = AttentionKernel::new(KernelConfig { seed: 43, ..cfg(0) }, 8);
        let digest = |k: &AttentionKernel| {
            let mut h = crate::rng::FNV1A64_SEED;
            k.digest_into(&mut h);
            h
        };
        assert_ne!(digest(&a), digest(&b), "redraw schedule must be part of the identity");
        assert_ne!(digest(&a), digest(&c), "the draw itself must be part of the identity");
    }
}
