//! Reformer-style LSH attention baseline [29].
//!
//! The paper's sparse-attention comparator (Fig. 4): shared-QK attention
//! restricted to hash buckets found by random-rotation LSH, chunked with
//! one-chunk lookback. This is a faithful *simplified* Reformer: single
//! hash round, stable bucket sort, no reversible layers (those affect
//! training memory, not the attention pattern).

use crate::rng::Pcg64;
use crate::tensor::Mat;

use super::Direction;

#[derive(Clone, Debug)]
/// Bucketing geometry for the LSH attention baseline.
pub struct LshConfig {
    /// number of hash buckets
    pub n_buckets: usize,
    /// rows per sorted chunk (attention looks back one chunk)
    pub chunk: usize,
}

impl LshConfig {
    /// Reasonable geometry for sequence length l.
    pub fn for_len(l: usize) -> Self {
        let chunk = (l / 8).max(8).min(64);
        LshConfig { n_buckets: (l / chunk).max(2), chunk }
    }
}

/// Rotation-LSH bucket ids: argmax([xR, -xR]) per row (Andoni et al.,
/// as used by Reformer).
pub fn lsh_buckets(x: &Mat, rot: &Mat) -> Vec<usize> {
    let half = rot.cols;
    let proj = x.matmul(rot);
    (0..x.rows)
        .map(|i| {
            let row = proj.row(i);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (j, &v) in row.iter().enumerate() {
                if v > best_v {
                    best_v = v;
                    best = j;
                }
                if -v > best_v {
                    best_v = -v;
                    best = j + half;
                }
            }
            best
        })
        .collect()
}

/// LSH attention over a single head. `q` doubles as the shared-QK tensor
/// (rows are L2-normalized internally, per Reformer).
pub fn lsh_attention(
    q: &Mat,
    v: &Mat,
    dir: Direction,
    cfg: &LshConfig,
    rng: &mut Pcg64,
) -> Mat {
    let (l, d) = (q.rows, q.cols);
    assert_eq!(v.rows, l);
    assert!(l % cfg.chunk == 0, "L={l} must be divisible by chunk={}", cfg.chunk);

    // normalize shared QK
    let mut qk = q.clone();
    for i in 0..l {
        let n = qk.row(i).iter().map(|x| x * x).sum::<f32>().sqrt() + 1e-6;
        for x in qk.row_mut(i) {
            *x /= n;
        }
    }

    let rot = Mat::from_vec(d, cfg.n_buckets / 2 + 1, rng.gaussian_vec(d * (cfg.n_buckets / 2 + 1)));
    let buckets = lsh_buckets(&qk, &rot);

    // stable sort by bucket
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by_key(|&i| (buckets[i], i));

    let n_chunks = l / cfg.chunk;
    let scale = (d as f32).sqrt();
    let mut out = Mat::zeros(l, d);

    for c in 0..n_chunks {
        let prev = if c == 0 { n_chunks - 1 } else { c - 1 };
        // key set = own chunk + previous chunk (Reformer lookback)
        let keys: Vec<usize> = (0..cfg.chunk)
            .map(|i| order[c * cfg.chunk + i])
            .chain((0..cfg.chunk).map(|i| order[prev * cfg.chunk + i]))
            .collect();
        for qi in 0..cfg.chunk {
            let pos_q = order[c * cfg.chunk + qi];
            let qrow = qk.row(pos_q);
            let mut scores: Vec<f32> = keys
                .iter()
                .map(|&pos_k| {
                    if pos_k == pos_q {
                        return -1e5; // no self-attention (shared-QK convention)
                    }
                    if dir == Direction::Unidirectional && pos_k > pos_q {
                        return f32::NEG_INFINITY;
                    }
                    crate::tensor::dot(qrow, qk.row(pos_k)) * scale
                })
                .collect();
            // stable softmax; if everything is masked fall back to self
            let mx = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            if mx == f32::NEG_INFINITY {
                out.row_mut(pos_q).copy_from_slice(v.row(pos_q));
                continue;
            }
            let mut sum = 0.0;
            for s in &mut scores {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let orow = out.row_mut(pos_q);
            for (ki, &pos_k) in keys.iter().enumerate() {
                let wgt = scores[ki] / sum;
                if wgt > 0.0 {
                    crate::tensor::axpy(wgt, v.row(pos_k), orow);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_in_range() {
        let mut rng = Pcg64::new(0);
        let x = Mat::from_vec(32, 8, rng.gaussian_vec(256));
        let rot = Mat::from_vec(8, 4, rng.gaussian_vec(32));
        let b = lsh_buckets(&x, &rot);
        assert!(b.iter().all(|&v| v < 8));
    }

    #[test]
    fn similar_vectors_share_buckets() {
        let mut rng = Pcg64::new(1);
        let base = rng.gaussian_vec(8);
        let mut data = Vec::new();
        // 4 near-duplicates of base, 4 near-duplicates of -base
        for s in [1.0f32, -1.0] {
            for _ in 0..4 {
                for (j, &b) in base.iter().enumerate() {
                    data.push(s * b + 0.01 * rng.gaussian() as f32 * (j as f32 * 0.0 + 1.0));
                }
            }
        }
        let x = Mat::from_vec(8, 8, data);
        let rot = Mat::from_vec(8, 8, rng.gaussian_vec(64));
        let b = lsh_buckets(&x, &rot);
        assert_eq!(b[0], b[1]);
        assert_eq!(b[4], b[5]);
        assert_ne!(b[0], b[4], "opposite vectors must hash apart");
    }

    #[test]
    fn output_shape_and_finite() {
        let mut rng = Pcg64::new(2);
        let q = Mat::from_vec(64, 8, rng.gaussian_vec(512));
        let v = Mat::from_vec(64, 8, rng.gaussian_vec(512));
        let cfg = LshConfig { n_buckets: 4, chunk: 16 };
        let out = lsh_attention(&q, &v, Direction::Bidirectional, &cfg, &mut rng);
        assert_eq!((out.rows, out.cols), (64, 8));
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causal_never_uses_future_values() {
        let mut rng = Pcg64::new(3);
        let q = Mat::from_vec(32, 4, rng.gaussian_vec(128));
        let mut v = Mat::from_vec(32, 4, rng.gaussian_vec(128));
        let mut r1 = Pcg64::new(99);
        let out1 = lsh_attention(&q, &v, Direction::Unidirectional,
                                 &LshConfig { n_buckets: 4, chunk: 8 }, &mut r1);
        for c in 0..4 {
            *v.at_mut(31, c) = 50.0;
        }
        let mut r2 = Pcg64::new(99);
        let out2 = lsh_attention(&q, &v, Direction::Unidirectional,
                                 &LshConfig { n_buckets: 4, chunk: 8 }, &mut r2);
        assert!(out1.rows_slice(0, 31).max_abs_diff(&out2.rows_slice(0, 31)) < 1e-6);
    }

    #[test]
    fn config_divides_length() {
        for l in [64usize, 128, 512, 1024] {
            let cfg = LshConfig::for_len(l);
            assert_eq!(l % cfg.chunk, 0);
            assert!(cfg.n_buckets >= 2);
        }
    }
}
