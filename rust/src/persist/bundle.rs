//! Checkpoint directories as single self-verifying byte blobs, for
//! shipping a migration export over the wire.
//!
//! A [`crate::persist::Checkpointer`] export is a directory: a
//! `manifest.json` plus one `PFRMSNAP` file per session. Live session
//! migration between processes (`net::router`'s drain/rebalance path)
//! needs that directory to travel over a TCP connection as one payload,
//! so this module defines the `PFRMBNDL` envelope:
//!
//! ```text
//! "PFRMBNDL" | u32 version | u32 file_count
//!   file_count x ( u32 name_len | name | u64 data_len | data )
//! u32 CRC32 over everything above
//! ```
//!
//! All integers little-endian. The same refuse-don't-guess discipline as
//! `PFRMSNAP` applies: [`unbundle_into`] rejects truncation, trailing
//! bytes, bad magic/version/CRC and path-escaping file names outright,
//! and the unpacked directory is then re-validated by opening its
//! manifest (which checks every snapshot's length + CRC32 again) before
//! any session is adopted from it.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::checkpointer::{write_atomic, Checkpointer};
use super::snapshot::crc32;

/// Magic prefix of a checkpoint bundle.
pub const BUNDLE_MAGIC: &[u8; 8] = b"PFRMBNDL";

/// Current bundle envelope version.
pub const BUNDLE_VERSION: u32 = 1;

/// Hard ceiling on the number of files a bundle may claim — refuses
/// absurd headers before any allocation happens.
pub const MAX_BUNDLE_FILES: u32 = 1 << 20;

/// Longest file name a bundle entry may carry.
pub const MAX_BUNDLE_NAME: u32 = 4096;

const MANIFEST: &str = "manifest.json";

/// Pack a committed checkpoint directory (manifest + every snapshot it
/// references) into one `PFRMBNDL` blob. The directory is validated
/// through [`Checkpointer::open`] first, so a torn or half-written
/// export refuses to ship instead of poisoning the receiving shard.
pub fn bundle_dir(dir: &Path) -> Result<Vec<u8>> {
    let ck = Checkpointer::open(dir)
        .with_context(|| format!("bundling checkpoint at {}", dir.display()))?;
    let mut names = vec![MANIFEST.to_string()];
    for id in ck.ids() {
        let rec = ck.record(&id).expect("listed id has a record");
        names.push(rec.file.clone());
    }
    let mut out = Vec::new();
    out.extend_from_slice(BUNDLE_MAGIC);
    out.extend_from_slice(&BUNDLE_VERSION.to_le_bytes());
    out.extend_from_slice(&(names.len() as u32).to_le_bytes());
    for name in &names {
        let data = std::fs::read(dir.join(name))
            .with_context(|| format!("reading {name} for bundling"))?;
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(data.len() as u64).to_le_bytes());
        out.extend_from_slice(&data);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    Ok(out)
}

/// Unpack a `PFRMBNDL` blob into `dir` (created if missing) and
/// re-validate the result by opening its manifest. Returns the number
/// of sessions the unpacked checkpoint holds. Any corruption — bad
/// magic, unknown version, truncation, trailing bytes, CRC mismatch,
/// or a file name that would escape `dir` — is a hard error and
/// nothing half-unpacked is left behind as a valid checkpoint (the
/// manifest is only readable if every byte survived).
pub fn unbundle_into(bytes: &[u8], dir: &Path) -> Result<usize> {
    ensure!(bytes.len() >= BUNDLE_MAGIC.len() + 12, "bundle truncated: {} bytes", bytes.len());
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let actual = crc32(body);
    ensure!(
        stored == actual,
        "bundle checksum mismatch (stored {stored:#010x}, computed {actual:#010x})"
    );
    let mut r = Reader { buf: body };
    let magic = r.take(BUNDLE_MAGIC.len())?;
    ensure!(magic == BUNDLE_MAGIC, "not a PFRMBNDL bundle");
    let version = r.u32()?;
    ensure!(
        version == BUNDLE_VERSION,
        "unsupported bundle version {version} (this build reads {BUNDLE_VERSION})"
    );
    let count = r.u32()?;
    ensure!(count <= MAX_BUNDLE_FILES, "bundle claims {count} files — refusing");
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating bundle target {}", dir.display()))?;
    for _ in 0..count {
        let name_len = r.u32()?;
        ensure!(name_len <= MAX_BUNDLE_NAME, "bundle file name of {name_len} bytes — refusing");
        let name = std::str::from_utf8(r.take(name_len as usize)?)
            .context("bundle file name is not UTF-8")?
            .to_string();
        // names must stay inside the target directory
        if name.is_empty() || name.contains('/') || name.contains('\\') || name.contains("..") {
            bail!("bundle file name '{name}' would escape the target directory");
        }
        let data_len = r.u64()?;
        ensure!(
            data_len <= r.buf.len() as u64,
            "bundle entry '{name}' claims {data_len} bytes, only {} remain",
            r.buf.len()
        );
        let data = r.take(data_len as usize)?;
        write_atomic(&dir.join(&name), data)
            .with_context(|| format!("unpacking bundle entry '{name}'"))?;
    }
    ensure!(r.buf.is_empty(), "{} trailing bytes after the bundle's last entry", r.buf.len());
    let ck = Checkpointer::open(dir).context("validating the unpacked bundle")?;
    Ok(ck.len())
}

/// Strict little-endian cursor: every read either yields exactly the
/// requested bytes or errors.
struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.buf.len() >= n, "bundle truncated: wanted {n} bytes, {} left", self.buf.len());
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        Ok(head)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::rng::Pcg64;
    use crate::stream::ChunkScorer;
    use crate::train::{NativeModel, SyntheticConfig};

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pfrm_bundle_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample_export(dir: &Path) -> Arc<NativeModel> {
        let model =
            Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut Pcg64::new(0)));
        let mut ck = Checkpointer::create(dir).unwrap();
        for id in ["user-0", "user-1"] {
            let mut scorer = ChunkScorer::new(model.clone()).unwrap();
            scorer.advance(&[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
            ck.save(id, &scorer).unwrap();
        }
        model
    }

    #[test]
    fn roundtrip_restores_identical_files() {
        let src = tmp("src");
        let dst = tmp("dst");
        sample_export(&src);
        let blob = bundle_dir(&src).unwrap();
        let n = unbundle_into(&blob, &dst).unwrap();
        assert_eq!(n, 2);
        for name in std::fs::read_dir(&src).unwrap() {
            let name = name.unwrap().file_name();
            let a = std::fs::read(src.join(&name)).unwrap();
            let b = std::fs::read(dst.join(&name)).unwrap();
            assert_eq!(a, b, "{name:?} changed across the bundle round trip");
        }
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
    }

    #[test]
    fn corruption_refuses() {
        let src = tmp("corrupt");
        sample_export(&src);
        let blob = bundle_dir(&src).unwrap();
        // truncation at every prefix boundary class
        for cut in [0, 7, 12, 16, blob.len() / 2, blob.len() - 1] {
            let dst = tmp("corrupt_out");
            assert!(unbundle_into(&blob[..cut], &dst).is_err(), "cut at {cut} decoded");
        }
        // a single flipped bit anywhere fails the CRC
        for pos in [0, 9, blob.len() / 3, blob.len() - 2] {
            let mut bad = blob.clone();
            bad[pos] ^= 0x40;
            let dst = tmp("corrupt_out");
            assert!(unbundle_into(&bad, &dst).is_err(), "flip at {pos} decoded");
        }
        // trailing garbage is not tolerated
        let mut long = blob.clone();
        long.push(0);
        assert!(unbundle_into(&long, &tmp("corrupt_out")).is_err());
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&tmp("corrupt_out"));
    }

    #[test]
    fn escaping_names_refuse() {
        // hand-craft a bundle whose single entry tries to escape
        let mut body = Vec::new();
        body.extend_from_slice(BUNDLE_MAGIC);
        body.extend_from_slice(&BUNDLE_VERSION.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        let name = b"../evil";
        body.extend_from_slice(&(name.len() as u32).to_le_bytes());
        body.extend_from_slice(name);
        body.extend_from_slice(&0u64.to_le_bytes());
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let err = unbundle_into(&body, &tmp("escape")).unwrap_err();
        assert!(format!("{err:#}").contains("escape"), "wrong error: {err:#}");
    }
}
