//! A directory of session snapshots with a crash-safe manifest — the
//! storage layer behind `SessionManager`'s spill tier and the
//! coordinator's `checkpoint_all` / `restore_from` migration APIs.
//!
//! On-disk layout:
//!
//! ```text
//! <dir>/manifest.json        index of live snapshots (see below)
//! <dir>/<id-slug>-<fnv64>.snap   one PFRMSNAP envelope per session
//! ```
//!
//! Every mutation is crash-safe by construction: snapshot bytes and the
//! manifest are both written to a `.tmp` sibling, fsynced, then renamed
//! over the final name — a crash leaves either the old state or the new
//! state, never a torn file. The manifest records each snapshot's byte
//! length and whole-file CRC32; [`Checkpointer::load`] verifies both
//! (and the envelope re-verifies its own checksum), so a corrupt or
//! truncated snapshot fails loudly instead of restoring garbage.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::{arr, num, obj, s, Json};
use crate::stream::ChunkScorer;
use crate::train::NativeModel;

use super::snapshot::{crc32, SessionSnapshot};

const MANIFEST: &str = "manifest.json";
const MANIFEST_FORMAT: &str = "pfrm-session-manifest";
const MANIFEST_VERSION: usize = 1;

/// One manifest entry: where a session's snapshot lives and what its
/// bytes must look like.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotRecord {
    pub id: String,
    /// file name inside the checkpoint directory
    pub file: String,
    /// exact snapshot length in bytes
    pub bytes: u64,
    /// CRC32 over the whole snapshot file
    pub crc: u32,
    /// stream position the snapshot was taken at
    pub pos: u64,
}

/// A checkpoint directory: save/load/remove session snapshots, with the
/// manifest as the single source of truth for what is restorable.
pub struct Checkpointer {
    dir: PathBuf,
    records: BTreeMap<String, SnapshotRecord>,
}

impl Checkpointer {
    /// Open-or-create: makes the directory, adopts an existing manifest
    /// if one is present. The spill tier uses this — an empty directory
    /// is a valid (empty) checkpoint.
    pub fn create(dir: &Path) -> Result<Checkpointer> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let records = if dir.join(MANIFEST).exists() { read_manifest(dir)? } else { BTreeMap::new() };
        Ok(Checkpointer { dir: dir.to_path_buf(), records })
    }

    /// Open an existing checkpoint directory for restore. A missing or
    /// malformed manifest is a loud error — restoring from a directory
    /// we cannot fully account for must never silently succeed.
    pub fn open(dir: &Path) -> Result<Checkpointer> {
        if !dir.join(MANIFEST).exists() {
            bail!("{} has no {MANIFEST}: not a checkpoint directory", dir.display());
        }
        Ok(Checkpointer { dir: dir.to_path_buf(), records: read_manifest(dir)? })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn contains(&self, id: &str) -> bool {
        self.records.contains_key(id)
    }

    /// Session ids with a restorable snapshot, in sorted order.
    pub fn ids(&self) -> Vec<String> {
        self.records.keys().cloned().collect()
    }

    pub fn record(&self, id: &str) -> Option<&SnapshotRecord> {
        self.records.get(id)
    }

    /// Total bytes of snapshots on disk.
    pub fn total_bytes(&self) -> u64 {
        self.records.values().map(|r| r.bytes).sum()
    }

    /// Snapshot one session: write-temp-then-rename the envelope, then
    /// the updated manifest, so a crash at any point leaves the
    /// directory restorable (at worst without this session).
    pub fn save(&mut self, id: &str, scorer: &ChunkScorer) -> Result<SnapshotRecord> {
        let record = self.stage(id, scorer)?;
        self.commit()?;
        Ok(record)
    }

    /// Write one session's snapshot WITHOUT rewriting the manifest —
    /// the bulk-export building block (`checkpoint_all` stages every
    /// session, then [`Self::commit`]s once, instead of paying N
    /// manifest rewrites). Until commit, the new snapshot is invisible
    /// to restores: the on-disk manifest still describes the previous
    /// state — old or new, never torn.
    pub fn stage(&mut self, id: &str, scorer: &ChunkScorer) -> Result<SnapshotRecord> {
        let snap = SessionSnapshot::capture(id, scorer)?;
        let bytes = snap.to_bytes();
        let file = snapshot_filename(id);
        write_atomic(&self.dir.join(&file), &bytes)
            .with_context(|| format!("spilling session '{id}'"))?;
        let record = SnapshotRecord {
            id: id.to_string(),
            file,
            bytes: bytes.len() as u64,
            crc: crc32(&bytes),
            pos: scorer.tokens_seen() as u64,
        };
        self.records.insert(id.to_string(), record.clone());
        Ok(record)
    }

    /// Persist the manifest, making every staged snapshot restorable.
    pub fn commit(&mut self) -> Result<()> {
        self.write_manifest()
    }

    /// Drop every snapshot (files + records) and persist the now-empty
    /// manifest. `checkpoint_all` clears its target first, so a reused
    /// export directory can never resurrect sessions that have since
    /// closed, and a `SessionManager` clears its spill directory on
    /// startup — the spill tier caches one process's live sessions,
    /// never a dead process's (restart recovery is `checkpoint_all` /
    /// `restore_from`). Returns how many snapshots were removed.
    pub fn clear(&mut self) -> Result<usize> {
        let records = std::mem::take(&mut self.records);
        if records.is_empty() {
            return Ok(0);
        }
        for r in records.values() {
            let path = self.dir.join(&r.file);
            match std::fs::remove_file(&path) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(anyhow!("removing {}: {e}", path.display())),
            }
        }
        self.write_manifest()?;
        Ok(records.len())
    }

    /// Rehydrate one session into a scorer over `model`. Verifies the
    /// manifest record (length + CRC32) against the file before the
    /// envelope is even decoded; any mismatch is a loud error.
    pub fn load(&self, id: &str, model: &Arc<NativeModel>) -> Result<ChunkScorer> {
        let record = self
            .records
            .get(id)
            .ok_or_else(|| anyhow!("no snapshot for session '{id}' in {}", self.dir.display()))?;
        let path = self.dir.join(&record.file);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() as u64 != record.bytes {
            bail!(
                "{}: {} bytes on disk, manifest says {} — truncated or torn snapshot",
                path.display(),
                bytes.len(),
                record.bytes
            );
        }
        let crc = crc32(&bytes);
        if crc != record.crc {
            bail!(
                "{}: checksum {crc:#010x} does not match manifest {:#010x} — corrupt snapshot",
                path.display(),
                record.crc
            );
        }
        let snap = SessionSnapshot::from_bytes(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        if snap.session != id {
            bail!(
                "{} holds session '{}', manifest filed it under '{id}'",
                path.display(),
                snap.session
            );
        }
        snap.into_scorer(model.clone())
    }

    /// Drop a session's snapshot (file + manifest record). Returns
    /// whether one existed.
    pub fn remove(&mut self, id: &str) -> Result<bool> {
        let Some(record) = self.records.remove(id) else {
            return Ok(false);
        };
        let path = self.dir.join(&record.file);
        match std::fs::remove_file(&path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(anyhow!("removing {}: {e}", path.display())),
        }
        self.write_manifest()?;
        Ok(true)
    }

    fn write_manifest(&self) -> Result<()> {
        let manifest = obj(vec![
            ("format", s(MANIFEST_FORMAT)),
            ("version", num(MANIFEST_VERSION as f64)),
            (
                "sessions",
                arr(self.records.values().map(|r| {
                    obj(vec![
                        ("id", s(&r.id)),
                        ("file", s(&r.file)),
                        ("bytes", num(r.bytes as f64)),
                        ("crc", num(r.crc as f64)),
                        ("pos", num(r.pos as f64)),
                    ])
                })),
            ),
        ]);
        write_atomic(&self.dir.join(MANIFEST), manifest.to_string().as_bytes())
            .context("writing checkpoint manifest")
    }
}

fn read_manifest(dir: &Path) -> Result<BTreeMap<String, SnapshotRecord>> {
    let path = dir.join(MANIFEST);
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("{} is not valid JSON", path.display()))?;
    let format = j.req("format")?.as_str()?;
    if format != MANIFEST_FORMAT {
        bail!("{}: format '{format}' is not a session manifest", path.display());
    }
    let version = j.req("version")?.as_usize()?;
    if version != MANIFEST_VERSION {
        bail!("{}: unsupported manifest version {version}", path.display());
    }
    let mut records = BTreeMap::new();
    for e in j.req("sessions")?.as_arr()? {
        let r = SnapshotRecord {
            id: e.req("id")?.as_str()?.to_string(),
            file: e.req("file")?.as_str()?.to_string(),
            bytes: e.req("bytes")?.as_f64()? as u64,
            crc: e.req("crc")?.as_f64()? as u32,
            pos: e.req("pos")?.as_f64()? as u64,
        };
        if r.file.contains('/') || r.file.contains("..") {
            bail!("{}: record '{}' escapes the checkpoint dir", path.display(), r.file);
        }
        records.insert(r.id.clone(), r);
    }
    Ok(records)
}

/// Write bytes to `path` via a `.tmp` sibling + fsync + rename + parent
/// directory fsync — the crash-safety primitive every persist-layer
/// write goes through. Without the directory sync the rename itself is
/// not durable across power loss on journaling filesystems; it is
/// best-effort because not every platform lets a directory be opened
/// for syncing.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(parent) = path.parent() {
        let _ = std::fs::File::open(parent).and_then(|d| d.sync_all());
    }
    Ok(())
}

/// Filesystem-safe snapshot name: a sanitized prefix of the id for
/// humans, plus an FNV-1a hash of the full id so distinct sessions can
/// never collide on a shared sanitized prefix.
fn snapshot_filename(id: &str) -> String {
    let safe: String = id
        .chars()
        .take(40)
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '_' })
        .collect();
    format!("{safe}-{:016x}.snap", crate::rng::fnv1a64(id.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::SyntheticConfig;

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(31);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("pfrm_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_remove_lifecycle() {
        let dir = tempdir("lifecycle");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        assert!(ck.is_empty());

        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(20, 1)).unwrap();
        let rec = ck.save("user/1", &scorer).unwrap();
        assert_eq!(rec.pos, 20);
        assert!(ck.contains("user/1"));
        assert_eq!(ck.total_bytes(), rec.bytes);

        // a fresh handle over the same dir sees the manifest
        let ck2 = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck2.ids(), vec!["user/1".to_string()]);
        let restored = ck2.load("user/1", &m).unwrap();
        assert_eq!(restored.tokens_seen(), 20);

        let mut ck3 = Checkpointer::open(&dir).unwrap();
        assert!(ck3.remove("user/1").unwrap());
        assert!(!ck3.remove("user/1").unwrap());
        assert!(Checkpointer::open(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resave_overwrites_in_place() {
        let dir = tempdir("resave");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 2)).unwrap();
        ck.save("s", &scorer).unwrap();
        scorer.advance(&tokens(8, 3)).unwrap();
        ck.save("s", &scorer).unwrap();
        assert_eq!(ck.len(), 1);
        assert_eq!(ck.load("s", &m).unwrap().tokens_seen(), 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_snapshots_are_invisible_until_commit() {
        let dir = tempdir("stage");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 10)).unwrap();
        ck.stage("a", &scorer).unwrap();
        // a second handle (≈ another process) sees nothing yet
        assert!(Checkpointer::create(&dir).unwrap().is_empty());
        ck.commit().unwrap();
        assert_eq!(Checkpointer::open(&dir).unwrap().ids(), vec!["a".to_string()]);

        // clear drops files and records, and persists the empty manifest
        let mut ck = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck.clear().unwrap(), 1);
        assert_eq!(ck.clear().unwrap(), 0);
        assert!(Checkpointer::open(&dir).unwrap().is_empty());
        assert!(
            !std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .any(|e| e.path().extension().is_some_and(|x| x == "snap")),
            "clear must remove the snapshot files themselves"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_manifest_fails_loudly() {
        let dir = tempdir("badmanifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(MANIFEST), b"{not json").unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        // wrong format marker is also rejected
        std::fs::write(dir.join(MANIFEST), br#"{"format":"other","version":1,"sessions":[]}"#)
            .unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        // a record pointing outside the dir is rejected
        std::fs::write(
            dir.join(MANIFEST),
            br#"{"format":"pfrm-session-manifest","version":1,
                "sessions":[{"id":"x","file":"../x.snap","bytes":1,"crc":0,"pos":0}]}"#,
        )
        .unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_snapshot_fails_loudly() {
        let dir = tempdir("truncated");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(12, 4)).unwrap();
        let rec = ck.save("t", &scorer).unwrap();

        let path = dir.join(&rec.file);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = Checkpointer::open(&dir).unwrap().load("t", &m).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");

        // corrupt (right length, flipped byte) must fail the checksum
        let mut bad = bytes.clone();
        bad[bytes.len() / 2] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let err = Checkpointer::open(&dir).unwrap().load("t", &m).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_session_is_an_error() {
        let dir = tempdir("missing");
        let ck = Checkpointer::create(&dir).unwrap();
        assert!(ck.load("ghost", &model()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn filenames_are_safe_and_collision_free() {
        let a = snapshot_filename("user/../../etc/passwd");
        assert!(!a.contains('/') && a.ends_with(".snap"));
        // same sanitized prefix, different ids -> different files
        let b = snapshot_filename("user:1");
        let c = snapshot_filename("user_1");
        assert_ne!(b, c);
    }
}
