//! A directory of session snapshots with a crash-safe manifest — the
//! storage layer behind `SessionManager`'s spill tier and the
//! coordinator's `checkpoint_all` / `restore_from` migration APIs.
//!
//! On-disk layout:
//!
//! ```text
//! <dir>/manifest.json        index of live snapshots (see below)
//! <dir>/<id-slug>-<fnv64>.snap   one PFRMSNAP envelope per session
//! ```
//!
//! Every mutation is crash-safe by construction: snapshot bytes and the
//! manifest are both written to a `.tmp` sibling, fsynced, then renamed
//! over the final name — a crash leaves either the old state or the new
//! state, never a torn file. The manifest records each snapshot's byte
//! length and whole-file CRC32; [`Checkpointer::load`] verifies both
//! (and the envelope re-verifies its own checksum), so a corrupt or
//! truncated snapshot fails loudly instead of restoring garbage.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::{arr, num, obj, s, Json};
use crate::stream::ChunkScorer;
use crate::train::NativeModel;

use super::snapshot::{crc32, SessionSnapshot};

const MANIFEST: &str = "manifest.json";
const MANIFEST_FORMAT: &str = "pfrm-session-manifest";
/// v2 adds a top-level manifest `generation` plus per-record dirty
/// markers (`exporter`, `dirty_gen`) — the bookkeeping behind delta
/// exports. v1 manifests are still readable (markers default to
/// "unknown", so a delta export re-writes every record once).
const MANIFEST_VERSION: usize = 2;

/// One manifest entry: where a session's snapshot lives and what its
/// bytes must look like.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotRecord {
    /// session id the snapshot belongs to
    pub id: String,
    /// file name inside the checkpoint directory
    pub file: String,
    /// exact snapshot length in bytes
    pub bytes: u64,
    /// CRC32 over the whole snapshot file
    pub crc: u32,
    /// stream position the snapshot was taken at
    pub pos: u64,
    /// identity token of the `SessionManager` that captured the
    /// snapshot (0 = unknown/foreign). Together with [`Self::dirty_gen`]
    /// this is the delta-export dirty marker: a later export from the
    /// *same* manager can prove the session has not advanced since this
    /// record was written and retain it instead of re-snapshotting.
    pub exporter: u64,
    /// the session's dirty generation at capture time (meaningful only
    /// when `exporter` matches the asking manager)
    pub dirty_gen: u64,
}

/// A checkpoint directory: save/load/remove session snapshots, with the
/// manifest as the single source of truth for what is restorable.
pub struct Checkpointer {
    dir: PathBuf,
    records: BTreeMap<String, SnapshotRecord>,
    /// manifest generation: bumped by [`Self::commit_new_generation`]
    /// (every full or delta export), so observers can tell exports
    /// apart even when the record set is unchanged
    generation: u64,
    /// files superseded by staged-but-uncommitted changes (replaced or
    /// unstaged records). Deleted only *after* the next manifest commit:
    /// until then the on-disk manifest still references them, so a crash
    /// mid-export must leave every previously committed snapshot intact
    garbage: Vec<String>,
}

impl Checkpointer {
    /// Open-or-create: makes the directory, adopts an existing manifest
    /// if one is present. The spill tier uses this — an empty directory
    /// is a valid (empty) checkpoint.
    pub fn create(dir: &Path) -> Result<Checkpointer> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let (records, generation) = if dir.join(MANIFEST).exists() {
            read_manifest(dir)?
        } else {
            (BTreeMap::new(), 0)
        };
        Ok(Checkpointer { dir: dir.to_path_buf(), records, generation, garbage: Vec::new() })
    }

    /// Open an existing checkpoint directory for restore. A missing or
    /// malformed manifest is a loud error — restoring from a directory
    /// we cannot fully account for must never silently succeed.
    pub fn open(dir: &Path) -> Result<Checkpointer> {
        if !dir.join(MANIFEST).exists() {
            bail!("{} has no {MANIFEST}: not a checkpoint directory", dir.display());
        }
        let (records, generation) = read_manifest(dir)?;
        Ok(Checkpointer { dir: dir.to_path_buf(), records, generation, garbage: Vec::new() })
    }

    /// The directory this checkpointer owns.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Current manifest generation (0 for a fresh or v1 directory).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Number of restorable snapshots.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the directory holds no snapshots.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Whether a restorable snapshot exists for `id`.
    pub fn contains(&self, id: &str) -> bool {
        self.records.contains_key(id)
    }

    /// Session ids with a restorable snapshot, in sorted order.
    pub fn ids(&self) -> Vec<String> {
        self.records.keys().cloned().collect()
    }

    /// The manifest record for `id`, if one exists.
    pub fn record(&self, id: &str) -> Option<&SnapshotRecord> {
        self.records.get(id)
    }

    /// Total bytes of snapshots on disk.
    pub fn total_bytes(&self) -> u64 {
        self.records.values().map(|r| r.bytes).sum()
    }

    /// Snapshot one session: write-temp-then-rename the envelope, then
    /// the updated manifest, so a crash at any point leaves the
    /// directory restorable (at worst without this session).
    pub fn save(&mut self, id: &str, scorer: &ChunkScorer) -> Result<SnapshotRecord> {
        let record = self.stage(id, scorer)?;
        self.commit()?;
        Ok(record)
    }

    /// Write one session's snapshot WITHOUT rewriting the manifest —
    /// the bulk-export building block (`checkpoint_all` stages every
    /// session, then [`Self::commit`]s once, instead of paying N
    /// manifest rewrites). Until commit, the new snapshot is invisible
    /// to restores: the on-disk manifest still describes the previous
    /// state — old or new, never torn.
    pub fn stage(&mut self, id: &str, scorer: &ChunkScorer) -> Result<SnapshotRecord> {
        self.stage_marked(id, scorer, 0, 0)
    }

    /// [`Self::stage`] carrying the delta-export dirty marker: the
    /// capturing manager's identity token plus the session's dirty
    /// generation, so a later delta export from the same manager can
    /// retain this record without re-reading the session.
    pub fn stage_marked(
        &mut self,
        id: &str,
        scorer: &ChunkScorer,
        exporter: u64,
        dirty_gen: u64,
    ) -> Result<SnapshotRecord> {
        let snap = SessionSnapshot::capture(id, scorer)?;
        self.stage_encoded(id, &snap.to_bytes(), scorer.tokens_seen() as u64, exporter, dirty_gen)
    }

    /// The file name a staged snapshot is written under. Committed
    /// exports must never have their referenced files replaced in place
    /// (a crash before the manifest commit would brick the previous
    /// generation), so the name embeds the generation being staged:
    /// re-staging a session writes a *new* file and queues the old one
    /// as post-commit garbage. Plain `save`/`stage` (no generation bump
    /// between commits) keeps overwriting one name, as before.
    fn staged_filename(&self, id: &str) -> String {
        let base = snapshot_filename(id);
        let stem = base.strip_suffix(".snap").unwrap_or(&base);
        format!("{stem}-g{}.snap", self.generation + 1)
    }

    /// Queue `record`'s file for deletion after the next manifest
    /// commit, unless a staged record still references the same name.
    fn retire_file(&mut self, record: &SnapshotRecord) {
        if self.records.values().all(|r| r.file != record.file) {
            self.garbage.push(record.file.clone());
        }
    }

    /// Stage an already-encoded `PFRMSNAP` envelope. This is the entry
    /// point for callers that hold snapshot bytes rather than a live
    /// scorer: the background spill writer (bytes were encoded on the
    /// serving thread at enqueue time) and exports of in-flight spills.
    pub fn stage_encoded(
        &mut self,
        id: &str,
        bytes: &[u8],
        pos: u64,
        exporter: u64,
        dirty_gen: u64,
    ) -> Result<SnapshotRecord> {
        let file = self.staged_filename(id);
        write_atomic(&self.dir.join(&file), bytes)
            .with_context(|| format!("writing snapshot for session '{id}'"))?;
        let record = SnapshotRecord {
            id: id.to_string(),
            file,
            bytes: bytes.len() as u64,
            crc: crc32(bytes),
            pos,
            exporter,
            dirty_gen,
        };
        if let Some(old) = self.records.insert(id.to_string(), record.clone()) {
            self.retire_file(&old);
        }
        Ok(record)
    }

    /// Stage a snapshot by *linking* an existing verified file (a spill
    /// snapshot or a previous export's record) into this directory
    /// instead of decoding and re-encoding it — O(1) IO per clean
    /// session. Hard-links when the filesystem allows (snapshot files
    /// are immutable once written: replacement always goes through a
    /// temp-file rename, never an in-place write, so a shared inode can
    /// never change under us), falling back to a byte copy. `src_record`
    /// supplies the verified length/CRC/position; only the dirty marker
    /// is re-stamped.
    pub fn stage_linked(
        &mut self,
        src: &Path,
        src_record: &SnapshotRecord,
        exporter: u64,
        dirty_gen: u64,
    ) -> Result<SnapshotRecord> {
        let file = self.staged_filename(&src_record.id);
        let dst = self.dir.join(&file);
        match std::fs::remove_file(&dst) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(anyhow!("clearing stale {}: {e}", dst.display())),
        }
        if std::fs::hard_link(src, &dst).is_err() {
            // cross-device or unsupported: fall back to a durable copy
            let bytes = std::fs::read(src)
                .with_context(|| format!("reading {} for linking", src.display()))?;
            write_atomic(&dst, &bytes)
                .with_context(|| format!("copying snapshot for '{}'", src_record.id))?;
        }
        let record = SnapshotRecord { file, exporter, dirty_gen, ..src_record.clone() };
        if let Some(old) = self.records.insert(record.id.clone(), record.clone()) {
            self.retire_file(&old);
        }
        Ok(record)
    }

    /// Drop a staged record WITHOUT rewriting the manifest — the
    /// delta-export building block for retiring records of sessions
    /// that have since closed; the caller commits once at the end. The
    /// file itself is deleted only after that commit (it is still
    /// referenced by the on-disk manifest until then). Returns whether
    /// a record existed.
    pub fn unstage(&mut self, id: &str) -> Result<bool> {
        let Some(record) = self.records.remove(id) else {
            return Ok(false);
        };
        self.retire_file(&record);
        Ok(true)
    }

    /// Insert one record in memory WITHOUT touching the manifest — the
    /// spill writer's publish step: the record becomes loadable through
    /// this handle immediately (the snapshot file is already on disk);
    /// a following [`Self::commit`] persists it for other processes.
    pub fn stage_record(&mut self, record: SnapshotRecord) {
        if let Some(old) = self.records.insert(record.id.clone(), record) {
            self.retire_file(&old);
        }
    }

    /// Persist the manifest, making every staged snapshot restorable,
    /// then delete files superseded since the previous commit.
    pub fn commit(&mut self) -> Result<()> {
        self.write_manifest()?;
        self.collect_garbage();
        Ok(())
    }

    /// Bump the manifest generation and persist — one atomic rename
    /// publishes the whole staged export (full or delta): a reader sees
    /// the previous generation or this one, never a mix. Files the
    /// previous generation referenced are deleted only now, after the
    /// new manifest is durable, so a crash at any earlier point leaves
    /// the previous generation fully restorable (at worst with a few
    /// orphaned staged files).
    pub fn commit_new_generation(&mut self) -> Result<()> {
        self.generation += 1;
        self.write_manifest()?;
        self.collect_garbage();
        Ok(())
    }

    /// Best-effort deletion of files superseded by the just-committed
    /// manifest (failures leave harmless orphans, never broken records).
    fn collect_garbage(&mut self) {
        for file in std::mem::take(&mut self.garbage) {
            let _ = std::fs::remove_file(self.dir.join(&file));
        }
    }

    /// Drop every snapshot (files + records) and persist the now-empty
    /// manifest. `checkpoint_all` clears its target first, so a reused
    /// export directory can never resurrect sessions that have since
    /// closed, and a `SessionManager` clears its spill directory on
    /// startup — the spill tier caches one process's live sessions,
    /// never a dead process's (restart recovery is `checkpoint_all` /
    /// `restore_from`). Returns how many snapshots were removed.
    pub fn clear(&mut self) -> Result<usize> {
        let records = std::mem::take(&mut self.records);
        if records.is_empty() && self.garbage.is_empty() {
            return Ok(0);
        }
        // manifest first: a crash mid-clear leaves a valid (empty)
        // directory plus orphan files, never a manifest pointing at
        // deleted snapshots
        self.write_manifest()?;
        for r in records.values() {
            let path = self.dir.join(&r.file);
            match std::fs::remove_file(&path) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(anyhow!("removing {}: {e}", path.display())),
            }
        }
        self.collect_garbage();
        Ok(records.len())
    }

    /// Rehydrate one session into a scorer over `model`. Verifies the
    /// manifest record (length + CRC32) against the file before the
    /// envelope is even decoded; any mismatch is a loud error.
    pub fn load(&self, id: &str, model: &Arc<NativeModel>) -> Result<ChunkScorer> {
        let record = self
            .records
            .get(id)
            .ok_or_else(|| anyhow!("no snapshot for session '{id}' in {}", self.dir.display()))?;
        let path = self.dir.join(&record.file);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() as u64 != record.bytes {
            bail!(
                "{}: {} bytes on disk, manifest says {} — truncated or torn snapshot",
                path.display(),
                bytes.len(),
                record.bytes
            );
        }
        let crc = crc32(&bytes);
        if crc != record.crc {
            bail!(
                "{}: checksum {crc:#010x} does not match manifest {:#010x} — corrupt snapshot",
                path.display(),
                record.crc
            );
        }
        let snap = SessionSnapshot::from_bytes(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        if snap.session != id {
            bail!(
                "{} holds session '{}', manifest filed it under '{id}'",
                path.display(),
                snap.session
            );
        }
        snap.into_scorer(model.clone())
    }

    /// Drop a session's snapshot (file + manifest record). Returns
    /// whether one existed.
    pub fn remove(&mut self, id: &str) -> Result<bool> {
        let Some(record) = self.records.remove(id) else {
            return Ok(false);
        };
        // manifest first, file second: the reverse order would leave a
        // manifest referencing a deleted snapshot after a crash
        self.write_manifest()?;
        let path = self.dir.join(&record.file);
        match std::fs::remove_file(&path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(anyhow!("removing {}: {e}", path.display())),
        }
        Ok(true)
    }

    fn write_manifest(&self) -> Result<()> {
        let manifest = obj(vec![
            ("format", s(MANIFEST_FORMAT)),
            ("version", num(MANIFEST_VERSION as f64)),
            ("generation", num(self.generation as f64)),
            (
                "sessions",
                arr(self.records.values().map(|r| {
                    obj(vec![
                        ("id", s(&r.id)),
                        ("file", s(&r.file)),
                        ("bytes", num(r.bytes as f64)),
                        ("crc", num(r.crc as f64)),
                        ("pos", num(r.pos as f64)),
                        // hex string: a u64 token does not fit losslessly
                        // in a JSON f64 number
                        ("exporter", s(&format!("{:016x}", r.exporter))),
                        ("dirty_gen", num(r.dirty_gen as f64)),
                    ])
                })),
            ),
        ]);
        write_atomic(&self.dir.join(MANIFEST), manifest.to_string().as_bytes())
            .context("writing checkpoint manifest")
    }
}

fn read_manifest(dir: &Path) -> Result<(BTreeMap<String, SnapshotRecord>, u64)> {
    let path = dir.join(MANIFEST);
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("{} is not valid JSON", path.display()))?;
    let format = j.req("format")?.as_str()?;
    if format != MANIFEST_FORMAT {
        bail!("{}: format '{format}' is not a session manifest", path.display());
    }
    let version = j.req("version")?.as_usize()?;
    // v1 manifests lack the generation counter and dirty markers: still
    // fully restorable, only un-retainable by a delta export
    if version == 0 || version > MANIFEST_VERSION {
        bail!("{}: unsupported manifest version {version}", path.display());
    }
    let generation = j.usize_or("generation", 0) as u64;
    let mut records = BTreeMap::new();
    for e in j.req("sessions")?.as_arr()? {
        let exporter = match e.get("exporter") {
            Some(v) => u64::from_str_radix(v.as_str()?, 16)
                .context("manifest exporter token is not hex")?,
            None => 0,
        };
        let r = SnapshotRecord {
            id: e.req("id")?.as_str()?.to_string(),
            file: e.req("file")?.as_str()?.to_string(),
            bytes: e.req("bytes")?.as_f64()? as u64,
            crc: e.req("crc")?.as_f64()? as u32,
            pos: e.req("pos")?.as_f64()? as u64,
            exporter,
            dirty_gen: e.f64_or("dirty_gen", 0.0) as u64,
        };
        if r.file.contains('/') || r.file.contains("..") {
            bail!("{}: record '{}' escapes the checkpoint dir", path.display(), r.file);
        }
        records.insert(r.id.clone(), r);
    }
    Ok((records, generation))
}

/// Write bytes to `path` via a `.tmp` sibling + fsync + rename + parent
/// directory fsync — the crash-safety primitive every persist-layer
/// write goes through. Without the directory sync the rename itself is
/// not durable across power loss on journaling filesystems; it is
/// best-effort because not every platform lets a directory be opened
/// for syncing.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if let Some(parent) = path.parent() {
        let _ = std::fs::File::open(parent).and_then(|d| d.sync_all());
    }
    Ok(())
}

/// Filesystem-safe snapshot name: a sanitized prefix of the id for
/// humans, plus an FNV-1a hash of the full id so distinct sessions can
/// never collide on a shared sanitized prefix.
pub(crate) fn snapshot_filename(id: &str) -> String {
    let safe: String = id
        .chars()
        .take(40)
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '_' })
        .collect();
    format!("{safe}-{:016x}.snap", crate::rng::fnv1a64(id.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protein::vocab::{AA_BASE, N_AA};
    use crate::rng::Pcg64;
    use crate::train::SyntheticConfig;

    fn model() -> Arc<NativeModel> {
        let mut rng = Pcg64::new(31);
        Arc::new(NativeModel::synthetic(&SyntheticConfig::default(), &mut rng))
    }

    fn tokens(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| AA_BASE + rng.below(N_AA) as u8).collect()
    }

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("pfrm_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_remove_lifecycle() {
        let dir = tempdir("lifecycle");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        assert!(ck.is_empty());

        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(20, 1)).unwrap();
        let rec = ck.save("user/1", &scorer).unwrap();
        assert_eq!(rec.pos, 20);
        assert!(ck.contains("user/1"));
        assert_eq!(ck.total_bytes(), rec.bytes);

        // a fresh handle over the same dir sees the manifest
        let ck2 = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck2.ids(), vec!["user/1".to_string()]);
        let restored = ck2.load("user/1", &m).unwrap();
        assert_eq!(restored.tokens_seen(), 20);

        let mut ck3 = Checkpointer::open(&dir).unwrap();
        assert!(ck3.remove("user/1").unwrap());
        assert!(!ck3.remove("user/1").unwrap());
        assert!(Checkpointer::open(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resave_overwrites_in_place() {
        let dir = tempdir("resave");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 2)).unwrap();
        ck.save("s", &scorer).unwrap();
        scorer.advance(&tokens(8, 3)).unwrap();
        ck.save("s", &scorer).unwrap();
        assert_eq!(ck.len(), 1);
        assert_eq!(ck.load("s", &m).unwrap().tokens_seen(), 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staged_snapshots_are_invisible_until_commit() {
        let dir = tempdir("stage");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 10)).unwrap();
        ck.stage("a", &scorer).unwrap();
        // a second handle (≈ another process) sees nothing yet
        assert!(Checkpointer::create(&dir).unwrap().is_empty());
        ck.commit().unwrap();
        assert_eq!(Checkpointer::open(&dir).unwrap().ids(), vec!["a".to_string()]);

        // clear drops files and records, and persists the empty manifest
        let mut ck = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck.clear().unwrap(), 1);
        assert_eq!(ck.clear().unwrap(), 0);
        assert!(Checkpointer::open(&dir).unwrap().is_empty());
        assert!(
            !std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .any(|e| e.path().extension().is_some_and(|x| x == "snap")),
            "clear must remove the snapshot files themselves"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_manifest_fails_loudly() {
        let dir = tempdir("badmanifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(MANIFEST), b"{not json").unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        // wrong format marker is also rejected
        std::fs::write(dir.join(MANIFEST), br#"{"format":"other","version":1,"sessions":[]}"#)
            .unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        // a record pointing outside the dir is rejected
        std::fs::write(
            dir.join(MANIFEST),
            br#"{"format":"pfrm-session-manifest","version":1,
                "sessions":[{"id":"x","file":"../x.snap","bytes":1,"crc":0,"pos":0}]}"#,
        )
        .unwrap();
        assert!(Checkpointer::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_snapshot_fails_loudly() {
        let dir = tempdir("truncated");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(12, 4)).unwrap();
        let rec = ck.save("t", &scorer).unwrap();

        let path = dir.join(&rec.file);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = Checkpointer::open(&dir).unwrap().load("t", &m).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");

        // corrupt (right length, flipped byte) must fail the checksum
        let mut bad = bytes.clone();
        bad[bytes.len() / 2] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        let err = Checkpointer::open(&dir).unwrap().load("t", &m).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_session_is_an_error() {
        let dir = tempdir("missing");
        let ck = Checkpointer::create(&dir).unwrap();
        assert!(ck.load("ghost", &model()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn generation_survives_reopen_and_bumps_on_commit() {
        let dir = tempdir("generation");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        assert_eq!(ck.generation(), 0);
        let mut scorer = ChunkScorer::new(m).unwrap();
        scorer.advance(&tokens(8, 50)).unwrap();
        ck.stage_marked("g", &scorer, 7, 3).unwrap();
        ck.commit_new_generation().unwrap();
        assert_eq!(ck.generation(), 1);

        let ck2 = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck2.generation(), 1);
        let rec = ck2.record("g").unwrap();
        assert_eq!((rec.exporter, rec.dirty_gen), (7, 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_manifests_still_open_with_default_markers() {
        let dir = tempdir("v1compat");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(MANIFEST),
            br#"{"format":"pfrm-session-manifest","version":1,
                "sessions":[{"id":"x","file":"x.snap","bytes":1,"crc":0,"pos":4}]}"#,
        )
        .unwrap();
        let ck = Checkpointer::open(&dir).unwrap();
        assert_eq!(ck.generation(), 0);
        let rec = ck.record("x").unwrap();
        assert_eq!((rec.exporter, rec.dirty_gen, rec.pos), (0, 0, 4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stage_linked_reuses_bytes_and_restamps_markers() {
        let src_dir = tempdir("link_src");
        let dst_dir = tempdir("link_dst");
        let m = model();
        let mut src = Checkpointer::create(&src_dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(16, 51)).unwrap();
        let rec = src.save("linked", &scorer).unwrap();

        let mut dst = Checkpointer::create(&dst_dir).unwrap();
        let lrec = dst
            .stage_linked(&src_dir.join(&rec.file), &rec, 99, 5)
            .unwrap();
        dst.commit_new_generation().unwrap();
        assert_eq!((lrec.bytes, lrec.crc), (rec.bytes, rec.crc));
        assert_eq!((lrec.exporter, lrec.dirty_gen), (99, 5));
        // the linked record restores like a first-class snapshot, even
        // after the source file's *name* disappears (the inode lives on)
        std::fs::remove_file(src_dir.join(&rec.file)).unwrap();
        let restored = Checkpointer::open(&dst_dir).unwrap().load("linked", &m).unwrap();
        assert_eq!(restored.tokens_seen(), 16);
        let _ = std::fs::remove_dir_all(&src_dir);
        let _ = std::fs::remove_dir_all(&dst_dir);
    }

    #[test]
    fn stage_record_publishes_in_memory_and_unstage_defers_deletion() {
        let dir = tempdir("adopt");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 52)).unwrap();
        // stage writes the file; the record is loadable through this
        // handle, while other processes see it only after commit
        let rec = ck.stage("a", &scorer).unwrap();
        assert!(Checkpointer::create(&dir).unwrap().is_empty());
        ck.stage_record(rec.clone());
        assert!(ck.load("a", &m).is_ok(), "staged record loads through this handle");
        ck.commit().unwrap();
        assert!(Checkpointer::open(&dir).unwrap().contains("a"));

        // unstage drops the record but defers the file delete to commit
        // (the on-disk manifest still references it until then)
        assert!(ck.unstage("a").unwrap());
        assert!(!ck.unstage("a").unwrap());
        assert!(Checkpointer::open(&dir).unwrap().contains("a"), "not yet committed");
        assert!(dir.join(&rec.file).exists(), "file must outlive the stale manifest");
        ck.commit().unwrap();
        assert!(Checkpointer::open(&dir).unwrap().is_empty());
        assert!(!dir.join(&rec.file).exists(), "commit reclaims the unstaged file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restaging_never_replaces_a_committed_file_in_place() {
        // the crash-consistency contract of delta exports: files a
        // committed manifest references are not touched until the next
        // generation commits, so re-staging a dirty session writes a
        // NEW file and the old one survives (and restores) up to commit
        let dir = tempdir("restaging");
        let m = model();
        let mut ck = Checkpointer::create(&dir).unwrap();
        let mut scorer = ChunkScorer::new(m.clone()).unwrap();
        scorer.advance(&tokens(8, 53)).unwrap();
        ck.stage("s", &scorer).unwrap();
        ck.commit_new_generation().unwrap();
        let gen1 = Checkpointer::open(&dir).unwrap();
        let old_file = gen1.record("s").unwrap().file.clone();

        scorer.advance(&tokens(8, 54)).unwrap();
        let new = ck.stage("s", &scorer).unwrap();
        assert_ne!(new.file, old_file, "re-staging must not reuse the committed name");
        assert!(dir.join(&old_file).exists(), "committed snapshot untouched pre-commit");
        // a crash here (simulated by a fresh handle) restores generation 1
        assert_eq!(gen1.load("s", &m).unwrap().tokens_seen(), 8);

        ck.commit_new_generation().unwrap();
        assert!(!dir.join(&old_file).exists(), "superseded file reclaimed at commit");
        let restored = Checkpointer::open(&dir).unwrap().load("s", &m).unwrap();
        assert_eq!(restored.tokens_seen(), 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn filenames_are_safe_and_collision_free() {
        let a = snapshot_filename("user/../../etc/passwd");
        assert!(!a.contains('/') && a.ends_with(".snap"));
        // same sanitized prefix, different ids -> different files
        let b = snapshot_filename("user:1");
        let c = snapshot_filename("user_1");
        assert_ne!(b, c);
    }
}
